"""Dispatch hot-path step-time sweep: every registered path x ``use_pallas``.

The rows this module emits (``dispatch_<path>_pallas-<mode>``) are the
step-time trajectory the benchmark-regression CI lane guards: they land in
``BENCH_dispatch.json`` and are compared against the committed
``results/BENCH_baseline.json`` by ``benchmarks.compare``.

Modes swept per path: ``off`` (jnp reference permutation) and ``auto``
(the engine default — Pallas kernels on TPU/GPU, reference elsewhere, so
on CPU CI the two columns coincide and the kernel speedup shows up on
accelerator runners).  On TPU an explicit ``on`` mode is added.

Measurement discipline (shared CI runners are noisy): every configuration
is compiled and warmed first, then timed in round-robin batches — one
batch of each config per round — and the per-config minimum over rounds is
reported (the ``timeit`` convention).  Interleaving spreads temporal noise
spikes across all rows, which is what lets ``benchmarks.compare``'s
machine-normalization cancel them.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import dispatch as dispatch_lib, gating
from repro.core.capacity import make_plan

PATHS = ("a2a", "a2a_pipelined", "gather", "einsum")


def _modes():
    modes = [("off", False), ("auto", None)]
    if jax.default_backend() == "tpu":
        modes.append(("on", True))
    return modes


def run(quick: bool = False):
    T = 128 if quick else 512
    D, F, N, K = 64, 128, 8, 2
    iters = 4 if quick else 8
    rounds = 8 if quick else 12
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dispatch_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                                 capacity_factor=2.0, dtype=jnp.float32)
    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dispatch_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                          gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=2.0, num_pods=1, ep_per_pod=1,
                     mode="even")
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)

    def _make(name, flag):
        kw = {}
        if name in ("a2a", "a2a_pipelined"):
            kw["plan"] = plan
        if name == "a2a_pipelined":
            kw["num_chunks"] = 2
        if name == "einsum":
            kw["capacity"] = T
        eng = dispatch_lib.make_engine(name, cfg=cfg, ep=ep,
                                       gate_cfg=gate_cfg, use_pallas=flag,
                                       **kw)
        body = shard_map(lambda p, xx: eng(p, xx)[0], mesh=mesh,
                         in_specs=(P(), P()), out_specs=P(),
                         check_vma=False)
        return jax.jit(body)

    # compile + warm every config up front, then time round-robin
    configs = []
    for name in PATHS:
        for mode, flag in _modes():
            if name == "einsum" and mode != "off":
                continue   # the oracle has no permutation kernels
            configs.append((f"{name}_pallas-{mode}", _make(name, flag)))

    # anchor rows: fixed pure-jnp workloads spelled out *here*, running no
    # repo code at all — benchmarks.compare estimates the machine-speed
    # scale from these (prefix "dispatch_anchor"), so a regression anywhere
    # in src/repro (permutation hot path, grouped GEMM, gating) cannot
    # shift the normalization and hide itself behind "the machine got
    # slower".
    w1 = jax.random.normal(jax.random.PRNGKey(9), (N, D, F), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(10), (N, F, D), jnp.float32)
    xa = jax.random.normal(jax.random.PRNGKey(8), (N, 8 * T, D),
                           jnp.float32)
    configs.append(("anchor_ffn", jax.jit(
        lambda p, xx, _xa=xa, _w1=w1, _w2=w2: jnp.einsum(
            "ecf,efd->ecd", jax.nn.silu(jnp.einsum("ecd,edf->ecf", _xa,
                                                   _w1)), _w2))))
    ma = jax.random.normal(jax.random.PRNGKey(7), (768, 768), jnp.float32)
    configs.append(("anchor_matmul", jax.jit(
        lambda p, xx, _a=ma: (_a @ _a) @ _a)))

    print(f"# dispatch sweep: T={T} d={D} E={N} k={K} "
          f"backend={jax.default_backend()} "
          f"({rounds} interleaved rounds x {iters} iters, min)")
    with mesh:
        for _, fn in configs:
            jax.block_until_ready(fn(params, x))
            jax.block_until_ready(fn(params, x))
        samples = {label: [] for label, _ in configs}
        for _ in range(rounds):
            for label, fn in configs:
                # anchors set the compare gate's machine-speed scale, so
                # their min must converge hardest: oversample them (they
                # are also the cheapest rows)
                reps = 4 if label.startswith("anchor") else 1
                for _ in range(reps):
                    t0 = time.perf_counter()
                    for _ in range(iters):
                        out = fn(params, x)
                    jax.block_until_ready(out)
                    samples[label].append(
                        (time.perf_counter() - t0) / iters * 1e6)

    rows = []
    print(f"{'config':>28s}{'us/call':>10s}")
    for label, _ in configs:
        us = float(min(samples[label]))
        print(f"{label:>28s}{us:10.1f}")
        rows.append((f"dispatch_{label}", us,
                     f"T={T};d={D};E={N};k={K};"
                     f"backend={jax.default_backend()}"))

    # cross-check while we are here: step-time rows are only comparable if
    # the paths still agree (guards against benchmarking a broken kernel).
    # Reuse the compiled configs; a blown tolerance raises, which run.py
    # records as a dispatch_FAILED row — and that fails the compare gate.
    fns = dict(configs)
    with mesh:
        y_a2a = np.asarray(fns["a2a_pallas-auto"](params, x))
        y_oracle = np.asarray(fns["einsum_pallas-off"](params, x))
    err = float(np.abs(y_a2a - y_oracle).max())
    print(f"# a2a vs einsum oracle max err: {err:.2e}")
    if err > 1e-4:
        raise RuntimeError(
            f"a2a diverged from the einsum oracle (max abs err {err:.2e}); "
            "refusing to report step times for broken dispatch math")
    rows.append(("dispatch_oracle_err", err * 1e6, f"max_abs_err={err:.2e}"))
    return rows
