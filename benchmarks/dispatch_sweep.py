"""Dispatch hot-path step-time sweep: every registered path x ``use_pallas``.

The rows this module emits (``dispatch_<path>_pallas-<mode>``) are the
step-time trajectory the benchmark-regression CI lane guards: they land in
``BENCH_dispatch.json`` and are compared against the committed
``results/BENCH_baseline.json`` by ``benchmarks.compare``.

Modes swept per path: ``off`` (jnp reference permutation) and ``auto``
(the engine default — Pallas kernels on TPU/GPU, reference elsewhere, so
on CPU CI the two columns coincide and the kernel speedup shows up on
accelerator runners).  On TPU an explicit ``on`` mode is added.  The
``a2a_wire-*`` rows run the same a2a engine under the registered wire
codecs (bf16 cast, int8 quantize + quantized expert GEMMs), and the
``dispatch_chunk_verdict_wire-*`` rows pin the comm-model chunk
chooser's verdict under codec-scaled byte counts.

Measurement discipline (shared CI runners are noisy): every configuration
is compiled and warmed first, then timed in round-robin batches — one
batch of each config per round — and the per-config minimum over rounds is
reported (the ``timeit`` convention).  Interleaving spreads temporal noise
spikes across all rows, which is what lets ``benchmarks.compare``'s
machine-normalization cancel them.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import dispatch as dispatch_lib, gating
from repro.core.capacity import make_plan
from repro.kernels.moe_fused import ops as fused_ops
from repro.kernels.moe_gemm import ops as gemm_ops
from repro.kernels.moe_permute import ops as permute_ops

PATHS = ("a2a", "a2a_pipelined", "gather", "einsum")

# gemm_occupancy microbench: the occupancy-aware ragged grouped FFN at
# 25/50/100% capacity utilization.  Shapes are chosen so per-block MXU work
# dominates the Pallas interpreter's unconditional per-step block copies —
# otherwise the block-skip saving drowns on CPU CI.
GEMM_E, GEMM_C, GEMM_D, GEMM_F, GEMM_BC = 4, 512, 128, 512, 128
GEMM_OCCS = (25, 50, 100)
# gemm_fused contrast: the dispatch→GEMM→combine megakernel must come in no
# slower than the same traffic through the three-kernel composition
# (permute → ragged grouped GEMM → unpermute) on the CPU interpret path —
# it runs strictly fewer kernel launches and zero [S, d] HBM round trips,
# so any slowdown means the fused grid is doing extra work.  Ratio is
# loose-ish because the three compared kernels interleave differently with
# interpreter per-step copy overhead on shared CI runners.
FUSED_MAX_VS_UNFUSED = 1.10
# the resilience guard (fault scalars, fused non-finite reduce, in-jit
# select) is sold as free: the guarded train step must ride within 5% of
# the plain step or the sweep fails rather than report
GUARD_MAX_OVERHEAD = 1.05


def _modes():
    modes = [("off", False), ("auto", None)]
    if jax.default_backend() == "tpu":
        modes.append(("on", True))
    return modes


def run(quick: bool = False):
    T = 128 if quick else 512
    D, F, N, K = 64, 128, 8, 2
    iters = 4 if quick else 8
    rounds = 8 if quick else 12
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dispatch_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                                 capacity_factor=2.0, dtype=jnp.float32)
    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dispatch_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                          gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=2.0, num_pods=1, ep_per_pod=1,
                     mode="even")
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)

    def _make(name, flag):
        kw = {}
        if name in ("a2a", "a2a_pipelined"):
            kw["plan"] = plan
        if name == "a2a_pipelined":
            kw["num_chunks"] = 2
        if name == "einsum":
            kw["capacity"] = T
        eng = dispatch_lib.make_engine(name, cfg=cfg, ep=ep,
                                       gate_cfg=gate_cfg, use_pallas=flag,
                                       **kw)
        body = shard_map(lambda p, xx: eng(p, xx)[0], mesh=mesh,
                         in_specs=(P(), P()), out_specs=P(),
                         check_vma=False)
        return jax.jit(body)

    # compile + warm every config up front, then time round-robin
    configs = []
    for name in PATHS:
        for mode, flag in _modes():
            if name == "einsum" and mode != "off":
                continue   # the oracle has no permutation kernels
            configs.append((f"{name}_pallas-{mode}", _make(name, flag)))

    # wire_codec rows: the a2a engine with the registered wire codecs at
    # matched shapes.  On the single-rank bench mesh the collectives are
    # trivial, so these rows time the codec overhead itself (encode /
    # scale / decode, plus the int8-quantized expert GEMMs) against the
    # raw-wire "a2a_pallas-*" rows above.
    import dataclasses as _dc
    for codec in ("bf16", "int8"):
        cfg_c = _dc.replace(cfg, wire_codec=codec)
        eng_c = dispatch_lib.make_engine("a2a", cfg=cfg_c, ep=ep,
                                         gate_cfg=gate_cfg, plan=plan,
                                         use_pallas=None)
        body_c = shard_map(lambda p, xx, _e=eng_c: _e(p, xx)[0], mesh=mesh,
                           in_specs=(P(), P()), out_specs=P(),
                           check_vma=False)
        configs.append((f"a2a_wire-{codec}_pallas-auto", jax.jit(body_c)))

    # train_step guard rows: one full fwd+bwd+AdamW step of the reduced
    # MoE stack, plain vs guarded (fault scalars, fused non-finite
    # reduce, in-jit select).  The guard is sold as free — its overhead
    # is gated at GUARD_MAX_OVERHEAD below, and both rows land in the
    # compare lane so the *absolute* step time is pinned too.
    from repro.configs.base import RunConfig as _RunConfig
    from repro.configs.base import get_config as _get_config
    from repro.data.pipeline import (DataConfig as _DataConfig,
                                     SyntheticLM as _SyntheticLM,
                                     shard_batch as _shard_batch)
    from repro.models import model as _model_lib
    from repro.optim import adamw as _adamw
    from repro.resilience import chaos as _chaos_lib
    from repro.training import trainer as _trainer_lib
    from repro import sharding as _sharding
    g_arch = _get_config("gpt3_medium_moe").reduced()
    g_run = _RunConfig(seq_len=32, global_batch=4, total_steps=100,
                       warmup_steps=10, aux_mode="ta", seed=0)
    g_ctx = _model_lib.build_ctx(g_arch, mesh, seq_len=g_run.seq_len,
                                 global_batch=g_run.global_batch,
                                 aux_mode="ta")
    with mesh, _sharding.axis_rules(_model_lib.default_rules(mesh)):
        g_params = _model_lib.init_params(jax.random.PRNGKey(2), g_ctx)
    g_opt = _adamw.init_state(g_params)
    g_batch = _shard_batch(_SyntheticLM(_DataConfig(
        vocab_size=g_arch.vocab_size, seq_len=g_run.seq_len,
        global_batch=g_run.global_batch, seed=0), g_arch).batch(0), mesh)
    g_plain = jax.jit(_trainer_lib.make_train_step(g_ctx, g_run))
    g_guarded = jax.jit(_trainer_lib.make_guarded_train_step(g_ctx, g_run))
    g_scales = _chaos_lib.fault_scales(None, 0)
    g_fault = {k: jnp.float32(g_scales[k])
               for k in ("loss_mult", "grad_mult")}
    configs.append(("train_step_guard-off",
                    lambda p, xx: g_plain(g_params, g_opt, g_batch)))
    configs.append(("train_step_guard-on",
                    lambda p, xx: g_guarded(g_params, g_opt, g_batch,
                                            g_fault)))

    # anchor rows: fixed pure-jnp workloads spelled out *here*, running no
    # repo code at all — benchmarks.compare estimates the machine-speed
    # scale from these (prefix "dispatch_anchor"), so a regression anywhere
    # in src/repro (permutation hot path, grouped GEMM, gating) cannot
    # shift the normalization and hide itself behind "the machine got
    # slower".
    w1 = jax.random.normal(jax.random.PRNGKey(9), (N, D, F), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(10), (N, F, D), jnp.float32)
    xa = jax.random.normal(jax.random.PRNGKey(8), (N, 8 * T, D),
                           jnp.float32)
    configs.append(("anchor_ffn", jax.jit(
        lambda p, xx, _xa=xa, _w1=w1, _w2=w2: jnp.einsum(
            "ecf,efd->ecd", jax.nn.silu(jnp.einsum("ecd,edf->ecf", _xa,
                                                   _w1)), _w2))))
    ma = jax.random.normal(jax.random.PRNGKey(7), (768, 768), jnp.float32)
    configs.append(("anchor_matmul", jax.jit(
        lambda p, xx, _a=ma: (_a @ _a) @ _a)))

    # gemm_occupancy rows: the ragged grouped FFN at partial capacity
    # utilization.  "off" is the dense-FLOPs jnp reference (occupancy
    # cannot change its cost); "kernel" forces the Pallas entry — compiled
    # on TPU, interpreted on CPU — where row blocks past the realized count
    # are skipped, so the 25% row must come in under the 100% row.
    E_g, C_g, d_g, f_g = GEMM_E, GEMM_C, GEMM_D, GEMM_F
    g_offs = tuple(C_g * e for e in range(E_g + 1))
    g_exps = tuple(range(E_g))
    kg = jax.random.split(jax.random.PRNGKey(11), 4)
    g_x = jax.random.normal(kg[0], (E_g * C_g, d_g), jnp.float32)
    g_wi = jax.random.normal(kg[1], (E_g, d_g, f_g), jnp.float32) * 0.1
    g_wg = jax.random.normal(kg[2], (E_g, d_g, f_g), jnp.float32) * 0.1
    g_wo = jax.random.normal(kg[3], (E_g, f_g, d_g), jnp.float32) * 0.1
    gemm_rows = {}
    for occ in GEMM_OCCS:
        nrows = C_g * occ // 100
        # zero-slot convention: rows past the realized count hold zeros,
        # exactly as the permute sentinel delivers them
        g_xo = jnp.where(
            jnp.arange(E_g * C_g)[:, None] % C_g < nrows, g_x, 0.0)
        valid = jnp.full((E_g,), nrows, jnp.int32)
        # the dense reference burns full-capacity FLOPs whatever the
        # occupancy, so a single "off" contrast row (at 100%) suffices —
        # duplicating it per occupancy only adds noisy gate rows
        modes = [("kernel", True)] if gemm_ops.use_ragged(True) else []
        if occ == 100:
            modes.append(("off", False))
        for mode, flag in modes:
            label = f"gemm_occupancy-{occ:03d}_pallas-{mode}"
            gemm_rows[label] = (occ, mode, nrows * E_g)
            configs.append((label, jax.jit(functools.partial(
                lambda p, xx, _x, _v, _f: gemm_ops.grouped_ffn_ragged(
                    _x, g_offs, g_exps, _v, g_wi, g_wg, g_wo,
                    block_c=GEMM_BC, use_pallas=_f),
                _x=g_xo, _v=valid, _f=flag))))

    # gemm_fused rows: the same expert shapes through the fused megakernel
    # vs the three-kernel composition, both with the kernels forced on, at
    # partial occupancy.  Tokens are distinct per valid slot (K = 1
    # inverse) so the unfused combine is a plain unpermute; slack slots
    # carry the sentinel and zero weight, exactly as build_indices emits
    # them.
    fused_rows = {}
    if fused_ops.use_fused(True):
        rngf = np.random.default_rng(12)
        T_f = GEMM_E * GEMM_C
        S_f = GEMM_E * GEMM_C
        f_x = jax.random.normal(jax.random.PRNGKey(12), (T_f, GEMM_D),
                                jnp.float32)
        for occ in GEMM_OCCS:
            nrows = GEMM_C * occ // 100
            perm = rngf.permutation(T_f)
            tok = np.full(S_f, T_f, np.int32)
            w = np.zeros(S_f, np.float32)
            for e in range(GEMM_E):
                seg = slice(e * GEMM_C, e * GEMM_C + nrows)
                tok[seg] = perm[e * nrows:(e + 1) * nrows]
                w[seg] = rngf.uniform(0.5, 1.0, nrows)
            inv_idx = np.full((T_f, 1), S_f, np.int32)
            inv_w = np.zeros((T_f, 1), np.float32)
            kept = tok < T_f
            inv_idx[tok[kept], 0] = np.nonzero(kept)[0]
            inv_w[tok[kept], 0] = w[kept]
            valid = jnp.full((GEMM_E,), nrows, jnp.int32)
            tok_j, w_j = jnp.asarray(tok), jnp.asarray(w)
            ii_j, iw_j = jnp.asarray(inv_idx), jnp.asarray(inv_w)

            def _fused(p, xx, _t=tok_j, _w=w_j, _v=valid):
                return fused_ops.local_moe(
                    f_x, _t, _w, g_offs, g_exps, _v, g_wi, g_wg, g_wo,
                    block_c=GEMM_BC, use_pallas=True)

            def _unfused(p, xx, _t=tok_j, _v=valid, _ii=ii_j, _iw=iw_j):
                buf = permute_ops.permute(f_x, _t, use_pallas=True)
                ys = gemm_ops.grouped_ffn_ragged(
                    buf, g_offs, g_exps, _v, g_wi, g_wg, g_wo,
                    block_c=GEMM_BC, use_pallas=True)
                return permute_ops.unpermute(ys, _ii, _iw, use_pallas=True)

            for mode, fn in (("kernel", _fused), ("unfused", _unfused)):
                label = f"gemm_fused-{occ:03d}_pallas-{mode}"
                fused_rows[label] = (occ, mode, nrows * GEMM_E)
                configs.append((label, jax.jit(fn)))

    print(f"# dispatch sweep: T={T} d={D} E={N} k={K} "
          f"backend={jax.default_backend()} "
          f"({rounds} interleaved rounds x {iters} iters, min)")
    with mesh:
        for _, fn in configs:
            jax.block_until_ready(fn(params, x))
            jax.block_until_ready(fn(params, x))
        samples = {label: [] for label, _ in configs}
        for _ in range(rounds):
            for label, fn in configs:
                # anchors set the compare gate's machine-speed scale, so
                # their min must converge hardest: oversample them (they
                # are also the cheapest rows); the big-GEMM occupancy rows
                # get 2x so their min shakes off contention spikes
                reps = 4 if label.startswith("anchor") \
                    else 2 if label.startswith(("gemm_occupancy",
                                                "gemm_fused")) else 1
                for _ in range(reps):
                    t0 = time.perf_counter()
                    for _ in range(iters):
                        out = fn(params, x)
                    jax.block_until_ready(out)
                    samples[label].append(
                        (time.perf_counter() - t0) / iters * 1e6)

    rows = []
    print(f"{'config':>34s}{'us/call':>10s}{'  realized':>12s}")
    for label, _ in configs:
        us = float(min(samples[label]))
        if label in gemm_rows or label in fused_rows:
            occ, mode, realized = (gemm_rows.get(label)
                                   or fused_rows[label])
            derived = (f"E={GEMM_E};C={GEMM_C};d={GEMM_D};f={GEMM_F};"
                       f"rows={realized}/{GEMM_E * GEMM_C};occ={occ}%;"
                       f"backend={jax.default_backend()}")
            print(f"{label:>34s}{us:10.1f}"
                  f"{realized:>6d}/{GEMM_E * GEMM_C}")
        else:
            derived = (f"T={T};d={D};E={N};k={K};"
                       f"backend={jax.default_backend()}")
            print(f"{label:>34s}{us:10.1f}")
        rows.append((f"dispatch_{label}", us, derived))

    # occupancy must buy wall-clock on the kernel path: at 25% utilization
    # three of four row blocks per expert are skipped by the pl.when
    # predicate, so the 25% row has to land measurably under the 100% row
    # (the "off" reference column burns dense FLOPs either way and is the
    # contrast).  Raising here turns into a dispatch_FAILED row in run.py,
    # which fails the compare gate.
    k25 = "gemm_occupancy-025_pallas-kernel"
    k100 = "gemm_occupancy-100_pallas-kernel"
    if k25 in samples and jax.default_backend() == "cpu":
        t25, t100 = min(samples[k25]), min(samples[k100])
        print(f"# gemm occupancy 25%/100% kernel-path ratio: "
              f"{t25 / t100:.3f}")
        if t25 > 0.92 * t100:
            raise RuntimeError(
                f"25%-occupancy ragged GEMM not measurably faster than "
                f"100% on the kernel path ({t25:.0f}us vs {t100:.0f}us): "
                "the block-skip predicate is not buying wall-clock")

    # the fused megakernel's own gates, same discipline: (a) fused must be
    # no slower than the three-kernel composition it replaces at every
    # occupancy, and (b) fused must inherit the slack-block skip — the 25%
    # row lands measurably under the 100% row, same bar as the plain
    # ragged GEMM above.  Raising turns into a dispatch_FAILED row.
    if fused_rows and jax.default_backend() == "cpu":
        for occ in GEMM_OCCS:
            tf = min(samples[f"gemm_fused-{occ:03d}_pallas-kernel"])
            tu = min(samples[f"gemm_fused-{occ:03d}_pallas-unfused"])
            print(f"# gemm fused/unfused ratio at {occ}%: {tf / tu:.3f}")
            if tf > FUSED_MAX_VS_UNFUSED * tu:
                raise RuntimeError(
                    f"fused megakernel slower than the three-kernel path "
                    f"at {occ}% occupancy ({tf:.0f}us vs {tu:.0f}us): "
                    "fusion is not paying for itself")
        f25 = min(samples["gemm_fused-025_pallas-kernel"])
        f100 = min(samples["gemm_fused-100_pallas-kernel"])
        print(f"# gemm fused 25%/100% ratio: {f25 / f100:.3f}")
        if f25 > 0.92 * f100:
            raise RuntimeError(
                f"25%-occupancy fused megakernel not measurably faster "
                f"than 100% ({f25:.0f}us vs {f100:.0f}us): the fused grid "
                "lost the slack-block skip")

    # guard-overhead gate: min-over-rounds of the guarded vs plain train
    # step.  Raising turns into a dispatch_FAILED row in run.py, which
    # fails the compare gate.
    tg_off = min(samples["train_step_guard-off"])
    tg_on = min(samples["train_step_guard-on"])
    print(f"# train-step guard overhead: {tg_on / tg_off:.3f}x "
          f"({tg_on:.0f}us vs {tg_off:.0f}us)")
    if tg_on > GUARD_MAX_OVERHEAD * tg_off:
        raise RuntimeError(
            f"guarded train step {tg_on / tg_off:.3f}x the plain step "
            f"({tg_on:.0f}us vs {tg_off:.0f}us, gate "
            f"{GUARD_MAX_OVERHEAD:.2f}x): the health guard is supposed "
            "to be free")

    # cross-check while we are here: step-time rows are only comparable if
    # the paths still agree (guards against benchmarking a broken kernel).
    # Reuse the compiled configs; a blown tolerance raises, which run.py
    # records as a dispatch_FAILED row — and that fails the compare gate.
    fns = dict(configs)
    with mesh:
        y_a2a = np.asarray(fns["a2a_pallas-auto"](params, x))
        y_oracle = np.asarray(fns["einsum_pallas-off"](params, x))
    err = float(np.abs(y_a2a - y_oracle).max())
    print(f"# a2a vs einsum oracle max err: {err:.2e}")
    if err > 1e-4:
        raise RuntimeError(
            f"a2a diverged from the einsum oracle (max abs err {err:.2e}); "
            "refusing to report step times for broken dispatch math")
    rows.append(("dispatch_oracle_err", err * 1e6, f"max_abs_err={err:.2e}"))

    # same discipline for the quantized wire: the int8-codec engine must
    # stay within quantization noise of the raw-wire engine, or its
    # step-time rows are meaningless
    with mesh:
        y_q = np.asarray(fns["a2a_wire-int8_pallas-auto"](params, x))
    qerr = float(np.abs(y_q - y_a2a).max())
    qref = max(float(np.abs(y_a2a).max()), 1.0)
    print(f"# int8-wire vs raw-wire a2a max err: {qerr:.2e} "
          f"(ref magnitude {qref:.2e})")
    if qerr > 0.08 * qref:
        raise RuntimeError(
            f"int8 wire codec diverged from the raw-wire engine "
            f"(max abs err {qerr:.2e} vs ref {qref:.2e}); refusing to "
            "report step times for broken quantization")

    # chunk-chooser verdicts from codec-scaled byte counts, at a
    # production-ish shape where the bf16 -> int8 swap flips the verdict
    # (deterministic model output, so the compare gate pins it exactly)
    from repro.core import comm_model
    from repro.core.capacity import make_dispatch_plan
    vplan = make_dispatch_plan(tokens_per_device=512, num_experts=32,
                               top_k=2, capacity_factor=2.0,
                               axis_sizes=(4, 8), mode="ta")
    for codec in ("bf16", "int8"):
        terms = comm_model.moe_overlap_terms(vplan, d_model=1024, d_ff=2048,
                                             bytes_per_el=2, codec=codec)
        pick = comm_model.choose_num_chunks(
            t_exchange=terms["t_exchange"], t_compute=terms["t_compute"],
            alpha=terms["alpha"])
        print(f"# chunk-chooser verdict (wire={codec}): num_chunks={pick} "
              f"t_exchange={terms['t_exchange']*1e6:.2f}us")
        rows.append((f"dispatch_chunk_verdict_wire-{codec}", float(pick),
                     f"t_exchange_us={terms['t_exchange']*1e6:.2f};"
                     f"E=32;T=512;mesh=4x8;d=1024;f=2048"))
    return rows
