"""Docs-freshness lint: fail if the docs reference a file, module, or CLI
flag that does not exist in the repo.

    PYTHONPATH=src python -m benchmarks.check_docs

Checked references, all taken from backticked spans:

- **paths** (contain ``/`` or end in a known source suffix): must exist
  relative to the repo root, after stripping an optional ``::member``
  suffix and any trailing punctuation.  Run-generated artifacts
  (``BENCH_*.json``, ``analysis_report.json``) are exempt — they are
  outputs, not sources.
- **modules** (``repro.foo.bar`` / ``benchmarks.baz`` dotted names): the
  corresponding ``.py`` file (or package dir) must exist.
- **flags** (``--foo-bar``): must appear literally somewhere under the
  repo's source/tooling trees — a renamed argparse option invalidates
  every doc that mentions it.

Exit 1 with a per-reference report on any miss; CI runs this in the lint
lane so stale docs fail the PR, not the reader.
"""

import argparse
import os
import re
import sys

DOC_FILES = ("README.md", "docs/architecture.md", "docs/serving.md",
             "docs/analysis.md", "docs/resilience.md")
# trees searched for flag definitions/uses
FLAG_TREES = ("src", "benchmarks", "examples", "tests", ".github", "results")
PATH_SUFFIXES = (".py", ".md", ".json", ".yml", ".yaml", ".txt", ".toml")
GENERATED = re.compile(r"^(BENCH_\w+|analysis_report)\.json$")
BACKTICK = re.compile(r"`([^`\n]+)`")
MODULE = re.compile(r"^(repro|benchmarks|results)(\.\w+)+$")
FLAG = re.compile(r"^--[a-z][a-z0-9-]*$")


def _span_refs(span):
    """Yield (kind, ref) pairs a backticked span pins to the repo."""
    # a span may be a whole command line: split and inspect each token
    for tok in span.split():
        tok = tok.strip(",;:()[]{}\"'")
        if not tok:
            continue
        if FLAG.match(tok.split("=")[0]):
            yield "flag", tok.split("=")[0]
            continue
        base = tok.split("::")[0].rstrip("/")
        if MODULE.match(base):
            yield "module", base
            continue
        looks_like_path = ("/" in base and not base.startswith("--")
                           ) or base.endswith(PATH_SUFFIXES)
        if looks_like_path and not base.startswith(("http://", "https://")):
            yield "path", base


def _flag_corpus(root):
    """Every ``--flag`` literal defined or used under the repo trees."""
    flags = set()
    for tree in FLAG_TREES:
        top = os.path.join(root, tree)
        for dirpath, _, names in os.walk(top):
            for name in names:
                if not name.endswith((".py", ".yml", ".yaml", ".sh")):
                    continue
                try:
                    with open(os.path.join(dirpath, name),
                              errors="ignore") as f:
                        text = f.read()
                except OSError:
                    continue
                flags.update(re.findall(r"--[a-z][a-z0-9-]*", text))
    return flags


def check(root, doc_files=DOC_FILES):
    """Returns (missing_docs, problems); problems are
    ``(doc, kind, ref)`` triples that did not resolve."""
    flags = _flag_corpus(root)
    missing_docs, problems = [], []
    for doc in doc_files:
        path = os.path.join(root, doc)
        if not os.path.exists(path):
            missing_docs.append(doc)
            continue
        with open(path) as f:
            text = f.read()
        # fenced code blocks are prose too — commands in them must be real
        seen = set()
        for span in BACKTICK.findall(text):
            for kind, ref in _span_refs(span):
                if (kind, ref) in seen:
                    continue
                seen.add((kind, ref))
                if kind == "path":
                    if GENERATED.match(os.path.basename(ref)):
                        continue
                    # subsystem shorthand like `core/dispatch` resolves
                    # under src/repro/ (the package root)
                    cand = (os.path.join(root, ref),
                            os.path.join(root, "src", "repro", ref))
                    if not any(os.path.exists(c) for c in cand):
                        problems.append((doc, kind, ref))
                elif kind == "module":
                    rel = ref.replace(".", "/")
                    cand = (os.path.join(root, "src", rel + ".py"),
                            os.path.join(root, "src", rel),
                            os.path.join(root, rel + ".py"),
                            os.path.join(root, rel))
                    if not any(os.path.exists(c) for c in cand):
                        problems.append((doc, kind, ref))
                elif kind == "flag":
                    if ref not in flags:
                        problems.append((doc, kind, ref))
    return missing_docs, problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    ap.add_argument("--docs", nargs="*", default=list(DOC_FILES))
    args = ap.parse_args(argv)

    missing_docs, problems = check(args.root, args.docs)
    for doc in missing_docs:
        print(f"[check_docs] MISSING DOC {doc}")
    for doc, kind, ref in problems:
        print(f"[check_docs] STALE {doc}: {kind} `{ref}` does not resolve")
    if missing_docs or problems:
        print(f"[check_docs] FAIL: {len(missing_docs)} missing doc(s), "
              f"{len(problems)} stale reference(s)")
        return 1
    print(f"[check_docs] OK: {len(args.docs)} docs, all references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
