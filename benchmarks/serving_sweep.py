"""Serving throughput sweep: continuous batching at 1/4/8 concurrent
streams over the mla and vlm serving configs.

Rows (``serving_<fam>_s<N>``) report microseconds per *generated* token
and aggregate tokens/sec at each concurrency level; they land in
``BENCH_serving.json`` and are gated by ``benchmarks.compare`` against
``results/BENCH_baseline.json``.  ``serving_anchor_*`` rows are fixed
pure-jnp workloads running no repo code — compare's machine-speed
normalization pivots on them, so a serving-path regression cannot
masquerade as "the runner got slower".

The ``serving_mla_seq8`` row is the contrast arm: the same eight requests
served as eight *sequential* single-stream ``generate`` calls (shared
warmed jit entries, so compile time is excluded from both arms).  On the
CPU lane the batched engine must beat it measurably — eight slots advance
per decode step for roughly the cost of one — and this module *raises*
otherwise, which run.py records as a ``serving_FAILED`` row and the
compare gate then rejects.

Measurement: every engine is compiled and warmed with a full run first;
the reported number is the min over measured runs (timeit convention).
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.compat import make_mesh
from repro.configs.base import get_config
from repro.models import model as model_lib, vlm
from repro.serving import batching, engine
from repro.serving.scheduler import Request

ARCHS = (("mla", "deepseek_v2_lite_16b"), ("vlm", "internvl2_26b"))
STREAMS = (1, 4, 8)
CACHE_LEN = 48
BUCKET = 24


def _build(arch_id, mesh):
    arch = dataclasses.replace(get_config(arch_id).reduced(),
                               dtype="float32")
    ctx = model_lib.build_ctx(arch, mesh, seq_len=CACHE_LEN,
                              global_batch=max(STREAMS), aux_mode="none")
    rules = model_lib.default_rules(mesh)
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(jax.random.PRNGKey(0), ctx,
                                       rules=rules)
    return arch, ctx, params


def _requests(arch, n, new_tokens, seed=0):
    """Mixed prompt lengths within one bucket, fixed output budget."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(6, BUCKET - 3))
        fe = (vlm.make_patches(rng, 1, arch)[0]
              if arch.frontend == "vision" else None)
        reqs.append(Request(uid=uid,
                            tokens=rng.integers(0, arch.vocab_size,
                                                size=plen).tolist(),
                            max_new_tokens=new_tokens, frontend=fe))
    return reqs


def _serve(eng, reqs, rounds):
    """Warm (compile) once, then min wall-time over measured runs."""
    eng.run(reqs)
    walls, report = [], None
    for _ in range(rounds):
        report = eng.run(reqs)
        walls.append(report.wall_time)
    return min(walls), report


def _sequential(arch, ctx, params, reqs, new_tokens, rounds, mesh):
    """The contrast arm: one warmed single-stream ``generate`` per
    request, prompts right-padded to the shared bucket so all eight calls
    hit one jit entry (exactly the shapes the batched engine prefills)."""
    fns = engine.make_generate_fns(ctx, CACHE_LEN)
    packs = []
    for req in reqs:
        toks, lens = batching.pad_pack([req.tokens], 1, (BUCKET,))
        fe = (req.frontend[None] if req.frontend is not None else None)
        packs.append((toks, lens, fe))

    def one_round():
        t0 = time.perf_counter()
        for toks, lens, fe in packs:
            engine.generate(params, ctx, toks, steps=new_tokens,
                            cache_len=CACHE_LEN, lens=lens, frontend=fe,
                            fns=fns)
        return time.perf_counter() - t0

    with mesh:
        one_round()                      # compile + warm
        return min(one_round() for _ in range(rounds))


def _anchor_rows(rounds):
    """Fixed pure-jnp decode-shaped workloads (no repo code): a batched
    GEMM chain driven from a host loop, mimicking the decode loop's
    call-overhead profile, plus a plain matmul."""
    a = jax.random.normal(jax.random.PRNGKey(3), (8, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (512, 512), jnp.float32)
    step = jax.jit(lambda x, _w=w: jnp.tanh(x @ _w))
    m = jax.random.normal(jax.random.PRNGKey(5), (640, 640), jnp.float32)
    mm = jax.jit(lambda x: (x @ x) @ x)

    def loop():
        x = a
        for _ in range(16):
            x = step(x)
        return x

    jax.block_until_ready(loop())
    jax.block_until_ready(mm(m))
    rows = []
    for name, fn, iters in (("decode_loop", loop, 4), ("matmul",
                                                       lambda: mm(m), 8)):
        samples = []
        for _ in range(max(rounds, 2) * 4):   # anchors set the gate scale
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            samples.append((time.perf_counter() - t0) / iters * 1e6)
        rows.append((f"serving_anchor_{name}", float(min(samples)),
                     f"backend={jax.default_backend()}"))
    return rows


def run(quick: bool = False):
    new_tokens = 4 if quick else 8
    rounds = 1 if quick else 2
    mesh = make_mesh((1, 1), ("data", "model"))
    backend = jax.default_backend()
    rows = []
    walls = {}
    print(f"# serving sweep: streams={STREAMS} new={new_tokens} "
          f"cache={CACHE_LEN} backend={backend} (min of {rounds} runs)")
    for fam, arch_id in ARCHS:
        arch, ctx, params = _build(arch_id, mesh)
        reqs = _requests(arch, max(STREAMS), new_tokens)
        for s in STREAMS:
            cfg = engine.ServeConfig(num_slots=s, cache_len=CACHE_LEN,
                                     prefill_pack=min(s, 4),
                                     prompt_buckets=(BUCKET,))
            with mesh:
                eng = engine.ServingEngine(params, ctx, cfg)
                wall, report = _serve(eng, reqs[:s], rounds)
            total = report.total_new_tokens
            tps = total / wall
            us = wall / total * 1e6
            walls[(fam, s)] = wall
            rows.append((f"serving_{fam}_s{s}", us,
                         f"streams={s};tok_s={tps:.2f};new={new_tokens};"
                         f"backend={backend}"))
            print(f"  {fam} s={s}: {tps:8.2f} tok/s "
                  f"({us:9.0f} us/token)")
        if fam == "mla":
            seq_wall = _sequential(arch, ctx, params, reqs[:8],
                                   new_tokens, rounds, mesh)
            seq_us = seq_wall / (8 * new_tokens) * 1e6
            rows.append(("serving_mla_seq8", seq_us,
                         f"streams=8;sequential=1;"
                         f"tok_s={8 * new_tokens / seq_wall:.2f};"
                         f"backend={backend}"))
            print(f"  {fam} seq8: {8 * new_tokens / seq_wall:8.2f} tok/s "
                  f"(sequential contrast)")
            batched = walls[("mla", 8)]
            print(f"# batched/sequential 8-stream wall ratio: "
                  f"{batched / seq_wall:.3f}")
            if backend == "cpu" and batched > 0.9 * seq_wall:
                raise RuntimeError(
                    f"8-stream continuous batching not measurably faster "
                    f"than 8 sequential generate calls "
                    f"({batched:.2f}s vs {seq_wall:.2f}s): the slot loop "
                    "is not amortizing decode steps")
    rows.extend(_anchor_rows(rounds))
    return rows
