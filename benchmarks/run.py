"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and (with ``--json``) writes a
machine-readable ``BENCH_dispatch.json`` with the same rows plus run
metadata, so CI can archive the perf trajectory (step times and
chunk-chooser verdicts per dispatch path / topology).  The JSON is
re-written after *every* suite, so a crash mid-sweep never loses the rows
already measured — the failing suite is recorded as a ``<name>_FAILED``
row carrying the exception.  Usage:

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only table1 fig4
    PYTHONPATH=src python -m benchmarks.run --quick    # smaller trainings
    PYTHONPATH=src python -m benchmarks.run --only dispatch overlap \
        --json BENCH_dispatch.json
"""

import argparse
import json
import platform
import time


def _write_json(path, sel, suite_times, quick, rows, complete):
    payload = {
        "schema": "bench_dispatch/v1",
        "suites": sel,
        "suite_seconds": suite_times,
        "quick": bool(quick),
        "complete": bool(complete),   # False while suites are still running
        "platform": platform.platform(),
        "python": platform.python_version(),
        "rows": [{"name": n, "us_per_call": round(us, 2), "derived": d}
                 for n, us, d in rows],
    }
    try:
        import jax
        payload["jax"] = jax.__version__
        payload["device_count"] = jax.device_count()
    except Exception:
        pass
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + metadata as JSON "
                         "(e.g. BENCH_dispatch.json); flushed after every "
                         "suite so partial sweeps survive a crash")
    args = ap.parse_args()

    from benchmarks import (ablation_dispatch, dispatch_sweep,
                            fig3_convergence, fig4_throughput,
                            fig5_fastermoe, fig6_dispatch, fig_overlap,
                            roofline, serving_sweep, table1_comm)

    suites = {
        "table1": lambda: table1_comm.run(),
        "fig4": lambda: fig4_throughput.run(),
        "fig6": lambda: fig6_dispatch.run(),
        "fig3": lambda: fig3_convergence.run(steps=30 if args.quick else 60, experts=(4,) if args.quick else (4, 8)),
        "fig5": lambda: fig5_fastermoe.run(steps=30 if args.quick else 60),
        "roofline": lambda: roofline.run(),
        "ablation": lambda: ablation_dispatch.run(),
        "overlap": lambda: fig_overlap.run(),
        "dispatch": lambda: dispatch_sweep.run(quick=args.quick),
        "serving": lambda: serving_sweep.run(quick=args.quick),
    }
    sel = args.only or list(suites)
    rows = []
    suite_times = {}
    for i, name in enumerate(sel):
        print(f"\n==== {name} ====", flush=True)
        t0 = time.time()
        try:
            rows.extend(suites[name]())
        except Exception as e:  # keep the harness running
            import traceback
            traceback.print_exc(limit=6)
            rows.append((f"{name}_FAILED", 0.0,
                         f"{type(e).__name__}: {e}"[:200]))
        suite_times[name] = round(time.time() - t0, 1)
        print(f"[{name} done in {suite_times[name]}s]", flush=True)
        if args.json:
            # incremental flush: completed rows survive a later crash
            _write_json(args.json, sel, suite_times, args.quick, rows,
                        complete=(i == len(sel) - 1))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")

    if args.json:
        print(f"[wrote {args.json}: {len(rows)} rows]")


if __name__ == "__main__":
    main()
