"""Paper Table 1: even vs uneven dispatch on a [2,2] symmetric tree.

128 MB global exchange; per-pair deliveries costed with the alpha-beta +
link-contention model (core/comm_model.py).  The paper measured ~30%
improvement for the bandwidth-proportional uneven pattern; we reproduce the
effect structurally with GPU-cluster-like constants (NVLink intra ~200 GB/s,
inter-node ~12.5 GB/s)."""

import numpy as np

from repro.core import comm_model as CM
from repro.core import topology as T


def run():
    topo = T.TreeTopology((2, 2))
    model = T.CommModel(topo=topo, alpha=(0.0, 2e-6, 2e-5),
                        beta=(1 / 800e9, 1 / 200e9, 1 / 12.5e9))
    total_bytes = 128e6  # paper: 128 MB upper-bound transfer size
    per_dev = total_bytes / topo.num_devices

    even = CM.dispatch_matrix_from_ratios(model, 1.0, per_dev, mode="even")
    # the paper's demonstration pattern (Table 1): 1/4 self, 1/2 neighbor,
    # 1/8 to each cross-switch device
    lm = topo.level_matrix()
    ratio = np.where(lm == 0, 0.25, np.where(lm == 1, 0.5, 0.125))
    uneven = ratio * per_dev
    # and the Eq. 7 optimum for reference
    c_hat = T.target_dispatch(model, tokens_sent=1.0)
    eq7 = CM.dispatch_matrix_from_ratios(model, 1.0, per_dev, mode="ta",
                                         c_hat=c_hat)

    t_even = CM.simulate_exchange(model, even)
    t_ta = CM.simulate_exchange(model, uneven)
    t_eq7 = CM.simulate_exchange(model, eq7)

    rows = []
    print("# Table 1 reproduction: [2,2] tree, 128MB exchange")
    print(f"{'pair':14s} {'even ratio':>10s} {'ta ratio':>10s} "
          f"{'even us':>10s} {'ta us':>10s}")
    for j, label in [(0, "0<->0"), (1, "0<->1"), (2, "0<->0^"), (3, "0<->1^")]:
        te = model.p2p_time(0, j, even[0, j]) * 1e6
        tt = model.p2p_time(0, j, uneven[0, j]) * 1e6
        print(f"{label:14s} {even[0, j]/per_dev:10.3f} "
              f"{uneven[0, j]/per_dev:10.3f} {te:10.1f} {tt:10.1f}")
    sp_cont = t_even.contention / t_ta.contention
    sp_lb = t_even.lower_bound / max(t_ta.lower_bound, 1e-12)
    sp_eq7 = t_even.contention / t_eq7.contention
    # level-indexed traffic: bytes crossing each topology level (level 1 =
    # intra-switch, level 2 = inter-switch) — the schema the dispatch
    # engine's frac_by_level metric mirrors at runtime
    for label, t in (("even", t_even), ("uneven", t_ta), ("eq7", t_eq7)):
        by_level = " ".join(f"L{lvl}={b/1e6:.1f}MB"
                            for lvl, b in sorted(t.per_level_bytes.items()))
        print(f"bytes by level [{label:6s}]: {by_level}")
    print(f"total (contention): even {t_even.contention*1e6:.0f}us  "
          f"uneven {t_ta.contention*1e6:.0f}us  speedup {sp_cont:.2f}x  "
          f"(paper ~1.3x)")
    print(f"Eq.7 optimum      : {t_eq7.contention*1e6:.0f}us  "
          f"speedup {sp_eq7:.2f}x (exploits self-locality fully)")
    rows.append(("table1_even_exchange", t_even.contention * 1e6,
                 f"lower_bound_us={t_even.lower_bound*1e6:.1f}"))
    rows.append(("table1_uneven_exchange", t_ta.contention * 1e6,
                 f"speedup={sp_cont:.2f}x;lb_speedup={sp_lb:.2f}x"))
    rows.append(("table1_eq7_exchange", t_eq7.contention * 1e6,
                 f"speedup={sp_eq7:.2f}x"))
    return rows
