"""Paper Fig. 6 + Fig. 7: (a) communication/computation breakdown and
(b) the dispatch distribution ("ladder") induced by the topology loss.

(b) is REAL: a gate is trained with l_topo on a simulated 2-pod topology's
penalties; the learned per-level dispatch fractions shift toward near
experts exactly as in the paper's rank 0-7 plots, while the load across
experts *within* a level stays balanced (constraint Eq. 4)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gating, topology
from benchmarks.fig4_throughput import _cluster, _t_a2a, TOKENS_PER_GPU
from repro.configs.base import get_config


def _train_gate(penalties, levels, N=8, d=32, steps=300, lr=0.3, seed=0):
    cfg = gating.GateConfig(num_experts=N, top_k=2, aux_mode="ta",
                            penalty_by_level=penalties)
    params = gating.init_gate_params(jax.random.PRNGKey(seed), d, cfg)

    @jax.jit
    def step(p, key):
        x = jax.random.normal(key, (256, d))

        def loss(pp):
            out = gating.gate_forward(pp, x, cfg, levels)
            return gating.aux_loss(out, cfg, levels)
        l, g = jax.value_and_grad(loss)(p)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    key = jax.random.PRNGKey(seed + 1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        params, l = step(params, sub)
    xe = jax.random.normal(jax.random.PRNGKey(99), (4096, d))
    out = gating.gate_forward(params, xe, cfg, levels)
    return gating.dispatch_fractions(out["topk_idx"], N)


def run():
    rows = []
    # ---- (b) dispatch distribution: 2 pods x 4 ranks, rank (0,0) ----
    N = 8
    levels = gating.expert_levels(N, 1, 4, 2, jnp.int32(0), jnp.int32(0))
    tm = topology.tpu_topology(2, 4)
    ratios = topology.per_level_ratios(tm)
    sizes = tuple(int(s) for s in tm.topo.level_sizes(0))
    pen = gating.ta_penalties(tuple(ratios), level_sizes=sizes)

    t0 = time.time()
    f_ta = np.asarray(_train_gate(pen, levels, N=N))
    f_lb = np.asarray(_train_gate((1.0, 1.0, 1.0), levels, N=N))
    dt = time.time() - t0
    lv = np.asarray(levels)
    near_ta = float(f_ta[lv <= 1].sum())
    near_lb = float(f_lb[lv <= 1].sum())
    # balance within levels (Eq. 4 retained in spirit)
    cv_near = float(np.std(f_ta[lv <= 1]) / (np.mean(f_ta[lv <= 1]) + 1e-9))
    print("# Fig6b/Fig7: learned dispatch fractions (rank (pod0,data0))")
    print(f"  levels : {lv.tolist()}")
    print(f"  lb     : {np.round(f_lb, 3).tolist()}  near={near_lb:.3f}")
    print(f"  ta     : {np.round(f_ta, 3).tolist()}  near={near_ta:.3f}")
    print(f"  ladder: near fraction {near_lb:.2f} -> {near_ta:.2f} "
          f"(ta penalties {tuple(round(p, 2) for p in pen)})")
    rows.append(("fig6b_dispatch_shift", dt * 1e6 / 600,
                 f"near_lb={near_lb:.3f};near_ta={near_ta:.3f};"
                 f"cv_within_near={cv_near:.3f}"))

    # ---- (a) comm/computation breakdown across expert counts ----
    arch = get_config("gpt3_medium_moe")
    d = arch.d_model
    n_moe = arch.num_layers // arch.moe.moe_period
    print("# Fig6a: comm vs compute breakdown on cluster C")
    print(f"{'E':>4s}{'t_comp ms':>11s}{'a2a even ms':>13s}"
          f"{'a2a ta ms':>11s}{'comm speedup':>14s}")
    for E in (8, 16, 32, 64):
        model = _cluster("C", E)
        act = arch.num_layers * 4 * d * d + n_moe * 2 * 3 * d * 2048
        t_comp = 6 * act * TOKENS_PER_GPU / 120e12
        bytes_rank = TOKENS_PER_GPU * arch.moe.top_k * d * 2
        te = n_moe * 2 * _t_a2a(model, "even", bytes_rank)
        tt = n_moe * 2 * _t_a2a(model, "ta", bytes_rank)
        print(f"{E:4d}{t_comp*1e3:11.1f}{te*1e3:13.1f}{tt*1e3:11.1f}"
              f"{te/tt:14.2f}")
        rows.append((f"fig6a_E{E}", te * 1e6,
                     f"comm_speedup={te/tt:.2f};compute_ms="
                     f"{t_comp*1e3:.1f}"))
    return rows
