"""Paper Fig. 5: time-to-convergence, TA-MoE vs a FasterMoE-Hir-style
compulsory dispatch.

Loss-vs-steps curves come from REAL CPU training of the reduced paper
model; wall time per step comes from the fig4 step-time model on cluster C
(the paper's representative cluster).  Hir trains faster per step (it is
even *more* aggressive about slow links) but its gate bias damages the
loss — TA reaches the target loss sooner, matching the paper's 1.25-1.54x.
"""


from repro.compat import make_mesh
from repro.configs.base import RunConfig, get_config
from repro.training import trainer
from benchmarks.fig4_throughput import _cluster, _t_a2a, TOKENS_PER_GPU


def _sim_step_time(mode: str, E=32):
    arch = get_config("gpt3_medium_moe")
    model = _cluster("C", E)
    d = arch.d_model
    n_moe = arch.num_layers // arch.moe.moe_period
    act = arch.num_layers * 4 * d * d + n_moe * 2 * 3 * d * 2048
    t_comp = 6 * act * TOKENS_PER_GPU / 120e12
    bytes_rank = TOKENS_PER_GPU * arch.moe.top_k * d * 2
    t_a2a = _t_a2a(model, "even" if mode == "lb" else mode, bytes_rank)
    return t_comp + n_moe * 2 * t_a2a


def run(steps=60):
    mesh = make_mesh((1, 1), ("data", "model"))
    arch = get_config("gpt3_medium_moe").reduced()
    run_cfg = RunConfig(seq_len=32, global_batch=8, learning_rate=1e-3,
                        total_steps=steps, warmup_steps=5)
    rows = []
    curves, stept = {}, {}
    for mode in ("ta", "hir"):
        res = trainer.train(arch, run_cfg, mesh, steps=steps, aux_mode=mode,
                            log_every=1, verbose=False, data_seed=0)
        curves[mode] = [m["nll"] for m in res.metrics_history]
        stept[mode] = _sim_step_time(mode)
    print(f"# Fig5: simulated step time ta={stept['ta']*1e3:.1f}ms "
          f"hir={stept['hir']*1e3:.1f}ms")
    lo = max(min(curves["ta"]), min(curves["hir"]))
    hi = min(curves["ta"][0], curves["hir"][0])
    targets = [hi - (hi - lo) * f for f in (0.5, 0.75, 0.9)]
    for tgt in targets:
        tt = {}
        for mode in ("ta", "hir"):
            idx = next((i for i, l in enumerate(curves[mode]) if l <= tgt),
                       None)
            tt[mode] = None if idx is None else idx * stept[mode]
        if tt["ta"] and tt["hir"]:
            sp = tt["hir"] / tt["ta"]
            print(f"  loss<={tgt:.3f}: ta {tt['ta']:.1f}s "
                  f"hir {tt['hir']:.1f}s speedup {sp:.2f}x")
            rows.append((f"fig5_target{tgt:.3f}", tt["ta"] * 1e6,
                         f"ta_vs_hir_speedup={sp:.2f}x"))
    if not rows:
        rows.append(("fig5_no_crossing", 0.0,
                     f"ta_final={curves['ta'][-1]:.3f};"
                     f"hir_final={curves['hir'][-1]:.3f}"))
    print(f"  final nll: ta={curves['ta'][-1]:.4f} "
          f"hir={curves['hir'][-1]:.4f}")
    return rows
