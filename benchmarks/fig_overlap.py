import os
if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
# ^ MUST run before any jax import: the sweeps build 8-device meshes (2x4
# pod x data and 2x2x2 pod x node x data) out of forced host devices.  When
# imported through benchmarks.run the sweep re-launches itself in a
# subprocess instead (jax may already be initialized with one device there).

"""Pipelined-dispatch overlap sweep (comm–compute overlap ablation).

For num_chunks in {1, 2, 4} on an 8-host-device mesh — both the 2-tier
2x4 (pod x data) and the 3-tier 2x2x2 (pod x node x data) hierarchy —
measure the wall-clock of one MoE layer step under ``a2a`` (sync baseline)
and ``a2a_pipelined`` through the dispatch-engine registry, and report the
alpha-beta model's simulated sync / pipelined exchange-step times for the
same level-indexed plan.  Host-device collectives are memcpys, so the
*measured* columns are a schedule-correctness and overhead check, while
the *simulated* columns show the predicted overlap on the target
interconnect (ICI/DCN/DCI ladder in core/topology.py).

Usage:
    PYTHONPATH=src python -m benchmarks.fig_overlap
"""

import subprocess
import sys
import time

CHUNKS = (1, 2, 4)


def _measure(fn, *args):
    jfn = __import__("jax").jit(fn)
    import jax
    out = jax.block_until_ready(jfn(*args))
    t0 = time.time()
    iters = 10
    for _ in range(iters):
        out = jax.block_until_ready(jfn(*args))
    return (time.time() - t0) / iters


def sweep(axis_sizes, T=256, D=64, F=128, N=16, K=2):
    """One overlap sweep on an EP hierarchy of ``axis_sizes`` devices."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core import capacity, comm_model, dispatch as dl, gating
    from repro.core.capacity import default_axis_names

    names = default_axis_names(len(axis_sizes))
    topo_tag = "x".join(str(s) for s in axis_sizes)
    suffix = "" if len(axis_sizes) == 2 else f"@{len(axis_sizes)}tier"
    mesh = make_mesh(axis_sizes, names)
    cfg = dl.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                       capacity_factor=2.0, dtype=jnp.float32)
    ep = dl.EPSpec.from_axes(names, axis_sizes)
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="ta")
    params = dl.init_moe_params(jax.random.PRNGKey(0), cfg, ep, gate_cfg)
    base_plan = capacity.make_dispatch_plan(
        tokens_per_device=T, num_experts=N, top_k=K, capacity_factor=2.0,
        axis_sizes=axis_sizes, axis_names=names, mode="ta")
    x = jax.random.normal(jax.random.PRNGKey(1), (ep.ep_world * T, D),
                          jnp.float32)
    pspec = dl.moe_param_specs(cfg, ep)
    pspec["gate"] = {"w": P()}

    def wrap(name, plan, num_chunks=1):
        eng = dl.make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                             plan=plan, num_chunks=num_chunks)
        return shard_map(lambda p, xx: eng(p, xx)[0], mesh=mesh,
                         in_specs=(pspec, P(names, None)),
                         out_specs=P(names, None), check_vma=False)

    rows = []
    caps = "/".join(str(c) for c in base_plan.caps)
    print(f"# overlap sweep: {topo_tag} host mesh ({'x'.join(names)}), "
          f"T/rank={T}, N={N}, top-{K}, caps by level={caps}")
    print(f"{'schedule':18s}{'chunks':>7s}{'meas ms':>9s}{'sim sync ms':>12s}"
          f"{'sim pipe ms':>12s}{'sim speedup':>12s}")

    with mesh:
        t_sync = _measure(wrap("a2a", base_plan), params, x)
    terms = comm_model.moe_overlap_terms(base_plan, d_model=D, d_ff=F,
                                         bytes_per_el=4)
    est1 = comm_model.estimate_overlap(num_chunks=1, **terms)
    print(f"{'a2a (sync)':18s}{'-':>7s}{t_sync*1e3:9.2f}"
          f"{est1.t_sync*1e3:12.4f}{'-':>12s}{'-':>12s}")
    rows.append((f"fig_overlap_sync{suffix}", t_sync * 1e6,
                 f"sim_ms={est1.t_sync*1e3:.4f};topology={topo_tag}"))

    for k in CHUNKS:
        plan = capacity.align_to_chunks(base_plan, k)
        with mesh:
            t = _measure(wrap("a2a_pipelined", plan, k), params, x)
        est = comm_model.estimate_overlap(num_chunks=k, **terms)
        print(f"{'a2a_pipelined':18s}{k:>7d}{t*1e3:9.2f}"
              f"{est.t_sync*1e3:12.4f}{est.t_pipelined*1e3:12.4f}"
              f"{est.speedup:12.2f}")
        rows.append((f"fig_overlap_pipelined_c{k}{suffix}", t * 1e6,
                     f"sim_pipe_ms={est.t_pipelined*1e3:.4f};"
                     f"sim_speedup={est.speedup:.2f};topology={topo_tag}"))
    auto = comm_model.choose_num_chunks(**terms)
    print(f"# comm-model pick (topology constants): num_chunks={auto}")
    rows.append((f"fig_overlap_auto_chunks{suffix}", float(auto),
                 f"model choice;topology={topo_tag}"))

    # quantized wire: rerun the chunk chooser on int8-codec byte counts
    # (1-byte payload + f32 scale sideband) — the codec swap must be
    # visible in the chooser's inputs, and often in its verdict
    qterms = comm_model.moe_overlap_terms(base_plan, d_model=D, d_ff=F,
                                          bytes_per_el=4, codec="int8")
    q_auto = comm_model.choose_num_chunks(**qterms)
    print(f"# comm-model pick (int8 wire codec): num_chunks={q_auto} "
          f"(t_exchange {terms['t_exchange']*1e6:.2f}us -> "
          f"{qterms['t_exchange']*1e6:.2f}us)")
    rows.append((f"fig_overlap_auto_chunks_int8{suffix}", float(q_auto),
                 f"t_exchange_us={qterms['t_exchange']*1e6:.2f};"
                 f"topology={topo_tag}"))

    # measured alpha/beta: micro-benchmark every mesh axis and rerun the
    # chunk chooser on the fitted terms (level-indexed links)
    links = comm_model.measured_ep_links(mesh, ep.axis_names)
    mterms = comm_model.moe_overlap_terms(base_plan, d_model=D, d_ff=F,
                                          bytes_per_el=4, links=links)
    m_auto = comm_model.choose_num_chunks(**mterms)
    for ax in ep.axis_names:
        li = links.get(ax)
        if li is not None:
            print(f"# measured axis {ax!r}: alpha={li.alpha*1e6:.1f}us "
                  f"beta={li.beta*1e9:.3f}ns/B")
    for r in comm_model.stage_overlap_terms(base_plan, d_model=D,
                                            bytes_per_el=4, links=links):
        print(f"# stage {r['stage']}: {r['bytes']/1e3:.1f}kB  "
              f"t_exchange={r['t_exchange']*1e6:.2f}us")
    print(f"# comm-model pick (measured alpha/beta): num_chunks={m_auto}")
    rows.append((f"fig_overlap_auto_chunks_measured{suffix}", float(m_auto),
                 f"alpha_us={mterms['alpha']*1e6:.2f};topology={topo_tag}"))
    return rows


def main():
    import jax
    assert jax.device_count() >= 8, (
        "needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    rows = sweep((2, 4))          # 2-tier: pod x data
    rows += sweep((2, 2, 2))      # 3-tier: pod x node x data
    for name, us, derived in rows:
        print(f"CSV {name},{us:.2f},{derived}")
    return rows


def run():
    """benchmarks.run entry: re-exec in a subprocess so the forced 8-device
    host platform is set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-m", "benchmarks.fig_overlap"],
                       capture_output=True, text=True, timeout=1800, env=env)
    print(r.stdout, end="")
    if r.returncode != 0:
        raise RuntimeError(f"fig_overlap subprocess failed:\n{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            name, us, derived = line[4:].split(",", 2)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    main()
