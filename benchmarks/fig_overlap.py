import os
if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
# ^ MUST run before any jax import: the sweep builds a 2x4 pod x data mesh
# out of forced host devices.  When imported through benchmarks.run the
# sweep re-launches itself in a subprocess instead (jax may already be
# initialized with one device there).

"""Pipelined-dispatch overlap sweep (comm–compute overlap ablation).

For num_chunks in {1, 2, 4} on an 8-host-device (2 pods x 4) mesh, measure
the wall-clock of one MoE layer step under ``a2a`` (sync baseline) and
``a2a_pipelined``, and report the alpha-beta model's simulated sync /
pipelined exchange-step times for the same plan.  Host-device collectives
are memcpys, so the *measured* columns are a schedule-correctness and
overhead check, while the *simulated* columns show the predicted overlap on
the target interconnect (ICI/DCI constants in core/topology.py).

Usage:
    PYTHONPATH=src python -m benchmarks.fig_overlap
"""

import subprocess
import sys
import time

CHUNKS = (1, 2, 4)


def _measure(fn, *args):
    jfn = __import__("jax").jit(fn)
    import jax
    out = jax.block_until_ready(jfn(*args))
    t0 = time.time()
    iters = 10
    for _ in range(iters):
        out = jax.block_until_ready(jfn(*args))
    return (time.time() - t0) / iters


def main(T=256, D=64, F=128, N=16, K=2):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core import capacity, comm_model, gating, moe as moe_lib

    assert jax.device_count() >= 8, (
        "needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    mesh = make_mesh((2, 4), ("pod", "data"))
    cfg = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                            capacity_factor=2.0, dtype=jnp.float32)
    ep = moe_lib.EPSpec(num_pods=2, ep_per_pod=4, pod_axis="pod",
                        data_axis="data", model_axis=None)
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="ta")
    params = moe_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                     gate_cfg)
    base_plan = capacity.make_plan(
        tokens_per_device=T, num_experts=N, top_k=K, capacity_factor=2.0,
        num_pods=2, ep_per_pod=4, mode="ta")
    x = jax.random.normal(jax.random.PRNGKey(1), (8 * T, D), jnp.float32)
    pspec = moe_lib.moe_param_specs(cfg, ep)
    pspec["gate"] = {"w": P()}

    def wrap(body):
        return shard_map(body, mesh=mesh,
                         in_specs=(pspec, P(("pod", "data"), None)),
                         out_specs=P(("pod", "data"), None),
                         check_vma=False)

    rows = []
    print(f"# overlap sweep: 2x4 host mesh, T/rank={T}, N={N}, top-{K}, "
          f"cap near/far={base_plan.cap_near}/{base_plan.cap_far}")
    print(f"{'schedule':18s}{'chunks':>7s}{'meas ms':>9s}{'sim sync ms':>12s}"
          f"{'sim pipe ms':>12s}{'sim speedup':>12s}")

    with mesh:
        t_sync = _measure(wrap(
            lambda p, xx: moe_lib.moe_apply_a2a(
                p, xx, cfg, ep, base_plan, gate_cfg)[0]), params, x)
    terms = comm_model.moe_overlap_terms(
        base_plan, d_model=D, d_ff=F, bytes_per_el=4,
        num_pods=2, ep_per_pod=4)
    est1 = comm_model.estimate_overlap(num_chunks=1, **terms)
    print(f"{'a2a (sync)':18s}{'-':>7s}{t_sync*1e3:9.2f}"
          f"{est1.t_sync*1e3:12.4f}{'-':>12s}{'-':>12s}")
    rows.append(("fig_overlap_sync", t_sync * 1e6,
                 f"sim_ms={est1.t_sync*1e3:.4f}"))

    for k in CHUNKS:
        plan = capacity.align_to_chunks(base_plan, k)
        with mesh:
            t = _measure(wrap(
                lambda p, xx, pl=plan, kk=k: moe_lib.moe_apply_a2a_pipelined(
                    p, xx, cfg, ep, pl, gate_cfg, num_chunks=kk)[0]),
                params, x)
        est = comm_model.estimate_overlap(num_chunks=k, **terms)
        print(f"{'a2a_pipelined':18s}{k:>7d}{t*1e3:9.2f}"
              f"{est.t_sync*1e3:12.4f}{est.t_pipelined*1e3:12.4f}"
              f"{est.speedup:12.2f}")
        rows.append((f"fig_overlap_pipelined_c{k}", t * 1e6,
                     f"sim_pipe_ms={est.t_pipelined*1e3:.4f};"
                     f"sim_speedup={est.speedup:.2f}"))
    auto = comm_model.choose_num_chunks(**terms)
    print(f"# comm-model pick (topology constants): num_chunks={auto}")
    rows.append(("fig_overlap_auto_chunks", float(auto), "model choice"))

    # measured alpha/beta: micro-benchmark the actual mesh links and rerun
    # the chunk chooser on the fitted terms (ROADMAP: profiled overlap model)
    links = comm_model.measured_moe_links(mesh, data_axis="data",
                                          pod_axis="pod")
    mterms = comm_model.moe_overlap_terms(
        base_plan, d_model=D, d_ff=F, bytes_per_el=4,
        num_pods=2, ep_per_pod=4, links=links)
    m_auto = comm_model.choose_num_chunks(**mterms)
    for lvl in ("near", "far"):
        li = links[lvl]
        if li is not None:
            print(f"# measured {lvl}: alpha={li.alpha*1e6:.1f}us "
                  f"beta={li.beta*1e9:.3f}ns/B")
    print(f"# comm-model pick (measured alpha/beta): num_chunks={m_auto}")
    rows.append(("fig_overlap_auto_chunks_measured", float(m_auto),
                 f"alpha_us={mterms['alpha']*1e6:.2f}"))
    for name, us, derived in rows:
        print(f"CSV {name},{us:.2f},{derived}")
    return rows


def run():
    """benchmarks.run entry: re-exec in a subprocess so the forced 8-device
    host platform is set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-m", "benchmarks.fig_overlap"],
                       capture_output=True, text=True, timeout=1800, env=env)
    print(r.stdout, end="")
    if r.returncode != 0:
        raise RuntimeError(f"fig_overlap subprocess failed:\n{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            name, us, derived = line[4:].split(",", 2)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    main()
