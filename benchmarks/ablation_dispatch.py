"""Ablation: GShard/DeepSpeed einsum dispatch vs the selection-based a2a
dispatch (paper §2: the einsum formulation "introduced redundant zero
computation and extra memory consumption").

Measured from compiled HLO on one device: FLOPs and bytes of a single MoE
layer under both formulations, plus wall-clock on CPU."""

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map

from repro.core import dispatch as dl, gating
from repro.core.capacity import make_plan


def _layer_stats(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    # wall clock (CPU, small sizes — relative only)
    out = jax.block_until_ready(jax.jit(fn)(*args))
    t0 = time.time()
    for _ in range(5):
        out = jax.block_until_ready(jax.jit(fn)(*args))
    dt = (time.time() - t0) / 5
    return float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0)), dt


def run(T=512, D=128, F=256, N=16, K=2):
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dl.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                            capacity_factor=1.25, dtype=jnp.float32)
    ep = dl.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                        data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dl.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                     gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=1.25, num_pods=1, ep_per_pod=1,
                     mode="even")
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)

    def wrap(body):
        return shard_map(body, mesh=mesh, in_specs=(P(), P()),
                         out_specs=P(), check_vma=False)

    def f_sel(p, xx):
        return dl.dispatch_moe("a2a", p, xx, cfg=cfg, ep=ep,
                               gate_cfg=gate_cfg, plan=plan)[0]

    def f_ein(p, xx):
        cap = max(1, int(T * K * cfg.capacity_factor / N))
        return dl.dispatch_moe("einsum", p, xx, cfg=cfg, ep=ep,
                               gate_cfg=gate_cfg, capacity=cap)[0]

    rows = []
    with mesh:
        fs, bs, ts = _layer_stats(wrap(f_sel), params, x)
        fe, be, te = _layer_stats(wrap(f_ein), params, x)
    print(f"# dispatch ablation (T={T}, N={N}, top-{K}, cf=1.25, 1 device)")
    print(f"{'path':10s}{'GFLOPs':>10s}{'MB accessed':>13s}{'ms/call':>9s}")
    print(f"{'select+a2a':10s}{fs/1e9:10.3f}{bs/1e6:13.1f}{ts*1e3:9.1f}")
    print(f"{'einsum':10s}{fe/1e9:10.3f}{be/1e6:13.1f}{te*1e3:9.1f}")
    print(f"einsum overhead: {fe/max(fs,1):.2f}x flops, "
          f"{be/max(bs,1):.2f}x bytes  (paper §2's 'redundant zero "
          f"computation')")
    rows.append(("ablation_dispatch_select", ts * 1e6,
                 f"gflops={fs/1e9:.3f};mb={bs/1e6:.1f}"))
    rows.append(("ablation_dispatch_einsum", te * 1e6,
                 f"gflops={fe/1e9:.3f};mb={be/1e6:.1f};"
                 f"flops_overhead={fe/max(fs,1):.2f}x"))
    return rows
