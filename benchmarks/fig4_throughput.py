"""Paper Fig. 4: throughput (tokens/s) and speedup of TA-MoE over the
even-dispatch baselines (DeepSpeed-MoE / FastMoE style) across expert
counts and cluster topologies.

Analytical step-time model calibrated with the alpha-beta contention
simulator (no GPUs in this container):

    t_step = t_compute + n_moe_layers * 2 * t_a2a(dispatch) + t_gradsync

The three clusters of paper Table 2 are modelled: A (8xA100 NVSwitch
nodes, fast RoCE), B (8xV100, same-switch), C (8xV100, multi-switch with a
contended slow tier).  TA changes only t_a2a via the dispatch matrix."""

import numpy as np

from repro.configs.base import get_config
from repro.core import comm_model as CM
from repro.core import topology as T

GPU_FLOPS_EFF = 120e12          # A100-class effective bf16 FLOP/s
TOKENS_PER_GPU = 6 * 1024       # paper batch 6, seq 1024


def _cluster(name: str, n_gpus: int):
    nodes = max(n_gpus // 8, 1)
    if name == "A":      # NVSwitch + 100Gb/s RoCE/4 (fast-ish inter)
        spec = tuple([8] * nodes) if nodes > 1 else 8
        beta = (1 / 800e9, 1 / 300e9, 1 / 25e9)
        alpha = (0.0, 2e-6, 1e-5)
    elif name == "B":    # NVLink + same-switch RoCE/8
        spec = tuple([8] * nodes) if nodes > 1 else 8
        beta = (1 / 800e9, 1 / 150e9, 1 / 12.5e9)
        alpha = (0.0, 3e-6, 1.5e-5)
    else:                # C: cross-switch, contended slow tier
        half = max(nodes // 2, 1)
        if nodes > 1:
            spec = (tuple([8] * half), tuple([8] * (nodes - half))) \
                if nodes - half > 0 else tuple([8] * half)
        else:
            spec = 8
        beta = (1 / 800e9, 1 / 150e9, 1 / 12.5e9, 1 / 4e9)
        alpha = (0.0, 3e-6, 1.5e-5, 5e-5)
    topo = T.TreeTopology(spec)
    L = topo.num_levels
    return T.CommModel(topo=topo, alpha=alpha[:L], beta=beta[:L])


def _t_a2a(model, mode: str, bytes_per_rank: float):
    P = model.topo.num_devices
    if mode == "even":
        c = CM.dispatch_matrix_from_ratios(model, 1.0, bytes_per_rank,
                                           mode="even")
    elif mode == "ta":
        c_hat = T.target_dispatch(model, tokens_sent=1.0)
        c = CM.dispatch_matrix_from_ratios(model, 1.0, bytes_per_rank,
                                           mode="ta", c_hat=c_hat)
    else:  # hir: compulsory 4:1 intra:inter, renormalized
        lm = model.topo.level_matrix()
        w = np.where(lm <= 1, 4.0, 1.0)
        w = w / w.sum(1, keepdims=True)
        c = w * bytes_per_rank
    return CM.simulate_exchange(model, c).contention


def run(expert_counts=(8, 16, 32, 64)):
    arch = get_config("gpt3_medium_moe")
    d, ff = arch.d_model, arch.moe.d_ff_expert
    n_moe = arch.num_layers // arch.moe.moe_period
    rows = []
    print("# Fig4: simulated throughput (tokens/s) and TA speedup")
    print(f"{'cluster':8s}{'E':>4s}{'even tok/s':>14s}{'ta tok/s':>12s}"
          f"{'speedup':>9s}{'hir tok/s':>12s}")
    for cl in ("A", "B", "C"):
        for E in expert_counts:
            P = E                           # one expert per GPU (paper)
            model = _cluster(cl, P)
            tokens = TOKENS_PER_GPU * P
            # active params per token: attn + top2 experts + embeds share
            act = (arch.num_layers * (4 * d * d)
                   + n_moe * arch.moe.top_k * 3 * d * ff
                   + (arch.num_layers - n_moe) * 3 * d * arch.d_ff)
            t_comp = 6 * act * TOKENS_PER_GPU / GPU_FLOPS_EFF
            bytes_rank = TOKENS_PER_GPU * arch.moe.top_k * d * 2
            grad_bytes = 2 * (act * 3) * 2 / P  # rough ring allreduce term
            t_grad = grad_bytes / 12.5e9
            out = {}
            for mode in ("even", "ta", "hir"):
                t_a2a = _t_a2a(model, mode, bytes_rank)
                t = t_comp + n_moe * 2 * t_a2a + t_grad
                out[mode] = tokens / t
            sp = out["ta"] / out["even"]
            print(f"{cl:8s}{E:4d}{out['even']:14.0f}{out['ta']:12.0f}"
                  f"{sp:9.2f}{out['hir']:12.0f}")
            rows.append((f"fig4_{cl}_E{E}", 1e6 * tokens / out["even"],
                         f"ta_speedup={sp:.3f};hir_vs_even="
                         f"{out['hir']/out['even']:.3f}"))
    return rows
