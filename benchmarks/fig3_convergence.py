"""Paper Fig. 3 + Table 4: validation loss w.r.t. steps — TA-MoE vs the
load-balance baseline must be consistent (TA does not hurt convergence).

Real training on CPU with the reduced paper model; the TA run uses the
*heterogeneous* 2-pod penalty profile (the worst case for accuracy) even
though the mesh is a single host device — the loss sees exactly the same
penalties it would on the production mesh."""

import dataclasses
import time

import jax
import numpy as np

from repro.compat import make_mesh
from repro.configs.base import RunConfig, get_config
from repro.core import gating, topology
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib
from repro.training import trainer


def _val_loss(arch, params, ctx, steps=2, seed=777):
    from repro import sharding
    from repro.models import transformer
    data = SyntheticLM(DataConfig(vocab_size=arch.vocab_size, seq_len=32,
                                  global_batch=8, seed=seed), arch)
    rules = model_lib.default_rules(ctx.mesh)
    tot = 0.0
    with ctx.mesh, sharding.axis_rules(rules):
        f = jax.jit(lambda p, b: transformer.loss_fn(p, b, ctx,
                                                     aux_weight=0.0)[1]["nll"])
        for i in range(steps):
            tot += float(f(params, data.batch(i)))
    return tot / steps


def run(steps=60, experts=(4,)):
    mesh = make_mesh((1, 1), ("data", "model"))
    rows = []
    base = get_config("gpt3_medium_moe").reduced()
    # heterogeneous penalties of the 2-pod production topology
    tm = topology.tpu_topology(2, 16)
    ratios = topology.per_level_ratios(tm)
    sizes = tuple(int(s) for s in tm.topo.level_sizes(0))
    pen = gating.ta_penalties(tuple(ratios), level_sizes=sizes)

    for n_exp in experts:
        arch = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, num_experts=n_exp))
        run_cfg = RunConfig(seq_len=32, global_batch=8, learning_rate=1e-3,
                            total_steps=steps, warmup_steps=5)
        curves = {}
        for mode in ("lb", "ta"):
            t0 = time.time()
            res = trainer.train(arch, run_cfg, mesh, steps=steps,
                                aux_mode=mode, log_every=max(steps // 6, 1),
                                verbose=False, data_seed=0)
            # patch heterogeneous penalties into the TA context for val
            ctx = model_lib.build_ctx(arch, mesh, seq_len=32, global_batch=8,
                                      aux_mode=mode)
            if mode == "ta":
                ctx = dataclasses.replace(
                    ctx, gate_cfg=dataclasses.replace(
                        ctx.gate_cfg, penalty_by_level=pen))
            vl = _val_loss(arch, res.params, ctx)
            curves[mode] = (res.losses, vl, time.time() - t0)
        lb, ta = curves["lb"], curves["ta"]
        gap = abs(ta[1] - lb[1])
        ppl_lb, ppl_ta = float(np.exp(lb[1])), float(np.exp(ta[1]))
        print(f"# Fig3 E={n_exp}: val nll lb={lb[1]:.4f} ta={ta[1]:.4f} "
              f"gap={gap:.4f}  PPL lb={ppl_lb:.2f} ta={ppl_ta:.2f}")
        print(f"  lb curve: {[round(x, 3) for x in lb[0]]}")
        print(f"  ta curve: {[round(x, 3) for x in ta[0]]}")
        rows.append((f"fig3_E{n_exp}_lb", lb[2] / steps * 1e6,
                     f"val_nll={lb[1]:.4f};ppl={ppl_lb:.2f}"))
        rows.append((f"fig3_E{n_exp}_ta", ta[2] / steps * 1e6,
                     f"val_nll={ta[1]:.4f};ppl={ppl_ta:.2f};gap={gap:.4f}"))
    return rows
