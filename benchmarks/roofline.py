"""Roofline table from dry-run records (EXPERIMENTS.md §Roofline source).

Reads the JSONL written by ``python -m repro.launch.dryrun --out ...`` and
prints the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck.  Falls back to a no-op row when no records exist yet."""

import json
import os

RECORDS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.jsonl")


def load(path=RECORDS):
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"], r.get("aux_mode", "ta"))
            recs[key] = r      # keep the latest record per combination
    return list(recs.values())


def run():
    recs = [r for r in load() if r.get("status") == "ok"]
    rows = []
    if not recs:
        print("# roofline: no dry-run records yet "
              "(run: python -m repro.launch.dryrun --all --out "
              "results/dryrun.jsonl)")
        return [("roofline_pending", 0.0, "no_records")]
    print("# Roofline terms (ms) per arch x shape x mesh")
    print(f"{'arch':22s}{'shape':12s}{'mesh':6s}{'t_comp':>9s}{'t_mem':>9s}"
          f"{'t_coll':>9s} {'dominant':10s}{'useful':>7s}")
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        print(f"{r['arch']:22s}{r['shape']:12s}{r['mesh']:6s}"
              f"{r['t_compute']*1e3:9.2f}{r['t_memory']*1e3:9.2f}"
              f"{r['t_collective']*1e3:9.2f} {r['dominant']:10s}"
              f"{r['useful_ratio']:7.3f}")
        rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                     max(r["t_compute"], r["t_memory"],
                         r["t_collective"]) * 1e6,
                     f"dominant={r['dominant']};useful="
                     f"{r['useful_ratio']:.3f}"))
    return rows
