"""Benchmark-regression gate: compare a fresh BENCH_dispatch.json against a
committed baseline and fail (exit 1) on step-time regressions.

    PYTHONPATH=src python -m benchmarks.compare \
        --baseline results/BENCH_baseline.json \
        --current BENCH_dispatch.json --tolerance 0.15

Only rows present in both files are compared; ``*_FAILED`` rows in the
current run fail outright; rows below ``--min-us`` are skipped as jitter;
a baseline with rows but zero comparable ones fails loudly (a renamed
sweep must refresh the baseline, not disarm the gate).

Machines differ in absolute speed, so the gate is two-tier:

1. **Per-row** (``--tolerance``, default ±15%): each row's cur/base ratio
   is divided by the *median* ratio over all comparable rows — the robust
   machine-speed estimate — and compared against the tolerance.  This
   catches a regression in any one path/mode that the others did not
   share.
2. **Uniform** (``--uniform-guard``, default 30%): a slowdown shared by
   every dispatch row shifts the median itself and normalizes away, so it
   is caught through the guard rows — the ``dispatch_anchor_*`` fixed
   pure-jnp workloads (they run **no repo code**) plus the einsum oracle
   row (repo code, but none of the permutation hot path this lane
   guards; its size damps the small anchors' timing noise).  If the guard
   rows' normalized geomean drops below ``1 - uniform_guard``, the whole
   dispatch pack regressed relative to them and the gate fails.  The
   guard is looser than the per-row tolerance because small anchor rows
   carry more relative timing noise.  Pure-anchor rows are *excluded*
   from the per-row tier: no PR can regress code they do not run, so any
   per-row movement there is machine noise by construction.

``--absolute`` skips normalization entirely (same-runner comparisons).
A missing baseline passes with a notice — that is how the trajectory
bootstraps.
"""

import argparse
import json
import math
import os
import statistics
import sys


def load_rows(path):
    """name -> us_per_call for every timed row of one BENCH json."""
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def compare(baseline, current, *, tolerance=0.15, min_us=50.0,
            normalize=True, anchor="dispatch_anchor",
            guard_rows="dispatch_anchor,dispatch_einsum",
            uniform_guard=0.30):
    """Returns (regressions, improvements, skipped, failed_rows,
    uniform_failure).

    regressions / improvements are ``(name, base_us, cur_us, ratio)`` where
    ratio is the (normalized) cur/base factor; ratio > 1 + tolerance is a
    regression.  Rows matching the ``anchor`` prefix run no repo code and
    are excluded from the per-row tier.  ``uniform_failure`` is None or a
    message describing a pack-wide slowdown detected via the
    ``guard_rows`` prefixes.
    """
    failed = [n for n in current if n.endswith("_FAILED")]
    common = sorted(n for n in baseline
                    if n in current and not n.endswith("_FAILED"))
    usable = [n for n in common
              if baseline[n] >= min_us and current[n] >= min_us]
    skipped = [n for n in common if n not in usable]

    scale = 1.0
    if normalize and usable:
        scale = math.exp(statistics.median(
            math.log(current[n] / baseline[n]) for n in usable))

    regressions, improvements = [], []
    for n in usable:
        if anchor and n.startswith(anchor):
            continue   # no repo code on an anchor row: movement == noise
        ratio = current[n] / baseline[n] / scale
        entry = (n, baseline[n], current[n], ratio)
        if ratio > 1.0 + tolerance:
            regressions.append(entry)
        elif ratio < 1.0 - tolerance:
            improvements.append(entry)

    uniform_failure = None
    prefixes = tuple(p for p in (guard_rows or "").split(",") if p)
    guards = [n for n in usable if n.startswith(prefixes)] if prefixes \
        else []
    if normalize and guards:
        log_rel = [math.log(current[n] / baseline[n] / scale)
                   for n in guards]
        guards_rel = math.exp(sum(log_rel) / len(log_rel))
        if guards_rel < 1.0 - uniform_guard:
            uniform_failure = (
                f"guard rows are {1 / guards_rel:.2f}x faster than the "
                f"dispatch pack relative to baseline (> {uniform_guard:.0%} "
                "guard): the dispatch rows regressed uniformly")
    return regressions, improvements, skipped, failed, uniform_failure


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="results/BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_dispatch.json")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="fractional per-row slowdown allowed (0.15 = 15%%)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="rows faster than this are timing jitter; skip")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw us instead of machine-normalized")
    ap.add_argument("--anchor", default="dispatch_anchor",
                    help="row-name prefix of the pure-compute anchor rows "
                         "(excluded from the per-row tier)")
    ap.add_argument("--guard-rows", default="dispatch_anchor,dispatch_einsum",
                    help="comma-separated row-name prefixes forming the "
                         "uniform-regression guard basis ('' disables)")
    ap.add_argument("--uniform-guard", type=float, default=0.30,
                    help="pack-wide slowdown vs the guard rows that fails "
                         "the gate (looser than --tolerance: small anchor "
                         "rows are noisy)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"[compare] no baseline at {args.baseline}; nothing to "
              "compare against (bootstrap run) -> pass")
        return 0
    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    regs, imps, skipped, failed, uniform = compare(
        base, cur, tolerance=args.tolerance, min_us=args.min_us,
        normalize=not args.absolute, anchor=args.anchor,
        guard_rows=args.guard_rows, uniform_guard=args.uniform_guard)

    mode = "absolute" if args.absolute else "normalized"
    n_usable = len([n for n in base
                    if n in cur and not n.endswith("_FAILED")
                    and base[n] >= args.min_us and cur[n] >= args.min_us])
    print(f"[compare] {len(base)} baseline rows, {len(cur)} current rows, "
          f"{mode} tolerance ±{args.tolerance:.0%}, "
          f"{len(skipped)} skipped (< {args.min_us:.0f}us or one-sided)")
    if base and n_usable == 0:
        # a renamed sweep or an empty current run must not disarm the gate
        print("[compare] FAIL: baseline has rows but ZERO are comparable — "
              "row names changed or the current run is empty; refresh "
              "results/BENCH_baseline.json alongside the sweep change")
        return 1
    for name, b, c, r in sorted(imps, key=lambda e: e[3]):
        print(f"  IMPROVED  {name}: {b:.1f}us -> {c:.1f}us "
              f"({(r - 1) * 100:+.1f}% rel)")
    for name, b, c, r in sorted(regs, key=lambda e: -e[3]):
        print(f"  REGRESSED {name}: {b:.1f}us -> {c:.1f}us "
              f"({(r - 1) * 100:+.1f}% rel)")
    for name in failed:
        print(f"  FAILED    {name}: suite raised in the current run")
    if uniform:
        print(f"  UNIFORM   {uniform}")
    if not regs and not failed and not uniform:
        print("[compare] OK: no step-time regressions")
        return 0
    print(f"[compare] FAIL: {len(regs)} regression(s), "
          f"{len(failed)} failed suite row(s)"
          + (", uniform pack regression" if uniform else ""))
    return 1


if __name__ == "__main__":
    sys.exit(main())
