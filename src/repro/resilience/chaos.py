"""Deterministic, seeded fault injection for resilience testing.

Every fault family the runtime claims to survive is a reproducible
scenario here, not a prayer: faults fire at explicit step indices, byte
corruption is seeded, and link degradation is a pure function of
``(config, step)`` — so a chaos run is exactly as replayable as a clean
one.

Fault families and where they land:

* non-finite grads / activations — ``fault_scales`` produces per-step
  ``loss_mult`` / ``grad_mult`` scalars the guarded train step multiplies
  in (a traced argument, so no recompilation per step).  ``loss_mult``
  poisons the *differentiated* total upstream of backprop (an
  activation-level fault: every grad goes non-finite); ``grad_mult``
  poisons or scales the grads directly.
* degraded links — ``link_multipliers`` yields per-mesh-axis beta
  multipliers applied on top of ``comm_model.measured_ep_links`` (via
  ``comm_model.scale_links``); a degradation persists from its step on.
* stragglers — ``maybe_straggle`` injects a host-side delay before the
  step, modelling a slow rank on the pipelined path.
* checkpoint corruption — ``corrupt_checkpoint`` flips seeded bytes in a
  saved payload so the sha256 manifest check fails.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """One reproducible fault schedule.  All step fields are tuples of
    global step indices; an empty tuple disables that family."""

    seed: int = 0
    # non-finite grad fault: grads multiplied by nan at these steps
    nan_grad_steps: tuple = ()
    # non-finite activation fault: the differentiated loss multiplied by
    # nan (backprop poisons every grad)
    nan_loss_steps: tuple = ()
    # loss-spike fault: the updated params scaled by `spike_scale` at
    # these steps (a sick-rank / divergence model — the *subsequent*
    # losses spike because the params got wrecked; injecting into grads
    # would be silently neutralized by global-norm clipping, and mild
    # scales are absorbed by RMSNorm's scale invariance — 10x is enough
    # to saturate attention scores and the unembed logits)
    spike_steps: tuple = ()
    spike_scale: float = 10.0
    # degraded links: (step, axis_name, beta_multiplier) triples; the
    # multiplier applies to every link observation from `step` onward
    degraded_links: tuple = ()
    # stragglers: host-side delay injected before these steps
    straggler_steps: tuple = ()
    straggler_delay_s: float = 0.02
    # checkpoint corruption: rolling checkpoints saved at these steps get
    # seeded byte flips right after the save
    corrupt_ckpt_steps: tuple = ()

    @property
    def any_step_faults(self) -> bool:
        return bool(self.nan_grad_steps or self.nan_loss_steps
                    or self.spike_steps)


def fault_scales(cfg: ChaosConfig | None, step: int) -> dict:
    """Per-step ``{"loss_mult", "grad_mult", "param_scale"}`` floats
    (all 1.0 when no fault fires — the healthy fast path; multiplying by
    exactly 1.0 is bitwise-exact).  The two mults feed the guarded train
    step as traced args; ``param_scale`` is applied by the host loop
    between steps so the healthy path never pays for it."""
    loss_mult, grad_mult, param_scale = 1.0, 1.0, 1.0
    if cfg is not None:
        if step in cfg.nan_loss_steps:
            loss_mult = float("nan")
        if step in cfg.nan_grad_steps:
            grad_mult = float("nan")
        if step in cfg.spike_steps:
            param_scale = cfg.spike_scale
    return {"loss_mult": loss_mult, "grad_mult": grad_mult,
            "param_scale": param_scale}


def link_multipliers(cfg: ChaosConfig | None, step: int) -> dict:
    """Accumulated per-axis beta multipliers active at ``step`` (every
    ``degraded_links`` entry whose step has passed compounds in)."""
    mults: dict = {}
    if cfg is not None:
        for at, axis, mult in cfg.degraded_links:
            if step >= at:
                mults[axis] = mults.get(axis, 1.0) * float(mult)
    return mults


def maybe_straggle(cfg: ChaosConfig | None, step: int) -> bool:
    """Host-side straggler delay before ``step``; returns True if slept."""
    if cfg is not None and step in cfg.straggler_steps:
        time.sleep(cfg.straggler_delay_s)
        return True
    return False


def should_corrupt(cfg: ChaosConfig | None, step: int) -> bool:
    return cfg is not None and step in cfg.corrupt_ckpt_steps


def corrupt_checkpoint(path: str, seed: int = 0, nbytes: int = 64) -> None:
    """Flip ``nbytes`` seeded bytes in the payload at ``path``.

    Deterministic per (path size, seed).  The flips land in the interior
    of the file, so the archive may or may not still load — either way
    the sha256 manifest check (``ckpt.verify`` / ``ckpt.restore``) fails,
    which is the contract the rollback fallback relies on.
    """
    size = os.path.getsize(path)
    if size < 2:
        return
    rng = np.random.default_rng(seed)
    offsets = rng.integers(low=size // 4, high=max(size // 4 + 1, size - 1),
                           size=min(nbytes, size // 2))
    with open(path, "r+b") as f:
        for off in offsets:
            f.seek(int(off))
            b = f.read(1)
            f.seek(int(off))
            f.write(bytes([b[0] ^ 0xFF]))
