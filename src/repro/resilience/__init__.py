"""Resilient training runtime: chaos fault injection, step-health guards,
and the recovery policy (skip / rollback / degraded-topology replan).

See docs/resilience.md for guard semantics, the recovery state machine,
and the chaos scenario catalog.
"""

from repro.resilience.chaos import ChaosConfig
from repro.resilience.policy import RecoveryPolicy, ResilienceConfig

__all__ = ["ChaosConfig", "RecoveryPolicy", "ResilienceConfig"]
