"""In-step health checks: the fused non-finite reduce (traced, runs inside
the jitted step) and the host-side detectors (EMA loss-spike, dropped-token
watermark) the recovery policy consumes.

Model-free on purpose — this module imports only jax, so the config layer
and the policy can depend on it without touching the model stack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def nonfinite_score(loss, grads):
    """One fused tree-reduce whose finiteness answers for loss + grads.

    ``sum(g * 0)`` is exactly 0.0 for an all-finite leaf and NaN when any
    element is NaN or inf (``0 * inf = nan``), so chaining the per-leaf
    reduces into one scalar add tree gives a single health flag without a
    second pass over the gradients.  Returns the scalar; callers test
    ``jnp.isfinite`` on it.
    """
    z = (loss * 0.0).astype(jnp.float32)
    for g in jax.tree_util.tree_leaves(grads):
        z = z + jnp.sum(g * 0).astype(jnp.float32)
    return z


class SpikeDetector:
    """EMA loss-spike detector: sustained ``loss > factor * ema`` trips it.

    The EMA only absorbs *non-spiking* finite losses (a spike must not
    poison its own baseline), and the first ``warmup`` updates never trip
    (the EMA needs a few steps to mean anything).  ``update`` returns True
    when ``patience`` consecutive spiking steps have been seen; ``reset``
    (called after a rollback) clears the streak but keeps the healthy EMA.
    """

    def __init__(self, factor: float = 3.0, patience: int = 2,
                 beta: float = 0.9, warmup: int = 5):
        self.factor = factor
        self.patience = patience
        self.beta = beta
        self.warmup = warmup
        self.ema = None
        self.n = 0
        self.streak = 0

    def update(self, loss: float) -> bool:
        import math
        if not math.isfinite(loss):
            return False            # the non-finite guard owns this case
        if self.ema is None:
            self.ema = loss
        if self.n >= self.warmup and loss > self.factor * self.ema:
            self.streak += 1
        else:
            self.streak = 0
            self.ema = self.beta * self.ema + (1 - self.beta) * loss
        self.n += 1
        return self.streak >= self.patience

    def reset(self) -> None:
        self.streak = 0


class DropWatermark:
    """Sustained-breach watermark on the dispatch ``dropped`` metric (the
    fraction of routed assignments the static capacities discarded).
    ``update`` returns True once ``patience`` consecutive observations
    exceed ``watermark``; ``watermark >= 1.0`` disables the check
    (``dropped`` lives in [0, 1])."""

    def __init__(self, watermark: float = 1.0, patience: int = 3):
        self.watermark = watermark
        self.patience = patience
        self.streak = 0

    def update(self, dropped: float | None) -> bool:
        if dropped is None or self.watermark >= 1.0:
            return False
        if dropped > self.watermark:
            self.streak += 1
        else:
            self.streak = 0
        if self.streak >= self.patience:
            self.streak = 0         # re-arm: one alarm per sustained breach
            return True
        return False
