"""Recovery policy: turns guard verdicts into actions.

State machine (docs/resilience.md has the full contract):

    healthy --non-finite loss/grads--> SKIP      (the host keeps its
                                                  still-live previous
                                                  params/opt and counts)
    healthy --sustained EMA spike----> ROLLBACK  (restore newest rolling
                                                  checkpoint that passes
                                                  its sha256 manifest)
    healthy --link slowdown >= thr---> REPLAN    (re-solve the Eq. (7)
                                                  DispatchPlan with the
                                                  degraded level's ratio
                                                  collapsed toward local,
                                                  re-jit at the epoch
                                                  boundary)

Replans only happen at ``replan_every`` boundaries because plans are
static per compilation — a new plan means a new jitted step.
"""

from __future__ import annotations

import dataclasses
import math

from repro.resilience import chaos as chaos_lib
from repro.resilience import guards


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Guard + recovery knobs; attach to ``RunConfig.resilience``.

    The guarded step itself is behaviour-preserving: with no chaos config
    and no fault firing, trained params are bit-identical to the unguarded
    loop (fault multipliers of 1.0 are IEEE-exact, and the healthy path
    runs no extra per-leaf work at all).
    """

    # skip-step on non-finite loss/grads (the in-jit select)
    skip_nonfinite: bool = True
    # rollback to the last good rolling checkpoint on sustained loss spike
    rollback_on_spike: bool = False
    spike_factor: float = 3.0
    spike_patience: int = 2
    spike_ema_beta: float = 0.9
    spike_warmup: int = 5
    # dropped-token watermark off the engine's `dropped` metric
    drop_watermark: float = 1.0       # >= 1.0 disables
    drop_patience: int = 3
    # degraded-topology fallback: probe links every `replan_every` steps
    # (0 disables); a level whose observed beta slowdown vs the first
    # probe reaches `degrade_threshold` gets its Eq. (7) ratio shrunk by
    # that slowdown, and `collapse_slowdown` collapses it to 0 (local-only
    # dispatch — the degenerate-empty-level rule of capacity.stage_ratio)
    replan_every: int = 0
    degrade_threshold: float = 4.0
    collapse_slowdown: float = 64.0
    # fault injection schedule (None = no chaos)
    chaos: chaos_lib.ChaosConfig | None = None


class RecoveryPolicy:
    """Host-side recovery driver owned by one training run.

    Counters (``skipped_steps`` / ``rollbacks`` / ``replans`` /
    ``drop_alarms``) surface in ``TrainResult`` and every logged
    ``metrics_history`` entry.
    """

    def __init__(self, cfg: ResilienceConfig):
        self.cfg = cfg
        self.spike = guards.SpikeDetector(
            factor=cfg.spike_factor, patience=cfg.spike_patience,
            beta=cfg.spike_ema_beta, warmup=cfg.spike_warmup)
        self.drop = guards.DropWatermark(
            watermark=cfg.drop_watermark, patience=cfg.drop_patience)
        self.skipped_steps = 0
        self.rollbacks = 0
        self.replans = 0
        self.drop_alarms = 0
        self._baseline_links: dict | None = None
        self._applied_scales: dict = {}

    @property
    def healthy(self) -> bool:
        """No suspicion in flight — safe to take a rolling checkpoint.
        (A checkpoint written mid-spike would poison the rollback target.)"""
        return self.spike.streak == 0

    def counters(self) -> dict:
        return {"skipped_steps": self.skipped_steps,
                "rollbacks": self.rollbacks, "replans": self.replans,
                "drop_alarms": self.drop_alarms}

    # -- per-step classification --------------------------------------------

    def classify(self, step: int, metrics: dict) -> str:
        """Map one step's host-visible metrics to "ok" | "skip" |
        "rollback".  ``metrics`` values must already be host floats."""
        nonfinite = metrics.get("nonfinite", 0.0)
        loss = metrics.get("loss", float("nan"))
        if self.drop.update(metrics.get("dropped")):
            self.drop_alarms += 1
        if self.cfg.skip_nonfinite and (nonfinite > 0.0
                                        or not math.isfinite(loss)):
            self.skipped_steps += 1
            return "skip"
        if self.spike.update(loss) and self.cfg.rollback_on_spike:
            self.rollbacks += 1
            return "rollback"
        return "ok"

    def on_rollback(self) -> None:
        """Reset detectors after params were restored (the EMA's healthy
        baseline is kept; only the spike streak clears)."""
        self.spike.reset()

    # -- degraded-topology fallback -----------------------------------------

    def observe_links(self, mesh, axis_names, step: int) -> dict:
        """Measured per-axis links (with chaos degradation applied) as
        slowdown ratios vs the pristine baseline.  The first call pins
        the baseline from the *unscaled* measurement, so degradation
        already active at the first probe is still caught."""
        from repro.core import comm_model
        links = comm_model.measured_ep_links(mesh, axis_names)
        if self._baseline_links is None:
            self._baseline_links = links
        mults = chaos_lib.link_multipliers(self.cfg.chaos, step)
        if mults:
            links = comm_model.scale_links(links, mults)
        return comm_model.link_slowdowns(links, self._baseline_links)

    def replan(self, ctx, slowdowns: dict):
        """Re-solve the dispatch plan against observed link slowdowns.

        Returns a replacement ``ModelCtx`` (caller re-jits at the epoch
        boundary) or None when nothing crossed ``degrade_threshold`` or
        the degradation set is unchanged since the last replan.  Axis
        ``k`` of the EP hierarchy (outermost-first) feeds topology level
        ``n - k``; a slowdown past ``collapse_slowdown`` scales that
        level's inverse bandwidth to inf, which drives its Eq. (7) ratio
        to exactly 0 — the same degenerate-empty-level convention
        ``capacity.stage_ratio`` pins for memberless levels.
        """
        if ctx.plan is None or ctx.ep is None:
            return None
        names = tuple(ctx.ep.axis_names)
        n = len(names)
        scales = {}
        for k, ax in enumerate(names):
            s = slowdowns.get(ax, 1.0)
            if s >= self.cfg.collapse_slowdown:
                scales[n - k] = math.inf
            elif s >= self.cfg.degrade_threshold:
                scales[n - k] = float(s)
        if scales == self._applied_scales:
            return None
        from repro.core import capacity, topology
        from repro.models import model as model_lib
        level_scale = tuple(scales.get(level, 1.0) for level in range(n + 1))
        plan = ctx.plan
        new_plan = capacity.make_dispatch_plan(
            tokens_per_device=plan.tokens_per_device,
            num_experts=plan.num_experts,
            top_k=ctx.arch.moe.top_k,
            capacity_factor=ctx.arch.moe.capacity_factor,
            axis_sizes=plan.axis_sizes, axis_names=names, mode=plan.mode,
            comm=topology.tree_topology_nd(plan.axis_sizes),
            level_beta_scale=level_scale)
        if plan.num_chunks > 1:
            new_plan = capacity.align_to_chunks(new_plan, plan.num_chunks)
        if new_plan.caps == plan.caps:
            self._applied_scales = scales
            return None
        gate_cfg = model_lib.make_gate_cfg(ctx.arch, new_plan, ctx.ep,
                                           ctx.gate_cfg.aux_mode)
        self._applied_scales = scales
        self.replans += 1
        return dataclasses.replace(ctx, plan=new_plan, gate_cfg=gate_cfg)
