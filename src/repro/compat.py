"""Version-portability shims for the JAX API surface this repo uses.

The codebase targets the modern API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``) but must also run on jax 0.4.x,
where ``shard_map`` lives in ``jax.experimental.shard_map`` (with the
``check_rep`` spelling) and ``jax.sharding.AxisType`` does not exist yet.
Everything below degrades gracefully in both directions; import from here
instead of reaching into ``jax`` directly for these three entry points.
"""

from __future__ import annotations

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types when supported.

    On jax >= 0.5 every axis is marked ``AxisType.Auto`` (the repo relies on
    auto sharding propagation outside shard_map regions); on 0.4.x the
    ``axis_types`` kwarg does not exist and Auto is the only behaviour.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _AXIS_TYPE is not None:
        kwargs["axis_types"] = (_AXIS_TYPE.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on 0.4.x.

    ``check_vma`` maps onto the old API's ``check_rep``; both default to
    False here because the MoE bodies return replicated metrics computed
    via pmean, which the rep checker cannot always verify.
    """
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=check_vma)
    else:
        from jax.experimental.shard_map import shard_map as _shard_map
        sm = _shard_map(f, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=check_vma)
    return sm
