"""AdamW with decoupled weight decay, global-norm clipping, and a
linear-warmup + cosine-decay schedule — pure JAX, optimizer state shards
like the params (ZeRO-1 falls out of pjit param sharding)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def init_state(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (cfg.min_lr_ratio
                                       + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    c1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return (new_p, {"mu": new_mu, "nu": new_nu, "step": step},
            {"grad_norm": gnorm, "lr": lr})
