"""GPT-3 Medium + MoE — the paper's own experimental model (Table 3):
12 layers, hidden 1024, GShard top-2 gate, intermediate 2048 experts.
Expert count is swept {8,16,32,48,64} in the benchmarks; 64 here."""

from repro.configs.base import ArchConfig, MoEArch

CONFIG = ArchConfig(
    name="gpt3-medium-moe",
    family="moe",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=50304,
    norm="layernorm",
    activation="gelu",
    moe=MoEArch(num_experts=64, top_k=2, d_ff_expert=2048,
                moe_period=2,          # MoE every other layer (standard GShard)
                capacity_factor=2.0),  # paper Table 3, GShard gate
    source="TA-MoE paper, Table 3 [arXiv:2302.09915]",
)
