"""xLSTM-350M — sLSTM + mLSTM blocks [arXiv:2405.04517].
24 blocks, 7:1 mLSTM:sLSTM, no separate FFN (d_ff=0)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                # xLSTM blocks carry their own projections
    vocab_size=50304,
    norm="rmsnorm",
    activation="gelu",
    ssm_kind="xlstm",
    slstm_every=8,         # one sLSTM per 8 blocks (7:1)
    source="arXiv:2405.04517",
)
