"""Whisper-tiny — encoder-decoder audio model [arXiv:2212.04356].
Conv/mel frontend is the sanctioned stub: input_specs provides frame
embeddings [B, 1500, 384] directly to the 4-layer encoder."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,          # decoder layers
    enc_layers=4,          # encoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    frontend_len=1500,     # 30 s of audio at 50 Hz after the conv stub
    source="arXiv:2212.04356",
)
