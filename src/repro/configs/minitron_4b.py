"""Minitron-4B — width-pruned Nemotron-4 [arXiv:2407.14679].
256k vocabulary exercises the vocab-sharded embedding path."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    norm="rmsnorm",
    activation="swiglu",
    source="arXiv:2407.14679",
)
