"""InternVL2-26B — VLM: InternViT (stub frontend) + InternLM2-20B backbone
[arXiv:2404.16821].  The language model consumes projected patch embeddings;
the vision tower is the assignment's sanctioned stub."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1e6,
    frontend="vision",
    frontend_len=256,      # projected ViT patch embeddings per image
    source="arXiv:2404.16821",
)
