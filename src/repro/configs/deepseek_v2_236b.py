"""DeepSeek-V2 236B — MLA + 160-expert MoE top-6 [arXiv:2405.04434].
60 layers (first dense), q_lora_rank=1536, 2 shared experts."""

from repro.configs.base import ArchConfig, MLAArch, MoEArch

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,            # dense first-layer FFN
    vocab_size=102400,
    norm="rmsnorm",
    activation="swiglu",
    mla=MLAArch(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                v_dim=128, q_lora_rank=1536),
    moe=MoEArch(num_experts=160, top_k=6, d_ff_expert=1536,
                num_shared_experts=2, first_dense=1,
                capacity_factor=1.25),
    source="arXiv:2405.04434",
)
