"""Jamba-v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].  32 layers, attention every 8th layer, MoE every other
layer (16 experts, top-2)."""

from repro.configs.base import ArchConfig, MoEArch

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    norm="rmsnorm",
    activation="swiglu",
    ssm_kind="mamba",
    attn_every=8,          # 1 attention : 7 mamba
    attn_offset=4,         # attention sits mid-group (Jamba places it at 4)
    moe=MoEArch(num_experts=16, top_k=2, d_ff_expert=14336,
                moe_period=2, capacity_factor=1.25),
    source="arXiv:2403.19887",
)
