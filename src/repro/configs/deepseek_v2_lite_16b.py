"""DeepSeek-V2-Lite 16B — MLA + fine-grained MoE [arXiv:2405.04434].
27 layers (first dense), 64 routed experts top-6 + 2 shared,
MLA kv_lora_rank=512."""

from repro.configs.base import ArchConfig, MLAArch, MoEArch

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,            # dense first-layer FFN
    vocab_size=102400,
    norm="rmsnorm",
    activation="swiglu",
    mla=MLAArch(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                v_dim=128, q_lora_rank=0),
    moe=MoEArch(num_experts=64, top_k=6, d_ff_expert=1408,
                num_shared_experts=2, first_dense=1,
                capacity_factor=1.25),
    source="arXiv:2405.04434",
)
