"""Architecture + run configuration dataclasses and the arch registry.

Each assigned architecture lives in ``configs/<id>.py`` exposing ``CONFIG``.
``ArchConfig.reduced()`` yields the CPU smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) of the same family.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEArch:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    moe_period: int = 1          # MoE FFN every `period` layers (1 = all)
    first_dense: int = 0         # leading layers keep a dense FFN
    capacity_factor: float = 1.25
    # Per-layer dispatch override: tuple of (global_layer_idx, path_name)
    # pairs, where path_name is any name in the core.dispatch engine
    # registry ("a2a" | "a2a_pipelined" | "gather" | "einsum").  Layers not
    # listed use the run-level RunConfig.dispatch default.  Run-level
    # overrides (RunConfig.dispatch_override) win over arch-level ones.
    dispatch_override: tuple = ()


@dataclasses.dataclass(frozen=True)
class MLAArch:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    q_lora_rank: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    norm: str = "rmsnorm"         # rmsnorm | nonparam_ln | layernorm
    activation: str = "swiglu"
    rope_theta: float = 1e4
    sliding_window: int = 0       # 0 = full attention
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: MoEArch | None = None
    mla: MLAArch | None = None
    # hybrid (jamba): attention mixer at layer i when i % attn_every == attn_offset,
    # else the SSM mixer.  attn_every=1 -> pure attention.
    attn_every: int = 1
    attn_offset: int = 0
    ssm_kind: str = ""            # "mamba" | "xlstm"
    slstm_every: int = 0          # xlstm: one sLSTM block per this many
    # encoder-decoder (whisper)
    enc_layers: int = 0
    # modality frontend stub: embeddings of shape [B, frontend_len, d_model]
    frontend: str | None = None  # "audio" | "vision"
    frontend_len: int = 0
    dtype: str = "bfloat16"
    source: str = ""              # citation

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md input-shape policy)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.mla is not None:
            return True           # compressed per-token cache, O(L)/step
        if self.family == "audio":
            return False          # enc-dec, bounded contexts
        return True               # dense/vlm: via sliding-window variant

    def reduced(self) -> ArchConfig:
        """Smoke-test variant: same family/structure, tiny dims."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        layers = min(self.num_layers, max(2, self.attn_every))
        if self.family == "hybrid":       # keep one full mixer group
            layers = self.attn_every
        if self.ssm_kind == "xlstm" and self.slstm_every:
            layers = min(self.num_layers, self.slstm_every)
        moe = self.moe
        if moe:
            moe = dataclasses.replace(
                moe, num_experts=min(moe.num_experts, 4),
                top_k=min(moe.top_k, 2),
                d_ff_expert=min(moe.d_ff_expert, 128),
                num_shared_experts=min(moe.num_shared_experts, 1),
                first_dense=min(moe.first_dense, 1))
        mla = self.mla
        if mla:
            mla = dataclasses.replace(mla, kv_lora_rank=64, qk_nope_dim=32,
                                      qk_rope_dim=16, v_dim=32,
                                      q_lora_rank=0)
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=layers, d_model=d,
            num_heads=heads, num_kv_heads=kv, head_dim=0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            enc_layers=min(self.enc_layers, 2),
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
            moe=moe, mla=mla, dtype="float32")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training-run / serving-run hyperparameters."""
    seq_len: int = 4096
    global_batch: int = 256
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    aux_weight: float = 1.0       # paper: 1.0
    aux_mode: str = "ta"          # lb | ta | hir | none
    seed: int = 0
    microbatch: int = 0           # 0 = no grad accumulation
    remat: bool = False
    # MoE dispatch execution path, resolved through the core.dispatch
    # engine registry: "a2a" (sync staged all-to-all), "a2a_pipelined"
    # (chunked comm–compute overlap), "gather" (weights-stationary), or
    # "einsum" (GShard baseline; single-rank only).
    dispatch: str = "a2a"
    a2a_num_chunks: int = 0       # 0 = auto-pick via core.comm_model
    # per-layer (global_layer_idx, path_name) pairs; wins over
    # MoEArch.dispatch_override for the same layer index.
    dispatch_override: tuple = ()
    # moe_permute token-permutation kernels in the dispatch hot path:
    # None = auto (Pallas on TPU/GPU, jnp reference elsewhere; setting
    # REPRO_KERNEL_INTERPRET=1 flips auto onto interpreted kernels — the
    # CPU CI lane).  True forces the kernels — on CPU that means the slow
    # Pallas *interpreter*, so True is for validation, not CPU speed;
    # False forces the jnp reference everywhere.
    use_pallas: bool | None = None
    # MoE a2a wire codec, a registered name in core.dispatch.wire.CODECS
    # ("bf16" | "int8" | "fp8e4m3"; "" = raw model-dtype wire).  Scaled
    # codecs move int8/fp8 payloads with a per-segment f32 scale sideband
    # riding the same collective chain; "int8" additionally runs the
    # delivered rows' up-projection GEMMs in int8 (i32 accumulate).
    wire_codec: str = ""
    # Resilient-runtime config (a repro.resilience.ResilienceConfig, or
    # None for the classic unguarded loop).  Typed as object to keep this
    # module import-light; trainer.train and build_ctx thread it through
    # to the guarded step factory and the recovery policy.
    resilience: object | None = None
    # Nested topology spec in the paper's Fig. 2 notation, e.g.
    # ((2, 2), (2, 2)) for a 3-tier pod x node x data hierarchy of 8
    # devices.  Empty = take the hierarchy from the mesh the caller built.
    # Launchers (repro.launch.train / mesh.mesh_from_topology) turn this
    # into an N-tier mesh, and trainer.train validates the mesh it is
    # handed against this spec; the level-indexed DispatchPlan then gets
    # one capacity per tier automatically.
    topology: tuple = ()

    def mesh_axis_sizes(self) -> tuple:
        """Outermost-first hierarchy sizes of ``topology`` (empty tuple
        when no spec was given)."""
        if not self.topology:
            return ()
        from repro.core.topology import axis_sizes_from_spec
        return axis_sizes_from_spec(self.topology)


ARCH_IDS = (
    "jamba_v0_1_52b", "internlm2_1_8b", "internvl2_26b", "olmo_1b",
    "whisper_tiny", "deepseek_v2_lite_16b", "xlstm_350m",
    "deepseek_v2_236b", "granite_3_2b", "minitron_4b",
    "gpt3_medium_moe",            # the paper's own model
)


def normalize_arch_id(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{normalize_arch_id(arch_id)}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


# The four assigned input shapes (system prompt).
INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
