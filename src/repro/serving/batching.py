"""Prefill packing and the slotted KV cache for continuous batching.

``pad_pack`` right-pads a pack of prompts to a fixed (pack, bucket) shape
so every admission round hits the same jit cache entry; ``SlotKVCache``
wraps ``decode_lib.init_cache`` with slot-indexed insert/evict so freed
slots are reused without recompilation (slot ids are traced values, the
shapes never change).  Padded pack rows carry slot id ``num_slots`` —
out of bounds, so JAX scatter semantics drop them on insert.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as decode_lib


def pick_bucket(length: int, buckets) -> int:
    """Smallest right-pad bucket that fits ``length``."""
    for b in sorted(buckets):
        if length <= b:
            return int(b)
    raise ValueError(f"prompt length {length} exceeds the largest prefill "
                     f"bucket {max(buckets)}")


def pad_pack(prompts, pack: int, buckets):
    """Right-pad ``prompts`` (list of 1-D int sequences, len <= pack) to a
    fixed ``[pack, bucket]`` token block.

    Returns ``(tokens [pack, L], lens [pack])`` — padded rows get a
    single-token dummy prompt (lens 1) so downstream gathers at
    ``lens - 1`` stay in bounds; their slot ids are out of range so their
    cache rows are never inserted.
    """
    if len(prompts) > pack:
        raise ValueError(f"pack of {len(prompts)} prompts exceeds width "
                         f"{pack}")
    L = pick_bucket(max((len(p) for p in prompts), default=1), buckets)
    tokens = np.zeros((pack, L), np.int32)
    lens = np.ones((pack,), np.int32)
    for i, p in enumerate(prompts):
        tokens[i, :len(p)] = np.asarray(p, np.int32)
        lens[i] = len(p)
    return jnp.asarray(tokens), jnp.asarray(lens)


def pad_frontend_pack(frontends, pack: int):
    """Stack per-request frontend arrays (e.g. vision patches) into a
    ``[pack, F, d]`` block, zero-filled for padded rows.  All present
    arrays must share one shape (the arch's ``frontend_len``)."""
    shapes = {tuple(np.asarray(f).shape) for f in frontends if f is not None}
    if len(shapes) != 1:
        raise ValueError(f"frontend arrays disagree on shape: {shapes}")
    F, d = shapes.pop()
    out = np.zeros((pack, F, d), np.float32)
    for i, f in enumerate(frontends):
        if f is not None:
            out[i] = np.asarray(f, np.float32)
    return jnp.asarray(out)


class SlotKVCache:
    """A decode cache with ``num_slots`` batch rows managed as slots.

    All three operations are jitted once and reused for the engine's
    lifetime — slot ids are data, not shapes — so admit/evict/re-admit
    cycles never recompile.
    """

    def __init__(self, ctx, num_slots: int, cache_len: int):
        self.ctx = ctx
        self.num_slots = int(num_slots)
        self.cache_len = int(cache_len)
        self.cache = jax.jit(
            lambda: decode_lib.init_cache(ctx, self.num_slots,
                                          self.cache_len))()
        if getattr(ctx, "mesh", None) is not None:
            # match the NamedSharding that prefilled pack caches carry, so
            # the very first insert hits the same jit entry as every later
            # one (SingleDeviceSharding vs NamedSharding keys differently)
            from jax.sharding import NamedSharding, PartitionSpec
            repl = NamedSharding(ctx.mesh, PartitionSpec())
            self.cache = jax.device_put(self.cache, repl)
        # wrap in partials so each instance gets a private tracing cache:
        # jax.jit shares its cache across wrappers of the same callable, so
        # another engine's differently-shaped cache would otherwise leak
        # into this instance's cache stats
        self._insert = jax.jit(functools.partial(decode_lib.cache_insert_slots))
        self._evict = jax.jit(functools.partial(decode_lib.cache_evict_slots))

    def insert(self, src_cache, slot_ids) -> None:
        """Write a prefilled pack cache into ``slot_ids`` (out-of-range ids
        are dropped — the padded-pack convention)."""
        self.cache = self._insert(self.cache, src_cache,
                                  jnp.asarray(slot_ids, jnp.int32))

    def evict(self, slot_ids) -> None:
        """Zero the cache at ``slot_ids`` (pos included)."""
        self.cache = self._evict(self.cache,
                                 jnp.asarray(slot_ids, jnp.int32))

    def positions(self):
        """Per-slot cache positions [num_slots] (0 = empty/evicted); reads
        the first attention/mla sublayer's ``pos`` leaf."""
        for k in sorted(self.cache):
            leaves = [leaf for path, leaf in
                      jax.tree_util.tree_flatten_with_path(self.cache[k])[0]
                      if str(getattr(path[-1], "key", "")) == "pos"]
            if leaves:
                pos = leaves[0]
                return np.asarray(pos[0] if k == "groups" else pos)
        raise ValueError("cache has no pos leaf (recurrent-only family)")
