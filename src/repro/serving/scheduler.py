"""Request scheduler for continuous-batching serving.

Pure-Python control plane: no jax in here.  A fixed pool of decode slots
(the batch rows of the slotted KV cache) is managed as a free heap —
``take`` admits pending requests into the lowest free slot ids (so a freed
slot is deterministically reused first), ``on_token`` advances a stream,
and ``complete`` evicts it and returns the finished stream.  The data
plane (prefill packing, cache insert/evict, the decode loop) lives in
``repro.serving.batching`` / ``repro.serving.engine``.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tokens`` is the prompt (any 1-D int sequence), ``max_new_tokens`` the
    stream's length budget; ``temperature`` 0 means greedy.  ``frontend``
    optionally carries a per-request modality array (vision patches for the
    vlm family), spliced over the leading prompt positions at prefill.
    ``deadline_s`` is a wall-clock budget measured from admission — a
    stream past it is evicted mid-decode (partial output kept, slot freed)
    so one stuck stream can't wedge the engine; None means no deadline.
    """
    uid: int
    tokens: object
    max_new_tokens: int
    temperature: float = 0.0
    frontend: object | None = None
    deadline_s: float | None = None

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class Stream:
    """A request occupying a decode slot."""
    request: Request
    slot: int
    generated: list = dataclasses.field(default_factory=list)
    t_admitted: float = 0.0
    t_finished: float = 0.0
    evicted: bool = False            # deadline eviction (partial output)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.request.max_new_tokens

    @property
    def tokens_per_sec(self) -> float:
        dt = max(self.t_finished - self.t_admitted, 1e-9)
        return len(self.generated) / dt


class Scheduler:
    """Admits requests into a fixed pool of ``num_slots`` decode slots."""

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self._free = list(range(num_slots))
        heapq.heapify(self._free)
        self._pending = deque()
        self._active = {}            # slot -> Stream
        self.finished = []

    # -- queue side ---------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.prompt_len < 1:
            raise ValueError(f"request {request.uid}: empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError(f"request {request.uid}: max_new_tokens < 1")
        self._pending.append(request)

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self._pending or self._active)

    def active_slots(self):
        return sorted(self._active)

    def stream(self, slot: int) -> Stream:
        return self._active[slot]

    # -- admission ----------------------------------------------------------

    def take(self, max_n: int, now: float | None = None):
        """Admit up to ``max_n`` pending requests into free slots.

        Returns the admitted ``[(slot, request), ...]`` (possibly empty when
        the pool is exhausted or the queue is drained); the caller prefills
        the pack and inserts it into the slot cache.
        """
        admits = []
        while self._pending and self._free and len(admits) < max_n:
            slot = heapq.heappop(self._free)
            req = self._pending.popleft()
            self._active[slot] = Stream(request=req, slot=slot,
                                        t_admitted=now if now is not None
                                        else time.time())
            admits.append((slot, req))
        return admits

    # -- decode progress -----------------------------------------------------

    def on_token(self, slot: int, token: int) -> bool:
        """Record one generated token for the stream in ``slot``; returns
        True when the stream just reached its length budget."""
        stream = self._active[slot]
        if stream.done:
            raise ValueError(f"slot {slot}: stream already complete")
        stream.generated.append(int(token))
        return stream.done

    def complete(self, slot: int, now: float | None = None) -> Stream:
        """Evict the stream in ``slot``, free the slot for reuse, and
        return the finished stream."""
        stream = self._active.pop(slot)
        stream.t_finished = now if now is not None else time.time()
        heapq.heappush(self._free, slot)
        self.finished.append(stream)
        return stream

    # -- deadlines -----------------------------------------------------------

    def expired(self, now: float) -> list:
        """Active slots whose stream has outlived its request deadline
        (``deadline_s`` from admission; None = never expires)."""
        return sorted(
            slot for slot, s in self._active.items()
            if s.request.deadline_s is not None
            and now - s.t_admitted >= s.request.deadline_s)

    def evict(self, slot: int, now: float | None = None) -> Stream:
        """Deadline-evict the stream in ``slot``: the partial output is
        kept on the finished list (``evicted`` flag set) and the slot
        returns to the free pool — the caller zeroes the slot's KV rows."""
        stream = self._active.pop(slot)
        stream.evicted = True
        stream.t_finished = now if now is not None else time.time()
        heapq.heappush(self._free, slot)
        self.finished.append(stream)
        return stream
