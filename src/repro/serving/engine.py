"""Serving engine: batched prefill + decode with per-family caches.

``prefill`` runs the full-sequence forward and materializes caches;
``decode_step`` appends one token per request.  Both are jittable and are
what the decode_32k / long_500k dry-runs lower.
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import decode as decode_lib, model as model_lib
from repro.models import transformer


def _with_overrides(ctx: transformer.ModelCtx, dispatch_override):
    """Serving-side per-layer dispatch override (e.g. force a prefill MoE
    layer onto ``a2a_pipelined``, or a decode layer off the gather path).
    Names resolve through the core.dispatch engine registry; entries merge
    per layer index with the ctx's existing (arch/run-level) overrides,
    serving-side entries winning.  Plans are level-indexed, so overrides
    behave identically on 2-level and N-level meshes — chunk alignment
    rounds every stage capacity of the ctx's ``DispatchPlan``."""
    if dispatch_override is None:
        return ctx
    from repro.core import capacity, dispatch as dispatch_lib
    for _, name in dispatch_override:
        dispatch_lib.get_path(name)
    merged = dict(ctx.dispatch_override)
    merged.update(dict(dispatch_override))
    ctx = dataclasses.replace(ctx,
                              dispatch_override=tuple(sorted(merged.items())))
    # a pipelined override needs a resolved chunk count + chunk-aligned
    # plan; build_ctx does this for overrides it saw, so only fill the gap
    if (ctx.plan is not None and ctx.a2a_num_chunks <= 1
            and any(n == "a2a_pipelined" for _, n in ctx.dispatch_override)):
        nc = model_lib.resolve_num_chunks(ctx.arch, ctx.plan, ctx.ep, 0)
        ctx = dataclasses.replace(
            ctx, a2a_num_chunks=nc,
            plan=capacity.align_to_chunks(ctx.plan, nc))
    return ctx


def make_decode_step(ctx: transformer.ModelCtx, dispatch_override=None):
    ctx = _with_overrides(ctx, dispatch_override)

    def step(params, cache, tokens):
        rules = model_lib.default_rules(ctx.mesh) if ctx.mesh else None
        import contextlib
        cm = sharding.axis_rules(rules) if rules else contextlib.nullcontext()
        with cm:
            logits, new_cache = decode_lib.decode_step(params, cache,
                                                       tokens, ctx)
        return logits, new_cache
    return step


def make_prefill(ctx: transformer.ModelCtx, dispatch_override=None):
    """Full-sequence forward returning last-position logits.

    Cache materialization for subsequent decode is done by running the
    forward; for the dry-run the logits path is what matters (the cache
    write is exercised by decode_step itself).
    """
    ctx = _with_overrides(ctx, dispatch_override)

    def prefill(params, batch):
        rules = model_lib.default_rules(ctx.mesh) if ctx.mesh else None
        import contextlib
        cm = sharding.axis_rules(rules) if rules else contextlib.nullcontext()
        with cm:
            logits, _ = transformer.forward(params, batch, ctx)
        return logits[:, -1]
    return prefill


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # [B, steps]
    steps_per_sec: float


def generate(params, ctx: transformer.ModelCtx, prompt_tokens, *,
             steps: int, cache_len: int, temperature: float = 0.0,
             seed: int = 0) -> GenerationResult:
    """Greedy/temperature generation driver for the serving example."""
    import time
    B, S = prompt_tokens.shape
    cache = decode_lib.init_cache(ctx, B, cache_len)
    step_fn = jax.jit(make_decode_step(ctx))
    # teacher-forced prefill via repeated decode (simple + exercises decode);
    # production prefill would use the fused full-sequence path.
    tok = prompt_tokens[:, :1]
    out = []
    key = jax.random.PRNGKey(seed)
    t0 = time.time()
    for i in range(S + steps - 1):
        logits, cache = step_fn(params, cache, tok)
        if i + 1 < S:
            tok = prompt_tokens[:, i + 1:i + 2]
        else:
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, 0] / temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            out.append(tok)
    dt = time.time() - t0
    return GenerationResult(tokens=jnp.concatenate(out, axis=1),
                            steps_per_sec=(S + steps - 1) / max(dt, 1e-9))
