"""Serving engine: fused prefill, decode steps, and continuous batching.

Three layers, lowest first:

- ``make_prefill`` / ``make_decode_step`` — jittable single-call entries
  (what the decode_32k / long_500k dry-runs lower).  ``make_prefill`` with
  ``with_cache=True`` runs the fused full-sequence forward *and*
  materializes the decode cache in one pass (``models/decode.prefill``).
- ``generate`` — the single-batch driver: one fused prefill, then one
  decode step per generated token.
- ``ServingEngine`` — slot-based continuous batching (MLPerf-offline
  style): a ``Scheduler`` admits requests from a queue into a fixed pool
  of decode slots, admission packs prefill through the fused path and are
  inserted into a ``SlotKVCache``, and every decode step advances all
  occupied slots at once.  Shapes are static everywhere (fixed pack width,
  fixed bucketed prompt pads, fixed slot count), so admit/evict/re-admit
  cycles never recompile.  See docs/serving.md.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.models import decode as decode_lib, model as model_lib
from repro.models import transformer
from repro.serving import batching
from repro.serving.scheduler import Request, Scheduler


def _with_overrides(ctx: transformer.ModelCtx, dispatch_override):
    """Serving-side per-layer dispatch override (e.g. force a prefill MoE
    layer onto ``a2a_pipelined``, or a decode layer off the gather path).
    Names resolve through the core.dispatch engine registry; entries merge
    per layer index with the ctx's existing (arch/run-level) overrides,
    serving-side entries winning.  Plans are level-indexed, so overrides
    behave identically on 2-level and N-level meshes — chunk alignment
    rounds every stage capacity of the ctx's ``DispatchPlan``."""
    if dispatch_override is None:
        return ctx
    from repro.core import capacity, dispatch as dispatch_lib
    for _, name in dispatch_override:
        dispatch_lib.get_path(name)
    merged = dict(ctx.dispatch_override)
    merged.update(dict(dispatch_override))
    ctx = dataclasses.replace(ctx,
                              dispatch_override=tuple(sorted(merged.items())))
    # a pipelined override needs a resolved chunk count + chunk-aligned
    # plan; build_ctx does this for overrides it saw, so only fill the gap
    if (ctx.plan is not None and ctx.a2a_num_chunks <= 1
            and any(n == "a2a_pipelined" for _, n in ctx.dispatch_override)):
        nc = model_lib.resolve_num_chunks(ctx.arch, ctx.plan, ctx.ep, 0)
        ctx = dataclasses.replace(
            ctx, a2a_num_chunks=nc,
            plan=capacity.align_to_chunks(ctx.plan, nc))
    return ctx


def _rules_cm(ctx):
    import contextlib
    rules = model_lib.default_rules(ctx.mesh) if ctx.mesh else None
    return sharding.axis_rules(rules) if rules else contextlib.nullcontext()


def make_decode_step(ctx: transformer.ModelCtx, dispatch_override=None):
    ctx = _with_overrides(ctx, dispatch_override)

    def step(params, cache, tokens):
        with _rules_cm(ctx):
            logits, new_cache = decode_lib.decode_step(params, cache,
                                                       tokens, ctx)
        return logits, new_cache
    return step


def make_prefill(ctx: transformer.ModelCtx, dispatch_override=None, *,
                 with_cache: bool = False, cache_len: int | None = None):
    """Fused full-sequence prefill.

    Default (``with_cache=False``): ``prefill(params, batch) ->
    last_logits`` — the logits-only entry the dry-runs lower.

    ``with_cache=True`` (requires ``cache_len``): ``prefill(params, batch)
    -> (last_logits [B, V], cache)`` where ``batch`` is ``{"tokens":
    [B, S], optional "lens" [B], optional "frontend"}``.  The cache is
    materialized from the same forward (K/V for attention, compressed
    latents for MLA; recurrent families scan — see
    ``models/decode.prefill``), with per-request positions set to ``lens``
    so right-padded prompt packs behave exactly like unpadded ones.
    """
    ctx = _with_overrides(ctx, dispatch_override)

    if with_cache:
        if cache_len is None:
            raise ValueError("with_cache=True requires cache_len")

        def prefill_cached(params, batch):
            with _rules_cm(ctx):
                return decode_lib.prefill(params, batch, ctx,
                                          cache_len=cache_len,
                                          lens=batch.get("lens"))
        return prefill_cached

    def prefill(params, batch):
        with _rules_cm(ctx):
            logits, _ = transformer.forward(params, batch, ctx)
        return logits[:, -1]
    return prefill


def _make_sample():
    """Jitted per-row sampler: greedy where temperature <= 0, categorical
    at ``logits / temperature`` elsewhere.  logits [N, V], temps [N]."""
    def sample(logits, temps, key):
        lf = logits.astype(jnp.float32)
        greedy = jnp.argmax(lf, axis=-1)
        scaled = lf / jnp.maximum(temps, 1e-6)[:, None]
        drawn = jax.random.categorical(key, scaled)
        return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)
    return sample


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # [B, steps]
    steps_per_sec: float


def make_generate_fns(ctx: transformer.ModelCtx, cache_len: int):
    """The jitted (prefill, decode_step, sample) triple ``generate`` runs.
    Build once and pass as ``generate(..., fns=...)`` when issuing many
    sequential calls — each bare ``generate`` call otherwise re-jits its
    own closures (fresh function identity, fresh jit cache)."""
    return (jax.jit(make_prefill(ctx, with_cache=True, cache_len=cache_len)),
            jax.jit(make_decode_step(ctx)),
            jax.jit(_make_sample()))


def generate(params, ctx: transformer.ModelCtx, prompt_tokens, *,
             steps: int, cache_len: int, temperature: float = 0.0,
             seed: int = 0, frontend=None, lens=None,
             fns=None) -> GenerationResult:
    """Greedy/temperature generation driver for the serving example.

    The prompt goes through the fused ``make_prefill`` path (one
    full-sequence forward that also materializes the cache); only the
    ``steps`` generated tokens run ``decode_step``.  ``steps_per_sec``
    counts generated tokens only — prompt positions are prefill work, not
    decode steps.  ``lens`` optionally marks per-row true prompt lengths
    when ``prompt_tokens`` is right-padded.
    """
    B, S = prompt_tokens.shape
    prefill_fn, step_fn, sample_fn = (
        fns if fns is not None else make_generate_fns(ctx, cache_len))
    temps = jnp.full((B,), temperature, jnp.float32)
    batch = {"tokens": prompt_tokens,
             "lens": (jnp.asarray(lens, jnp.int32) if lens is not None
                      else jnp.full((B,), S, jnp.int32))}
    if frontend is not None:
        batch["frontend"] = frontend
    key = jax.random.PRNGKey(seed)
    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    key, sub = jax.random.split(key)
    tok = sample_fn(logits, temps, sub)[:, None]
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = step_fn(params, cache, tok)
        key, sub = jax.random.split(key)
        tok = sample_fn(logits[:, 0], temps, sub)[:, None]
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    tokens.block_until_ready()
    dt = time.time() - t0
    return GenerationResult(tokens=tokens,
                            steps_per_sec=steps / max(dt, 1e-9))


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static shapes of the continuous-batching engine.

    ``num_slots`` decode slots run every step; admission packs are
    ``prefill_pack`` wide with prompts right-padded to the smallest
    ``prompt_buckets`` entry that fits (one jit entry per bucket used).
    Every admitted request must satisfy
    ``prompt_len + max_new_tokens <= cache_len``.
    """
    num_slots: int = 8
    cache_len: int = 128
    prefill_pack: int = 4
    prompt_buckets: tuple = (32,)


@dataclasses.dataclass
class ServingReport:
    streams: list                  # finished Stream records, completion order
    wall_time: float
    total_new_tokens: int
    decode_steps: int
    prefill_calls: int
    evictions: int = 0             # deadline-evicted streams (partial
                                   # outputs stay on `streams`, flagged)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_new_tokens / max(self.wall_time, 1e-9)

    def tokens_for(self, uid: int):
        for s in self.streams:
            if s.request.uid == uid:
                return s.generated
        raise KeyError(uid)


class ServingEngine:
    """Slot-based continuous batching over the MoE decode path.

    One engine owns the jitted prefill/decode/sample functions and a
    ``SlotKVCache``; ``run`` drains a list of requests through the
    scheduler.  The loop per iteration: (1) admit pending requests into
    free slots and prefill them as one fused pack, (2) advance every slot
    one decode step, (3) complete streams that hit their budget, freeing
    their slots for the next admission round.
    """

    def __init__(self, params, ctx: transformer.ModelCtx, cfg: ServeConfig,
                 dispatch_override=None):
        self.params = params
        self.ctx = _with_overrides(ctx, dispatch_override)
        self.cfg = cfg
        if max(cfg.prompt_buckets) > cfg.cache_len:
            raise ValueError("prompt bucket exceeds cache_len")
        self._prefill = jax.jit(make_prefill(
            self.ctx, with_cache=True, cache_len=cfg.cache_len))
        self._decode = jax.jit(make_decode_step(self.ctx))
        self._sample = jax.jit(_make_sample())
        # current token per slot, scatter-updated at admission; padded pack
        # rows carry slot id == num_slots and are dropped (OOB scatter)
        self._scatter = jax.jit(
            lambda cur, slots, toks: cur.at[slots, 0].set(toks))

    def _admit(self, sched, kv, cur, temps, key, now):
        cfg = self.cfg
        admits = sched.take(cfg.prefill_pack, now=now)
        if not admits:
            return cur, key, 0
        for _, req in admits:
            need = req.prompt_len + req.max_new_tokens
            if need > cfg.cache_len:
                raise ValueError(
                    f"request {req.uid}: prompt+new tokens {need} exceed "
                    f"cache_len {cfg.cache_len}")
        tokens, lens = batching.pad_pack([req.tokens for _, req in admits],
                                         cfg.prefill_pack,
                                         cfg.prompt_buckets)
        batch = {"tokens": tokens, "lens": lens}
        if any(req.frontend is not None for _, req in admits):
            batch["frontend"] = batching.pad_frontend_pack(
                [req.frontend for _, req in admits], cfg.prefill_pack)
        logits, pack_cache = self._prefill(self.params, batch)
        slots = np.full((cfg.prefill_pack,), cfg.num_slots, np.int32)
        slots[:len(admits)] = [s for s, _ in admits]
        slots = jnp.asarray(slots)
        kv.insert(pack_cache, slots)
        pack_temps = np.zeros((cfg.prefill_pack,), np.float32)
        for i, (s, req) in enumerate(admits):
            temps[s] = req.temperature
            pack_temps[i] = req.temperature
        key, sub = jax.random.split(key)
        first = self._sample(logits, jnp.asarray(pack_temps), sub)
        cur = self._scatter(cur, slots, first)
        for i, (s, _) in enumerate(admits):
            if sched.on_token(s, int(first[i])):
                sched.complete(s, now=time.time())
        return cur, key, 1

    def run(self, requests, *, seed: int = 0) -> ServingReport:
        """Serve ``requests`` to completion; returns per-stream stats."""
        cfg = self.cfg
        sched = Scheduler(cfg.num_slots)
        for req in requests:
            sched.submit(req)
        kv = batching.SlotKVCache(self.ctx, cfg.num_slots, cfg.cache_len)
        cur = jnp.zeros((cfg.num_slots, 1), jnp.int32)
        temps = np.zeros((cfg.num_slots,), np.float32)
        key = jax.random.PRNGKey(seed)
        decode_steps = prefill_calls = evictions = 0
        t0 = time.time()
        while sched.has_work:
            cur, key, n_pre = self._admit(sched, kv, cur, temps, key,
                                          now=time.time())
            prefill_calls += n_pre
            # deadline sweep: evict overdue streams mid-decode — their KV
            # rows are zeroed and the freed slots return to the pool for
            # the next admission round (one stuck stream can't wedge the
            # engine)
            now = time.time()
            overdue = sched.expired(now)
            if overdue:
                kv.evict(overdue)
                for slot in overdue:
                    sched.evict(slot, now=now)
                evictions += len(overdue)
            if not sched.num_active:
                continue        # everything admitted finished at 1 token
            logits, kv.cache = self._decode(self.params, kv.cache, cur)
            key, sub = jax.random.split(key)
            nxt = self._sample(logits[:, 0], jnp.asarray(temps), sub)
            cur = nxt[:, None]
            decode_steps += 1
            nxt_host = np.asarray(nxt)
            for slot in sched.active_slots():
                if sched.on_token(slot, int(nxt_host[slot])):
                    sched.complete(slot, now=time.time())
        wall = time.time() - t0
        total = sum(len(s.generated) for s in sched.finished)
        return ServingReport(streams=sched.finished, wall_time=wall,
                             total_new_tokens=total,
                             decode_steps=decode_steps,
                             prefill_calls=prefill_calls,
                             evictions=evictions)


__all__ = ["GenerationResult", "Request", "ServeConfig", "ServingEngine",
           "ServingReport", "generate", "make_decode_step",
           "make_generate_fns", "make_prefill"]
