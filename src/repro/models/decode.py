"""Decode-time forward: one new token against per-layer caches/states.

Cache pytree mirrors the (prefix, groups) layer plan; group caches carry a
leading n_groups axis and thread through ``lax.scan`` alongside the stacked
params.  Mixer-family cache kinds:

    attn  -> KV cache [B, L, K, hd]        (L may be sharded: context parallel)
    mla   -> compressed latent cache [B, L, r] + rope keys
    mamba -> (h, conv) recurrent state     (O(1) per step)
    mlstm -> (C, n, m) matrix memory       (O(1) per step)
    slstm -> (c, n, h, m) scalar memory    (O(1) per step)
    cross -> precomputed encoder K/V       (static during decode)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import layers, mamba as mamba_lib, mla as mla_lib
from repro.models import xlstm as xlstm_lib
from repro.models.transformer import (ModelCtx, SubLayer, _moe_block,
                                      _overrides_hit_groups, layer_plan)


def _init_sub_cache(sub: SubLayer, batch: int, max_len: int, ctx: ModelCtx):
    c = {}
    if sub.mixer == "attn":
        c["mixer"] = layers.init_kv_cache(batch, max_len, ctx.attn_cfg)
    elif sub.mixer == "mla":
        c["mixer"] = mla_lib.init_mla_cache(batch, max_len, ctx.mla_cfg)
    elif sub.mixer == "mamba":
        c["mixer"] = mamba_lib.init_mamba_state(batch, ctx.mamba_cfg)
    elif sub.mixer == "mlstm":
        c["mixer"] = xlstm_lib.init_mlstm_state(batch, ctx.xlstm_cfg)
    elif sub.mixer == "slstm":
        c["mixer"] = xlstm_lib.init_slstm_state(batch, ctx.xlstm_cfg)
    if sub.cross:
        # encoder K/V filled at prefill; zeros here
        a = ctx.attn_cfg
        enc_len = ctx.arch.frontend_len or 1
        c["cross_k"] = jnp.zeros((batch, enc_len, a.num_kv_heads, a.head_dim),
                                 a.dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, a.num_kv_heads, a.head_dim),
                                 a.dtype)
    return c


def init_cache(ctx: ModelCtx, batch: int, max_len: int):
    prefix, group, n_groups = layer_plan(ctx.arch)
    cache = {}
    for i, sub in enumerate(prefix):
        cache[f"prefix{i}"] = _init_sub_cache(sub, batch, max_len, ctx)

    def one(_):
        return {f"sub{j}": _init_sub_cache(s, batch, max_len, ctx)
                for j, s in enumerate(group)}
    cache["groups"] = jax.vmap(one)(jnp.arange(n_groups))
    return cache


def fill_cross_cache(params, cache, enc_out, ctx: ModelCtx):
    """Project encoder output into every decoder layer's cross K/V cache."""
    prefix, group, n_groups = layer_plan(ctx.arch)
    a = ctx.attn_cfg
    B, F, _ = enc_out.shape

    def kv(p_cross, stacked: bool):
        eq = "bfd,gdk->gbfk" if stacked else "bfd,dk->bfk"
        k = jnp.einsum(eq, enc_out, p_cross["wk"])
        v = jnp.einsum(eq, enc_out, p_cross["wv"])
        shp = ((n_groups, B, F, a.num_kv_heads, a.head_dim) if stacked
               else (B, F, a.num_kv_heads, a.head_dim))
        return k.reshape(shp), v.reshape(shp)

    cache = jax.tree_util.tree_map(lambda x: x, cache)  # shallow copy
    for i, sub in enumerate(prefix):
        if sub.cross:
            k, v = kv(params[f"prefix{i}"]["cross"], stacked=False)
            cache[f"prefix{i}"]["cross_k"] = k.astype(a.dtype)
            cache[f"prefix{i}"]["cross_v"] = v.astype(a.dtype)
    for j, sub in enumerate(group):
        if sub.cross:
            k, v = kv(params["groups"][f"sub{j}"]["cross"], stacked=True)
            cache["groups"][f"sub{j}"]["cross_k"] = k.astype(a.dtype)
            cache["groups"][f"sub{j}"]["cross_v"] = v.astype(a.dtype)
    return cache


def _decode_sublayer(p, c, x, sub: SubLayer, ctx: ModelCtx, layer_idx=None):
    a = ctx.arch
    h = layers.norm_apply(p["norm1"], x, a.norm)
    if sub.mixer == "attn":
        mix, c["mixer"] = layers.attn_decode(p["mixer"], h, c["mixer"],
                                             ctx.attn_cfg)
    elif sub.mixer == "mla":
        mix, c["mixer"] = mla_lib.mla_decode(p["mixer"], h, c["mixer"],
                                             ctx.mla_cfg)
    elif sub.mixer == "mamba":
        mix, c["mixer"] = mamba_lib.mamba_decode(p["mixer"], h, c["mixer"],
                                                 ctx.mamba_cfg)
    elif sub.mixer == "mlstm":
        mix, c["mixer"] = xlstm_lib.mlstm_decode(p["mixer"], h, c["mixer"],
                                                 ctx.xlstm_cfg)
    elif sub.mixer == "slstm":
        mix, c["mixer"] = xlstm_lib.slstm_decode(p["mixer"], h, c["mixer"],
                                                 ctx.xlstm_cfg)
    x = x + mix
    if sub.cross:
        h = layers.norm_apply(p["norm_cross"], x, a.norm)
        B = x.shape[0]
        cfg = ctx.attn_cfg
        q = (h @ p["cross"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
        out = layers._sdpa(q, c["cross_k"], c["cross_v"], causal=False,
                           sliding_window=0, q_positions=jnp.zeros((1,), int),
                           k_positions=jnp.arange(c["cross_k"].shape[1]))
        x = x + out.reshape(B, 1, -1) @ p["cross"]["wo"]
    if sub.ffn == "mlp":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        x = x + layers.mlp_apply(p["ffn"], h, a.activation)
    elif sub.ffn == "moe":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        y, _ = _moe_block(p["ffn"], h, ctx, decode=True, layer_idx=layer_idx)
        x = x + y
    return x, c


def decode_step(params, cache, tokens, ctx: ModelCtx):
    """tokens: [B, 1] — returns (logits [B, 1, V], new_cache)."""
    a = ctx.arch
    prefix, group, n_groups = layer_plan(a)
    x = layers.embed_apply(params["embed"], tokens)
    if not ctx.decode_replicated:
        x = sharding.constrain(x, "batch", None, None)

    new_cache = {}
    for i, sub in enumerate(prefix):
        x, new_cache[f"prefix{i}"] = _decode_sublayer(
            params[f"prefix{i}"], dict(cache[f"prefix{i}"]), x, sub, ctx,
            layer_idx=i)

    n_prefix = len(prefix)
    if _overrides_hit_groups(ctx, n_prefix, group, n_groups, decode=True):
        # layer-dependent dispatch inside the groups: unroll (mirrors
        # transformer.forward_features) and restack the per-group caches.
        new_gs = []
        for g in range(n_groups):
            pg = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
            cg = jax.tree_util.tree_map(lambda a: a[g], cache["groups"])
            for j, sub in enumerate(group):
                x, cg[f"sub{j}"] = _decode_sublayer(
                    pg[f"sub{j}"], dict(cg[f"sub{j}"]), x, sub, ctx,
                    layer_idx=n_prefix + g * len(group) + j)
            new_gs.append(cg)
        new_cache["groups"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_gs)
    else:
        def body(x, pc):
            p, c = pc
            c = jax.tree_util.tree_map(lambda v: v, c)  # shallow copy
            for j, sub in enumerate(group):
                x, c[f"sub{j}"] = _decode_sublayer(
                    p[f"sub{j}"], dict(c[f"sub{j}"]), x, sub, ctx)
            return x, c

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))
        new_cache["groups"] = new_groups
    x = layers.norm_apply(params["final_norm"], x, a.norm)
    logits = layers.unembed_apply(params["embed"], x)
    return logits, new_cache
