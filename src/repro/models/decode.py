"""Decode-time forward: one new token against per-layer caches/states.

Cache pytree mirrors the (prefix, groups) layer plan; group caches carry a
leading n_groups axis and thread through ``lax.scan`` alongside the stacked
params.  Mixer-family cache kinds:

    attn  -> KV cache [B, L, K, hd]        (L may be sharded: context parallel)
    mla   -> compressed latent cache [B, L, r] + rope keys
    mamba -> (h, conv) recurrent state     (O(1) per step)
    mlstm -> (C, n, m) matrix memory       (O(1) per step)
    slstm -> (c, n, h, m) scalar memory    (O(1) per step)
    cross -> precomputed encoder K/V       (static during decode)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import layers, mamba as mamba_lib, mla as mla_lib
from repro.models import xlstm as xlstm_lib
from repro.models.transformer import (ModelCtx, SubLayer, _moe_block,
                                      _overrides_hit_groups, layer_plan)


def _init_sub_cache(sub: SubLayer, batch: int, max_len: int, ctx: ModelCtx):
    c = {}
    if sub.mixer == "attn":
        c["mixer"] = layers.init_kv_cache(batch, max_len, ctx.attn_cfg)
    elif sub.mixer == "mla":
        c["mixer"] = mla_lib.init_mla_cache(batch, max_len, ctx.mla_cfg)
    elif sub.mixer == "mamba":
        c["mixer"] = mamba_lib.init_mamba_state(batch, ctx.mamba_cfg)
    elif sub.mixer == "mlstm":
        c["mixer"] = xlstm_lib.init_mlstm_state(batch, ctx.xlstm_cfg)
    elif sub.mixer == "slstm":
        c["mixer"] = xlstm_lib.init_slstm_state(batch, ctx.xlstm_cfg)
    if sub.cross:
        # encoder K/V filled at prefill; zeros here
        a = ctx.attn_cfg
        enc_len = ctx.arch.frontend_len or 1
        c["cross_k"] = jnp.zeros((batch, enc_len, a.num_kv_heads, a.head_dim),
                                 a.dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, a.num_kv_heads, a.head_dim),
                                 a.dtype)
    return c


def init_cache(ctx: ModelCtx, batch: int, max_len: int):
    prefix, group, n_groups = layer_plan(ctx.arch)
    cache = {}
    for i, sub in enumerate(prefix):
        cache[f"prefix{i}"] = _init_sub_cache(sub, batch, max_len, ctx)

    def one(_):
        return {f"sub{j}": _init_sub_cache(s, batch, max_len, ctx)
                for j, s in enumerate(group)}
    cache["groups"] = jax.vmap(one)(jnp.arange(n_groups))
    return cache


def fill_cross_cache(params, cache, enc_out, ctx: ModelCtx):
    """Project encoder output into every decoder layer's cross K/V cache."""
    prefix, group, n_groups = layer_plan(ctx.arch)
    a = ctx.attn_cfg
    B, F, _ = enc_out.shape

    def kv(p_cross, stacked: bool):
        eq = "bfd,gdk->gbfk" if stacked else "bfd,dk->bfk"
        k = jnp.einsum(eq, enc_out, p_cross["wk"])
        v = jnp.einsum(eq, enc_out, p_cross["wv"])
        shp = ((n_groups, B, F, a.num_kv_heads, a.head_dim) if stacked
               else (B, F, a.num_kv_heads, a.head_dim))
        return k.reshape(shp), v.reshape(shp)

    cache = jax.tree_util.tree_map(lambda x: x, cache)  # shallow copy
    for i, sub in enumerate(prefix):
        if sub.cross:
            k, v = kv(params[f"prefix{i}"]["cross"], stacked=False)
            cache[f"prefix{i}"]["cross_k"] = k.astype(a.dtype)
            cache[f"prefix{i}"]["cross_v"] = v.astype(a.dtype)
    for j, sub in enumerate(group):
        if sub.cross:
            k, v = kv(params["groups"][f"sub{j}"]["cross"], stacked=True)
            cache["groups"][f"sub{j}"]["cross_k"] = k.astype(a.dtype)
            cache["groups"][f"sub{j}"]["cross_v"] = v.astype(a.dtype)
    return cache


# ---------------------------------------------------------------------------
# slot-indexed cache ops (continuous-batching serving)
# ---------------------------------------------------------------------------
#
# The cache pytree has two batch layouts: top-level ``prefix{i}`` entries
# carry the batch on axis 0, the stacked ``groups`` entry carries it on
# axis 1 (axis 0 is the scanned layer-group axis).  The helpers below are
# the only place that layout knowledge lives.


def _map_batch_axis(cache, fn):
    """Apply ``fn(leaf, batch_axis)`` across the cache pytree."""
    out = {}
    for k, v in cache.items():
        axis = 1 if k == "groups" else 0
        out[k] = jax.tree_util.tree_map(lambda leaf: fn(leaf, axis), v)
    return out


def cache_insert_slots(dst, src, slots):
    """Write ``src`` (leading batch P) into ``dst`` (leading batch N) at
    ``slots`` [P].  Slot ids >= N are dropped (JAX scatter out-of-bounds
    semantics), which is how padded admission packs no-op: pad ``slots``
    with N and the extra rows never land."""
    def ins(d, s, axis):
        idx = (slice(None),) * axis + (slots,)
        return d.at[idx].set(s.astype(d.dtype))
    out = {}
    for k in dst:
        axis = 1 if k == "groups" else 0
        out[k] = jax.tree_util.tree_map(
            lambda d, s: ins(d, s, axis), dst[k], src[k])
    return out


def cache_evict_slots(cache, slots):
    """Zero every cache leaf at ``slots`` (pos included, so the slot reads
    as empty).  Not required before re-insertion — ``cache_insert_slots``
    overwrites a slot completely — but keeps freed slots inert and is the
    eviction half of the serving API."""
    def ev(leaf, axis):
        idx = (slice(None),) * axis + (slots,)
        return leaf.at[idx].set(jnp.zeros((), leaf.dtype))
    return _map_batch_axis(cache, ev)


def _select_batch(mask, new, old):
    """Per-request select between two cache pytrees: ``mask`` [B] picks
    ``new`` where True.  Used by the scan prefill to freeze a request's
    cache once its (right-padded) prompt is exhausted."""
    def sel(n, o, axis):
        shape = [1] * n.ndim
        shape[axis] = mask.shape[0]
        return jnp.where(mask.reshape(shape), n, o)
    out = {}
    for k in new:
        axis = 1 if k == "groups" else 0
        out[k] = jax.tree_util.tree_map(
            lambda n, o: sel(n, o, axis), new[k], old[k])
    return out


def _decode_sublayer(p, c, x, sub: SubLayer, ctx: ModelCtx, layer_idx=None):
    a = ctx.arch
    h = layers.norm_apply(p["norm1"], x, a.norm)
    if sub.mixer == "attn":
        mix, c["mixer"] = layers.attn_decode(p["mixer"], h, c["mixer"],
                                             ctx.attn_cfg)
    elif sub.mixer == "mla":
        mix, c["mixer"] = mla_lib.mla_decode(p["mixer"], h, c["mixer"],
                                             ctx.mla_cfg)
    elif sub.mixer == "mamba":
        mix, c["mixer"] = mamba_lib.mamba_decode(p["mixer"], h, c["mixer"],
                                                 ctx.mamba_cfg)
    elif sub.mixer == "mlstm":
        mix, c["mixer"] = xlstm_lib.mlstm_decode(p["mixer"], h, c["mixer"],
                                                 ctx.xlstm_cfg)
    elif sub.mixer == "slstm":
        mix, c["mixer"] = xlstm_lib.slstm_decode(p["mixer"], h, c["mixer"],
                                                 ctx.xlstm_cfg)
    x = x + mix
    if sub.cross:
        h = layers.norm_apply(p["norm_cross"], x, a.norm)
        B = x.shape[0]
        cfg = ctx.attn_cfg
        q = (h @ p["cross"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
        out = layers._sdpa(q, c["cross_k"], c["cross_v"], causal=False,
                           sliding_window=0, q_positions=jnp.zeros((1,), int),
                           k_positions=jnp.arange(c["cross_k"].shape[1]))
        x = x + out.reshape(B, 1, -1) @ p["cross"]["wo"]
    if sub.ffn == "mlp":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        x = x + layers.mlp_apply(p["ffn"], h, a.activation)
    elif sub.ffn == "moe":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        y, _ = _moe_block(p["ffn"], h, ctx, decode=True, layer_idx=layer_idx)
        x = x + y
    return x, c


def decode_step(params, cache, tokens, ctx: ModelCtx):
    """tokens: [B, 1] — returns (logits [B, 1, V], new_cache)."""
    a = ctx.arch
    prefix, group, n_groups = layer_plan(a)
    x = layers.embed_apply(params["embed"], tokens)
    if not ctx.decode_replicated:
        x = sharding.constrain(x, "batch", None, None)

    new_cache = {}
    for i, sub in enumerate(prefix):
        x, new_cache[f"prefix{i}"] = _decode_sublayer(
            params[f"prefix{i}"], dict(cache[f"prefix{i}"]), x, sub, ctx,
            layer_idx=i)

    n_prefix = len(prefix)
    if _overrides_hit_groups(ctx, n_prefix, group, n_groups, decode=True):
        # layer-dependent dispatch inside the groups: unroll (mirrors
        # transformer.forward_features) and restack the per-group caches.
        new_gs = []
        for g in range(n_groups):
            pg = jax.tree_util.tree_map(lambda a, g=g: a[g], params["groups"])
            cg = jax.tree_util.tree_map(lambda a, g=g: a[g], cache["groups"])
            for j, sub in enumerate(group):
                x, cg[f"sub{j}"] = _decode_sublayer(
                    pg[f"sub{j}"], dict(cg[f"sub{j}"]), x, sub, ctx,
                    layer_idx=n_prefix + g * len(group) + j)
            new_gs.append(cg)
        new_cache["groups"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_gs)
    else:
        def body(x, pc):
            p, c = pc
            c = jax.tree_util.tree_map(lambda v: v, c)  # shallow copy
            for j, sub in enumerate(group):
                x, c[f"sub{j}"] = _decode_sublayer(
                    p[f"sub{j}"], dict(c[f"sub{j}"]), x, sub, ctx)
            return x, c

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))
        new_cache["groups"] = new_groups
    x = layers.norm_apply(params["final_norm"], x, a.norm)
    logits = layers.unembed_apply(params["embed"], x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# fused prefill: full-sequence forward that materializes the decode cache
# ---------------------------------------------------------------------------


def _needs_scan_prefill(arch) -> bool:
    """Recurrent mixers (mamba/xlstm) and cross-attention decoders carry
    per-step state the full-sequence applies do not expose, so those
    families prefill by scanning ``decode_step`` (still one fused XLA call,
    just sequential over time)."""
    prefix, group, _ = layer_plan(arch)
    return any(sub.mixer not in ("attn", "mla") or sub.cross
               for sub in list(prefix) + list(group))


def _prefill_sublayer(p, c, x, sub: SubLayer, ctx: ModelCtx, lens,
                      layer_idx=None):
    """Full-sequence sublayer forward that also writes the decode cache:
    K/V (attn) or the compressed latent entries (mla) for positions
    [0, S), with ``pos`` set to each request's true prompt length so
    right-padded rows are never attended."""
    a = ctx.arch
    S = x.shape[1]
    h = layers.norm_apply(p["norm1"], x, a.norm)
    if sub.mixer == "attn":
        mix, (k, v) = layers.attn_apply(p["mixer"], h, ctx.attn_cfg)
        c["mixer"] = {
            "k": jnp.asarray(c["mixer"]["k"]).at[:, :S].set(
                k.astype(c["mixer"]["k"].dtype)),
            "v": jnp.asarray(c["mixer"]["v"]).at[:, :S].set(
                v.astype(c["mixer"]["v"].dtype)),
            "pos": lens,
        }
    elif sub.mixer == "mla":
        mix, entry = mla_lib.mla_apply(p["mixer"], h, ctx.mla_cfg)
        c["mixer"] = {
            "c_kv": jnp.asarray(c["mixer"]["c_kv"]).at[:, :S].set(
                entry["c_kv"].astype(c["mixer"]["c_kv"].dtype)),
            "k_rope": jnp.asarray(c["mixer"]["k_rope"]).at[:, :S].set(
                entry["k_rope"].astype(c["mixer"]["k_rope"].dtype)),
            "pos": lens,
        }
    else:  # _needs_scan_prefill routes recurrent mixers away from here
        raise ValueError(f"fused prefill cannot cache mixer {sub.mixer!r}")
    x = x + mix
    if sub.ffn == "mlp":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        x = x + layers.mlp_apply(p["ffn"], h, a.activation)
    elif sub.ffn == "moe":
        # decode=True: the weights-stationary gather path computes every
        # token independently (no capacity drops), so a packed prefill is
        # exactly equivalent to prefilling each request alone — the
        # property the continuous-batching tests pin.
        h = layers.norm_apply(p["norm2"], x, a.norm)
        y, _ = _moe_block(p["ffn"], h, ctx, decode=True, layer_idx=layer_idx)
        x = x + y
    return x, c


def _prefill_by_scan(params, batch, cache, ctx: ModelCtx, lens):
    """Prefill fallback for recurrent/cross families: one ``lax.scan`` of
    ``decode_step`` over the prompt.  Per-request cache updates freeze once
    t >= lens[b], so right padding cannot corrupt recurrent state."""
    tokens = batch["tokens"]
    B, S = tokens.shape

    def body(carry, inp):
        cache, last = carry
        tok, t = inp
        logits, new_cache = decode_step(params, cache, tok[:, None], ctx)
        active = t < lens
        cache = _select_batch(active, new_cache, cache)
        last = jnp.where((t == lens - 1)[:, None], logits[:, 0], last)
        return (cache, last), None

    last0 = jnp.zeros((B, ctx.arch.vocab_size), jnp.float32)
    (cache, last), _ = jax.lax.scan(
        body, (cache, last0), (tokens.T, jnp.arange(S)))
    return last, cache


def prefill(params, batch, ctx: ModelCtx, *, cache_len: int, lens=None):
    """Fused prefill: full-sequence forward over right-padded prompts that
    materializes the decode cache in one pass.

    batch: {"tokens": [B, S], optional "frontend"}; ``lens`` [B] gives each
    request's true prompt length (default S).  Returns
    ``(last_logits [B, V], cache)`` — the logits at each request's final
    prompt position (the distribution of its first generated token) and a
    cache of length ``cache_len`` with ``pos == lens``.

    Attention/MLA families run the parallel forward and write K/V (or the
    compressed latents) directly; recurrent and cross-attention families
    fall back to a scanned ``decode_step`` (see ``_needs_scan_prefill``).
    MoE sublayers go through the decode-default ``gather`` path, which is
    drop-free and per-token independent — a packed prefill therefore
    equals a sequence of single-request prefills.
    """
    a = ctx.arch
    tokens = batch["tokens"]
    B, S = tokens.shape
    if S > cache_len:
        raise ValueError(f"prompt length {S} exceeds cache_len {cache_len}")
    if lens is None:
        lens = jnp.full((B,), S, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    cache = init_cache(ctx, B, cache_len)

    if a.family == "audio" and "frontend" in batch:
        from repro.models.transformer import _run_encoder
        enc_out = _run_encoder(params, batch["frontend"].astype(a.jnp_dtype),
                               ctx)
        cache = fill_cross_cache(params, cache, enc_out, ctx)

    if _needs_scan_prefill(a):
        return _prefill_by_scan(params, batch, cache, ctx, lens)

    prefix, group, n_groups = layer_plan(a)
    x = layers.embed_apply(params["embed"], tokens)
    if a.family == "vlm" and "frontend" in batch:
        patches = jax.nn.gelu(batch["frontend"].astype(x.dtype)
                              @ params["proj"]["w1"]) @ params["proj"]["w2"]
        n = patches.shape[1]
        x = jnp.concatenate([patches, x[:, n:]], axis=1)
    if not ctx.decode_replicated:
        x = sharding.constrain(x, "batch", None, None)

    new_cache = {}
    for i, sub in enumerate(prefix):
        x, new_cache[f"prefix{i}"] = _prefill_sublayer(
            params[f"prefix{i}"], dict(cache[f"prefix{i}"]), x, sub, ctx,
            lens, layer_idx=i)

    n_prefix = len(prefix)
    if _overrides_hit_groups(ctx, n_prefix, group, n_groups, decode=True):
        new_gs = []
        for g in range(n_groups):
            pg = jax.tree_util.tree_map(lambda a, g=g: a[g], params["groups"])
            cg = jax.tree_util.tree_map(lambda a, g=g: a[g], cache["groups"])
            for j, sub in enumerate(group):
                x, cg[f"sub{j}"] = _prefill_sublayer(
                    pg[f"sub{j}"], dict(cg[f"sub{j}"]), x, sub, ctx, lens,
                    layer_idx=n_prefix + g * len(group) + j)
            new_gs.append(cg)
        new_cache["groups"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_gs)
    else:
        def body(x, pc):
            p, c = pc
            c = jax.tree_util.tree_map(lambda v: v, c)  # shallow copy
            for j, sub in enumerate(group):
                x, c[f"sub{j}"] = _prefill_sublayer(
                    p[f"sub{j}"], dict(c[f"sub{j}"]), x, sub, ctx, lens)
            return x, c

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))
        new_cache["groups"] = new_groups

    x = layers.norm_apply(params["final_norm"], x, a.norm)
    logits = layers.unembed_apply(params["embed"], x)
    last = jnp.take_along_axis(
        logits.astype(jnp.float32),
        jnp.maximum(lens - 1, 0)[:, None, None], axis=1)[:, 0]
    return last, new_cache
