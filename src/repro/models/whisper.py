"""Whisper audio frontend STUB (sanctioned carve-out).

The real Whisper front end is log-mel spectrogram + 2 strided Conv1d
blocks: 30 s of 16 kHz audio -> 1500 frames of d_model features.  Per the
assignment, the modality frontend is a stub: ``frame_spec``/``make_frames``
provide precomputed frame embeddings of exactly that shape; the
encoder-decoder transformer backbone (models/transformer.py, family
"audio") consumes them.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FRAMES_PER_CLIP = 1500    # 30 s at 50 Hz post-conv


def frame_shape(batch: int, arch) -> tuple:
    return (batch, arch.frontend_len or FRAMES_PER_CLIP, arch.d_model)


def make_frames(rng: np.random.Generator, batch: int, arch) -> jnp.ndarray:
    """Deterministic stand-in frame embeddings (unit-variance)."""
    return jnp.asarray(
        rng.standard_normal(frame_shape(batch, arch)).astype(np.float32))
