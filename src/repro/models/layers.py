"""Shared transformer building blocks (pure-functional JAX).

Conventions: params are plain dict pytrees; ``init_*`` builds them,
``*_apply`` consumes them.  Activations default to bf16, norms/softmax in
f32.  Sharding is applied by the caller (pjit constraints / param specs) —
these functions are mesh-agnostic except where noted.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _norm_init(key, shape, scale):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int):
    if kind == "nonparam_ln":      # OLMo: LayerNorm without scale/bias
        return {}
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    raise ValueError(kind)


def norm_apply(params, x, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        y = y * params["scale"]
    else:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 1e4
    sliding_window: int = 0      # 0 = full attention
    causal: bool = True
    qkv_bias: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    use_flash_kernel: bool = False
    use_blockwise: bool = False      # flash-style jnp path (dry-run perf)


def init_attn(key, cfg: AttnConfig):
    ks = jax.random.split(key, 4)
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": _norm_init(ks[0], (d, H * hd), s).astype(cfg.dtype),
        "wk": _norm_init(ks[1], (d, K * hd), s).astype(cfg.dtype),
        "wv": _norm_init(ks[2], (d, K * hd), s).astype(cfg.dtype),
        "wo": _norm_init(ks[3], (H * hd, d), 1.0 / np.sqrt(H * hd)).astype(cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((K * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((K * hd,), cfg.dtype)
    return p


def _qkv(params, x, cfg: AttnConfig):
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, K, hd),
            v.reshape(B, S, K, hd))


def _sdpa(q, k, v, *, causal, sliding_window, q_positions, k_positions):
    """Reference scaled-dot-product attention with GQA + optional window.

    q: [B, Sq, H, hd]; k/v: [B, Sk, K, hd]. Returns [B, Sq, H, hd].
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32) / np.sqrt(hd)
    qg = qf.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_positions[:, None] >= k_positions[None, :]
    if sliding_window:
        mask &= (q_positions[:, None] - k_positions[None, :]) < sliding_window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _blockwise_sdpa(q, k, v, *, causal, sliding_window, block_k: int = 1024):
    """Flash-style online-softmax attention in pure jnp: never materializes
    the [Sq, Sk] score matrix — memory is O(Sq * block_k).  This is the
    HLO-level analogue of kernels/flash_attn for the dry-run (Pallas cannot
    lower on the CPU backend); on TPU the Pallas kernel takes over.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    hdv = v.shape[-1]                 # may differ from hd (MLA)
    G = H // K
    bk = min(block_k, Sk)
    nk = -(-Sk // bk)
    qf = (q.astype(jnp.float32) / np.sqrt(hd)).reshape(B, Sq, K, G, hd)
    kp = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, nk * bk - Sk),
                                         (0, 0), (0, 0)))
    vp = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, nk * bk - Sk),
                                         (0, 0), (0, 0)))
    kb = kp.reshape(B, nk, bk, K, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, bk, K, hdv).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq)

    def step(carry, inp):
        o, m, l, j = carry
        kj, vj = inp
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kj)      # [B,K,G,Sq,bk]
        kpos = j * bk + jnp.arange(bk)
        mask = kpos[None, :] < Sk
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if sliding_window:
            mask &= (qpos[:, None] - kpos[None, :]) < sliding_window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]),
                      0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        o = o * alpha[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, vj)
        return (o, m_new, l, j + 1), None

    o0 = jnp.zeros((B, K, G, Sq, hdv), jnp.float32)
    m0 = jnp.full((B, K, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    (o, m, l, _), _ = jax.lax.scan(step, (o0, m0, l0, jnp.int32(0)),
                                   (kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    o = (o / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hdv)
    return o.astype(q.dtype)


def attn_apply(params, x, cfg: AttnConfig, positions=None):
    """Full-sequence (train / prefill) attention. x: [B, S, d]."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    if positions is None:
        positions = jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_flash_kernel:
        from repro.kernels.flash_attn import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=cfg.causal,
                                     sliding_window=cfg.sliding_window)
    elif cfg.use_blockwise:
        out = _blockwise_sdpa(q, k, v, causal=cfg.causal,
                              sliding_window=cfg.sliding_window)
    else:
        out = _sdpa(q, k, v, causal=cfg.causal,
                    sliding_window=cfg.sliding_window,
                    q_positions=positions, k_positions=positions)
    return out.reshape(B, S, -1) @ params["wo"], (k, v)


def attn_decode(params, x, cache, cfg: AttnConfig):
    """Single-token decode vs a KV cache.

    x: [B, 1, d]; cache: {"k": [B, L, K, hd], "v": ..., "pos": [B]}.
    The cache position axis may be sharded (context parallelism) — the
    softmax reductions lower to collectives under pjit automatically.
    """
    B = x.shape[0]
    q, k_new, v_new = _qkv(params, x, cfg)
    pos = cache["pos"]  # [B] current length
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
    L = cache["k"].shape[1]
    idx = pos  # write position
    k = jax.lax.select(
        jnp.ones((), bool),
        jnp.asarray(cache["k"]).at[jnp.arange(B), idx].set(k_new[:, 0]),
        cache["k"])
    v = jnp.asarray(cache["v"]).at[jnp.arange(B), idx].set(v_new[:, 0])
    k_positions = jnp.arange(L)
    valid = k_positions[None, :] <= pos[:, None]          # [B, L]
    if cfg.sliding_window:
        valid &= (pos[:, None] - k_positions[None, :]) < cfg.sliding_window
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    qg = (q.astype(jnp.float32) / np.sqrt(hd)).reshape(B, K, G, hd)
    logits = jnp.einsum("bkgh,blkh->bkgl", qg, k.astype(jnp.float32))
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgl,blkh->bkgh", w, v.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    new_cache = {"k": k, "v": v, "pos": pos + 1}
    return out @ params["wo"], new_cache


def init_kv_cache(batch: int, max_len: int, cfg: AttnConfig):
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, K, hd), cfg.dtype),
            "v": jnp.zeros((batch, max_len, K, hd), cfg.dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    s1, s2 = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {"w_in": _norm_init(ks[0], (d, f), s1).astype(dtype),
         "w_out": _norm_init(ks[1], (f, d), s2).astype(dtype)}
    if activation == "swiglu":
        p["w_gate"] = _norm_init(ks[2], (d, f), s1).astype(dtype)
    return p


def mlp_apply(params, x, activation: str):
    if activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_in"])
    else:
        h = jax.nn.gelu(x @ params["w_in"])
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, dtype):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32)
            .astype(dtype) * 0.02}


def embed_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed_apply(params, x):
    # logits in f32 for a stable softmax-xent
    return x.astype(jnp.float32) @ params["table"].T.astype(jnp.float32)
