"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan with exponential gating).

xlstm-350m interleaves them 7:1 (seven mLSTM blocks then one sLSTM block).
mLSTM train/prefill uses the parallel quadratic formulation (stabilized
exponential gating); decode keeps the (C, n, m) recurrent state — constant
memory per step, which is what makes long_500k feasible for this family.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    num_heads: int = 4
    proj_factor: float = 2.0      # mLSTM up-projection
    slstm_every: int = 8          # one sLSTM per this many blocks
    dtype: jnp.dtype = jnp.bfloat16
    chunk_size: int = 0           # >0: chunkwise mLSTM (O(S·C) instead of
                                  # the O(S²) parallel D-matrix; §Perf)

    @property
    def d_inner(self):
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self):
        return self.d_inner // self.num_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: XLSTMConfig):
    ks = jax.random.split(key, 8)
    d, di, H, hd = cfg.d_model, cfg.d_inner, cfg.num_heads, cfg.head_dim
    s, si = 1 / np.sqrt(d), 1 / np.sqrt(di)
    return {
        "w_up": layers._norm_init(ks[0], (d, 2 * di), s).astype(cfg.dtype),
        "wq": layers._norm_init(ks[1], (di, di), si).astype(cfg.dtype),
        "wk": layers._norm_init(ks[2], (di, di), si).astype(cfg.dtype),
        "wv": layers._norm_init(ks[3], (di, di), si).astype(cfg.dtype),
        "w_if": layers._norm_init(ks[4], (di, 2 * H), si).astype(cfg.dtype),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.full((H,), 3.0)]
                                ).astype(jnp.float32),
        "ln": {"scale": jnp.ones((cfg.head_dim,), jnp.float32)},
        "w_down": layers._norm_init(ks[5], (di, d), si).astype(cfg.dtype),
    }


def _mlstm_gates(params, xu, H):
    g = (xu @ params["w_if"]).astype(jnp.float32) + params["b_if"]
    i_pre, f_pre = g[..., :H], g[..., H:]          # [B,S,H]
    logf = jax.nn.log_sigmoid(f_pre)
    return i_pre, logf


def mlstm_apply(params, x, cfg: XLSTMConfig):
    """Parallel (quadratic) mLSTM. x: [B,S,d]."""
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    up = x @ params["w_up"]
    xu, z = jnp.split(up, 2, axis=-1)              # [B,S,di] each
    q = (xu @ params["wq"]).reshape(B, S, H, hd)
    k = (xu @ params["wk"]).reshape(B, S, H, hd) / np.sqrt(hd)
    v = (xu @ params["wv"]).reshape(B, S, H, hd)

    i_pre, logf = _mlstm_gates(params, xu, H)      # [B,S,H]
    ck = cfg.chunk_size
    if ck and ck < S and S % ck == 0:
        num, den, m_t = _mlstm_chunkwise(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), i_pre, logf, ck)
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        y = num / denom
    else:
        F = jnp.cumsum(logf, axis=1)               # sum of log f up to t
        # D[t, s] = exp(F_t - F_s + i_s - m_t) for s <= t (stabilized)
        dmat = (F[:, :, None, :] - F[:, None, :, :]
                + i_pre[:, None, :, :])            # [B, t, s, H]
        tri = jnp.tril(jnp.ones((S, S), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m = jnp.max(dmat, axis=2, keepdims=True)   # [B,t,1,H]
        dexp = jnp.exp(dmat - m)                   # stabilizer
        logits = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                            k.astype(jnp.float32))
        w = logits * dexp
        denom = jnp.maximum(jnp.abs(jnp.sum(w, axis=2, keepdims=True)),
                            jnp.exp(-m))           # [B,t,1,H]
        y = jnp.einsum("btsh,bshd->bthd", w / denom, v.astype(jnp.float32))
    y = layers.norm_apply(params["ln"], y, "rmsnorm").reshape(B, S, -1)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_down"]


def _mlstm_chunkwise(q, k, v, i_pre, logf, chunk: int):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + inter-chunk
    recurrent (C, n, m) carry — the same stabilized exponential-gating math
    as the parallel form, with memory O(S·chunk) instead of O(S²).

    q,k,v: [B,S,H,hd] (k pre-scaled by 1/sqrt(hd)); i_pre/logf: [B,S,H].
    Returns the un-normalized numerator/denominator pair as [B,S,H,hd]/[B,S,H].
    """
    B, S, H, hd = q.shape
    nc = S // chunk
    ck = chunk

    def split(t):
        return t.reshape(B, nc, ck, *t.shape[2:]).transpose(1, 0, 2, 3, 4) \
            if t.ndim == 4 else \
            t.reshape(B, nc, ck, t.shape[-1]).transpose(1, 0, 2, 3)

    qc, kc, vc = split(q), split(k), split(v)       # [nc,B,ck,H,hd]
    ic, fc = split(i_pre), split(logf)              # [nc,B,ck,H]

    def chunk_step(carry, inp):
        C0, n0, m0 = carry                          # [B,H,hd,hd],[B,H,hd],[B,H]
        qj, kj, vj, ij, fj = inp
        F = jnp.cumsum(fj, axis=1)                  # [B,ck,H]
        # intra-chunk decay matrix: F_t - F_s + i_s (s <= t)
        dmat = (F[:, :, None, :] - F[:, None, :, :] + ij[:, None, :, :])
        tri = jnp.tril(jnp.ones((ck, ck), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)             # [B,ck,H]
        m_inter = F + m0[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        logits = jnp.einsum("bthd,bshd->btsh", qj, kj)
        num = jnp.einsum("btsh,bshd->bthd", logits * dexp, vj)
        den = jnp.sum(logits * dexp, axis=2)        # [B,ck,H]
        # inter-chunk contribution from carried state
        w_inter = jnp.exp(m_inter - m_t)            # [B,ck,H]
        num = num + w_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qj, C0)
        den = den + w_inter * jnp.einsum("bthd,bhd->bth", qj, n0)
        # carry update to chunk end (t = ck)
        F_T = F[:, -1:, :]                          # [B,1,H]
        g = F_T - F + ij                            # [B,ck,H]
        m_up = jnp.maximum(F_T[:, 0] + m0, jnp.max(g, axis=1))   # [B,H]
        wk = jnp.exp(g - m_up[:, None, :])          # [B,ck,H]
        C_new = (jnp.exp(F_T[:, 0] + m0 - m_up)[..., None, None] * C0
                 + jnp.einsum("bsh,bshd,bshe->bhde", wk, kj, vj))
        n_new = (jnp.exp(F_T[:, 0] + m0 - m_up)[..., None] * n0
                 + jnp.einsum("bsh,bshd->bhd", wk, kj))
        return (C_new, n_new, m_up), (num, den, m_t)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, (num, den, m_t) = jax.lax.scan(chunk_step, (C0, n0, m0),
                                      (qc, kc, vc, ic, fc))
    merge = lambda t: t.transpose(1, 0, 2, 3, 4).reshape(B, S, *t.shape[3:]) \
        if t.ndim == 5 else t.transpose(1, 0, 2, 3).reshape(B, S, t.shape[-1])
    return merge(num), merge(den), merge(m_t)


def init_mlstm_state(batch: int, cfg: XLSTMConfig):
    H, hd = cfg.num_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def mlstm_decode(params, x, state, cfg: XLSTMConfig):
    """Recurrent step. x: [B,1,d]."""
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim
    up = x @ params["w_up"]
    xu, z = jnp.split(up, 2, axis=-1)
    q = (xu @ params["wq"]).reshape(B, H, hd).astype(jnp.float32)
    k = ((xu @ params["wk"]).reshape(B, H, hd) / np.sqrt(hd)).astype(jnp.float32)
    v = (xu @ params["wv"]).reshape(B, H, hd).astype(jnp.float32)
    i_pre, logf = _mlstm_gates(params, xu, H)
    i_pre, logf = i_pre[:, 0], logf[:, 0]          # [B,H]
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fg = jnp.exp(logf + state["m"] - m_new)[..., None]
    ig = jnp.exp(i_pre - m_new)[..., None]
    C = fg[..., None] * state["C"] + ig[..., None] * (k[..., None] * v[..., None, :])
    n = fg * state["n"] + ig * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    y = layers.norm_apply(params["ln"], num / den, "rmsnorm")
    y = y.reshape(B, 1, -1).astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_down"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: XLSTMConfig):
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    s = 1 / np.sqrt(d)
    return {
        "w_gates": layers._norm_init(ks[0], (d, 4 * d), s).astype(cfg.dtype),
        "r_gates": layers._norm_init(ks[1], (H, hd, 4 * hd),
                                     1 / np.sqrt(hd)).astype(jnp.float32),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "ln": {"scale": jnp.ones((d,), jnp.float32)},
        "w_out": layers._norm_init(ks[2], (d, d), s).astype(cfg.dtype),
    }


def slstm_apply(params, x, cfg: XLSTMConfig, state=None):
    """Sequential sLSTM over time. x: [B,S,d] -> ([B,S,d], state)."""
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    wx = (x @ params["w_gates"]).astype(jnp.float32) + params["b_gates"]
    wx = wx.reshape(B, S, 4, H, hd)

    if state is None:
        state = init_slstm_state(B, cfg)

    def step(carry, wx_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhd,hde->bhe", h, params["r_gates"])  # [B,H,4hd]
        rec = rec.reshape(B, H, 4, hd).transpose(0, 2, 1, 3)
        z_pre, i_pre, f_pre, o_pre = [wx_t[:, g] + rec[:, g] for g in range(4)]
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_pre) + m, i_pre)
        ig = jnp.exp(i_pre - m_new)
        fg = jnp.exp(jax.nn.log_sigmoid(f_pre) + m - m_new)
        zv = jnp.tanh(z_pre)
        og = jax.nn.sigmoid(o_pre)
        c_new = fg * c + ig * zv
        n_new = fg * n + ig
        h_new = og * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, hs = jax.lax.scan(step, carry, wx.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, S, d)       # [B,S,d]
    y = layers.norm_apply(params["ln"], hs, "rmsnorm").astype(x.dtype)
    new_state = dict(zip(("c", "n", "h", "m"), carry))
    return y @ params["w_out"], new_state


def init_slstm_state(batch: int, cfg: XLSTMConfig):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def slstm_decode(params, x, state, cfg: XLSTMConfig):
    y, new_state = slstm_apply(params, x, cfg, state)
    return y, new_state
