"""Composable decoder / encoder-decoder assembly over all mixer families.

A model is a (prefix, repeated group) layer plan; the repeated group is
initialized with ``vmap`` and executed with ``lax.scan`` so the HLO stays
small at 60-layer scale (critical for multi-pod compile times).  Sublayers:

    mixer: attn (GQA, optional sliding window, optional cross) | mla |
           mamba | mlstm | slstm
    ffn  : mlp | moe | None

MoE sublayers enter ``shard_map`` over the expert-parallel axes (see
core/dispatch/); dense compute relies on pjit sharding constraints
(repro.sharding.constrain).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs.base import ArchConfig
from repro.core import dispatch as dispatch_lib, gating
from repro.core.capacity import DispatchPlan
from repro.core.dispatch import base as moe_base
from repro.models import layers, mamba as mamba_lib, mla as mla_lib
from repro.models import xlstm as xlstm_lib


@dataclasses.dataclass(frozen=True)
class SubLayer:
    mixer: str                    # attn | mla | mamba | mlstm | slstm
    ffn: str | None            # mlp | moe | None
    cross: bool = False           # add cross-attention (whisper decoder)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Everything the forward pass needs besides params and data."""
    arch: ArchConfig
    mesh: object | None = None
    ep: moe_base.EPSpec | None = None
    plan: DispatchPlan | None = None          # level-indexed a2a capacities
    gate_cfg: gating.GateConfig | None = None
    use_flash: bool = False
    use_moe_kernel: bool = False
    remat: bool = False
    decode_replicated: bool = False              # long_500k batch=1
    # default MoE dispatch path (any name in the core.dispatch registry:
    # "a2a" | "a2a_pipelined" | "gather" | "einsum")
    dispatch: str = "a2a"
    a2a_num_chunks: int = 1                      # resolved by build_ctx
    # per-layer dispatch override: tuple of (global_layer_idx, path_name)
    # pairs.  Overrides on scanned group layers force the group loop to
    # unroll (the schedule becomes layer-dependent, so the HLO does too).
    dispatch_override: tuple = ()
    # moe_permute token-permutation kernels in the dispatch hot path:
    # None = auto (Pallas on TPU/GPU, jnp reference elsewhere)
    use_pallas: bool | None = None
    # perf flags (see EXPERIMENTS.md §Perf) — default off = paper baseline
    use_blockwise: bool = False                  # flash-style attention HLO
    fused_xent: bool = False                     # vocab-sharded xent
    a2a_dtype: str = ""                          # deprecated: use wire_codec
    wire_codec: object = None                    # a2a wire codec (a
                                                 # core.dispatch.wire codec or
                                                 # registered name) — payload
                                                 # encoding + scale sideband
    mamba_scan_chunk: int = 0                    # chunked selective scan
    xlstm_chunk: int = 0                         # chunkwise mLSTM
    resilience: object | None = None             # ResilienceConfig (guards,
                                                 # recovery policy, chaos) —
                                                 # carried for the guarded
                                                 # step factory; None = the
                                                 # classic unguarded loop

    @property
    def attn_cfg(self):
        a = self.arch
        return layers.AttnConfig(
            d_model=a.d_model, num_heads=a.num_heads,
            num_kv_heads=a.num_kv_heads, head_dim=a.head_dim_,
            rope_theta=a.rope_theta, sliding_window=a.sliding_window,
            qkv_bias=a.qkv_bias, dtype=a.jnp_dtype,
            use_flash_kernel=self.use_flash,
            use_blockwise=self.use_blockwise)

    @property
    def mla_cfg(self):
        a = self.arch
        m = a.mla
        return mla_lib.MLAConfig(
            d_model=a.d_model, num_heads=a.num_heads,
            kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
            qk_rope_dim=m.qk_rope_dim, v_dim=m.v_dim,
            q_lora_rank=m.q_lora_rank, rope_theta=a.rope_theta,
            dtype=a.jnp_dtype, use_blockwise=self.use_blockwise)

    @property
    def mamba_cfg(self):
        return mamba_lib.MambaConfig(d_model=self.arch.d_model,
                                     dtype=self.arch.jnp_dtype,
                                     scan_chunk=self.mamba_scan_chunk)

    @property
    def xlstm_cfg(self):
        a = self.arch
        return xlstm_lib.XLSTMConfig(d_model=a.d_model, num_heads=a.num_heads,
                                     slstm_every=a.slstm_every or 8,
                                     dtype=a.jnp_dtype,
                                     chunk_size=self.xlstm_chunk)

    @property
    def moe_cfg(self):
        a = self.arch
        return moe_base.MoEConfig(
            d_model=a.d_model, d_ff=a.moe.d_ff_expert,
            num_experts=a.moe.num_experts, top_k=a.moe.top_k,
            capacity_factor=a.moe.capacity_factor,
            num_shared_experts=a.moe.num_shared_experts,
            activation=a.activation, dtype=a.jnp_dtype,
            use_kernel=self.use_moe_kernel, a2a_dtype=self.a2a_dtype,
            wire_codec=self.wire_codec)

    @property
    def frac_levels(self) -> int:
        """Length of the ``frac_by_level`` metric vector (dispatch stages
        of the EP hierarchy; 1 when the model has no MoE layers)."""
        if self.plan is not None:
            return self.plan.num_stages
        if self.ep is not None:
            return self.ep.num_stages
        return 1

    def dispatch_for_layer(self, layer_idx: int | None,
                           decode: bool = False) -> str:
        """Dispatch path name for one layer: the per-layer override when
        present, else the mode default (decode steps default to the
        weights-stationary gather path)."""
        default = "gather" if decode else self.dispatch
        if layer_idx is None:
            return default
        return dict(self.dispatch_override).get(layer_idx, default)


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------


def layer_plan(arch: ArchConfig):
    """Returns (prefix: [SubLayer], group: [SubLayer], n_groups)."""
    if arch.family == "ssm" and arch.ssm_kind == "xlstm":
        g = arch.slstm_every or 8
        group = [SubLayer("slstm" if j == g - 1 else "mlstm", None)
                 for j in range(g)]
        return [], group, arch.num_layers // g

    if arch.family == "hybrid":           # jamba
        g = arch.attn_every
        group = []
        for j in range(g):
            mixer = "attn" if j == arch.attn_offset else "mamba"
            ffn = "moe" if (arch.moe and j % arch.moe.moe_period
                            == arch.moe.moe_period - 1) else "mlp"
            group.append(SubLayer(mixer, ffn))
        return [], group, arch.num_layers // g

    mixer = "mla" if arch.mla else "attn"
    if arch.is_moe:
        prefix = [SubLayer(mixer, "mlp")] * arch.moe.first_dense
        group = [SubLayer(mixer, "moe")]
        return prefix, group, arch.num_layers - arch.moe.first_dense
    # dense / vlm / audio decoder
    cross = arch.family == "audio"
    group = [SubLayer(mixer, "mlp", cross=cross)]
    return [], group, arch.num_layers


def encoder_plan(arch: ArchConfig):
    return [SubLayer("attn", "mlp", causal=False)], arch.enc_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sublayer(key, sub: SubLayer, ctx: ModelCtx):
    a = ctx.arch
    ks = jax.random.split(key, 6)
    p = {"norm1": layers.init_norm(a.norm, a.d_model)}
    if sub.mixer == "attn":
        p["mixer"] = layers.init_attn(ks[0], ctx.attn_cfg)
    elif sub.mixer == "mla":
        p["mixer"] = mla_lib.init_mla(ks[0], ctx.mla_cfg)
    elif sub.mixer == "mamba":
        p["mixer"] = mamba_lib.init_mamba(ks[0], ctx.mamba_cfg)
    elif sub.mixer == "mlstm":
        p["mixer"] = xlstm_lib.init_mlstm(ks[0], ctx.xlstm_cfg)
    elif sub.mixer == "slstm":
        p["mixer"] = xlstm_lib.init_slstm(ks[0], ctx.xlstm_cfg)
    else:
        raise ValueError(sub.mixer)
    if sub.cross:
        p["norm_cross"] = layers.init_norm(a.norm, a.d_model)
        p["cross"] = layers.init_attn(ks[1], ctx.attn_cfg)
    if sub.ffn == "mlp":
        p["norm2"] = layers.init_norm(a.norm, a.d_model)
        p["ffn"] = layers.init_mlp(ks[2], a.d_model, a.d_ff, a.activation,
                                   a.jnp_dtype)
    elif sub.ffn == "moe":
        p["norm2"] = layers.init_norm(a.norm, a.d_model)
        p["ffn"] = moe_base.init_moe_params(ks[2], ctx.moe_cfg, ctx.ep,
                                           ctx.gate_cfg)
    return p


def _init_group(key, group, ctx: ModelCtx):
    ks = jax.random.split(key, len(group))
    return {f"sub{j}": _init_sublayer(ks[j], sub, ctx)
            for j, sub in enumerate(group)}


def init_model(key, ctx: ModelCtx):
    a = ctx.arch
    prefix, group, n_groups = layer_plan(a)
    keys = jax.random.split(key, 8 + len(prefix))
    params = {"embed": layers.init_embed(keys[0], a.vocab_size, a.d_model,
                                         a.jnp_dtype),
              "final_norm": layers.init_norm(a.norm, a.d_model)}
    for i, sub in enumerate(prefix):
        params[f"prefix{i}"] = _init_sublayer(keys[8 + i], sub, ctx)
    gkeys = jax.random.split(keys[1], n_groups)
    params["groups"] = jax.vmap(lambda k: _init_group(k, group, ctx))(gkeys)
    if a.frontend == "vision":
        # 2-layer projector from the (stub) vision encoder width to d_model
        pk = jax.random.split(keys[2], 2)
        params["proj"] = {
            "w1": layers._norm_init(pk[0], (1024, a.d_model),
                                    1 / np.sqrt(1024)).astype(a.jnp_dtype),
            "w2": layers._norm_init(pk[1], (a.d_model, a.d_model),
                                    1 / np.sqrt(a.d_model)).astype(a.jnp_dtype),
        }
    if a.enc_layers:
        esub, n_enc = encoder_plan(a)
        ekeys = jax.random.split(keys[3], n_enc)
        params["enc_groups"] = jax.vmap(
            lambda k: _init_group(k, esub, ctx))(ekeys)
        params["enc_norm"] = layers.init_norm(a.norm, a.d_model)
    return params


# ---------------------------------------------------------------------------
# MoE via shard_map
# ---------------------------------------------------------------------------


def _tree_specs_default(tree, special: dict):
    from jax.sharding import PartitionSpec as P

    def path_str(path):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)

    def assign(path, leaf):
        return special.get(path_str(path), P())
    return jax.tree_util.tree_map_with_path(assign, tree)


def _moe_block(p, x, ctx: ModelCtx, decode: bool, layer_idx=None):
    """x: [B, S, d] (global view). Returns (y, metrics).

    Resolves the layer's dispatch path through the core.dispatch engine
    registry (per-layer override via ``ctx.dispatch_override``); every path
    returns the same uniform metrics schema, so the out_specs never branch.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    ep, cfg, gate_cfg = ctx.ep, ctx.moe_cfg, ctx.gate_cfg
    mesh = ctx.mesh
    d = x.shape[-1]
    batch_axes = sharding.hierarchy_axes(mesh) if mesh is not None else ()
    replicated = ctx.decode_replicated
    name = ctx.dispatch_for_layer(layer_idx, decode)
    eng = dispatch_lib.make_engine(
        name, cfg=cfg, ep=ep, gate_cfg=gate_cfg, plan=ctx.plan,
        num_chunks=max(1, ctx.a2a_num_chunks),
        tokens_replicated=replicated and decode,
        use_pallas=ctx.use_pallas)

    def body(p_local, x_local):
        y, metrics = eng(p_local, x_local.reshape(-1, d))
        # average metrics over every mesh axis so outputs are replicated
        for ax in mesh.axis_names:
            metrics = {k: jax.lax.pmean(v, ax) for k, v in metrics.items()}
        return y.reshape(x_local.shape), metrics

    pspecs = moe_base.moe_param_specs(cfg, ep)
    pspecs = _merge_specs(p, pspecs)
    x_spec = (P() if replicated
              else P(batch_axes if len(batch_axes) > 1 else
                     (batch_axes[0] if batch_axes else None), None, None))
    fn = shard_map(body, mesh=mesh, in_specs=(pspecs, x_spec),
                   out_specs=(x_spec, _metric_specs()),
                   check_vma=False)
    return fn(p, x)


def _metric_specs():
    from jax.sharding import PartitionSpec as P
    return {k: P() for k in dispatch_lib.METRIC_KEYS}


def _merge_specs(params, partial_specs):
    """Full spec tree for the MoE params: known names from
    moe_param_specs, default replicated for the rest (gate, norms)."""
    from jax.sharding import PartitionSpec as P

    def assign(path, leaf):
        node = partial_specs
        for k in path:
            key = getattr(k, "key", None)
            if isinstance(node, dict) and key in node:
                node = node[key]
            else:
                return P()
        return node if isinstance(node, P) else P()
    return jax.tree_util.tree_map_with_path(assign, params)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_sublayer(p, x, sub: SubLayer, ctx: ModelCtx, *, enc_out=None,
                    aux0=0.0, frac0=None, drop0=None, layer_idx=None):
    """Returns (x, aux, frac, drop): the residual stream, the accumulated
    aux loss, the accumulated per-level dispatch-fraction vector, and the
    accumulated dropped-token fraction (``frac0`` / ``drop0`` passed
    through unchanged — possibly None — for non-MoE sublayers)."""
    a = ctx.arch
    h = layers.norm_apply(p["norm1"], x, a.norm)
    if sub.mixer == "attn":
        cfg = ctx.attn_cfg
        if not sub.causal:
            cfg = dataclasses.replace(cfg, causal=False)
        mix, _ = layers.attn_apply(p["mixer"], h, cfg)
    elif sub.mixer == "mla":
        mix, _ = mla_lib.mla_apply(p["mixer"], h, ctx.mla_cfg)
    elif sub.mixer == "mamba":
        mix = mamba_lib.mamba_apply(p["mixer"], h, ctx.mamba_cfg)
    elif sub.mixer == "mlstm":
        mix = xlstm_lib.mlstm_apply(p["mixer"], h, ctx.xlstm_cfg)
    elif sub.mixer == "slstm":
        mix, _ = xlstm_lib.slstm_apply(p["mixer"], h, ctx.xlstm_cfg)
    x = x + mix
    x = sharding.constrain(x, "batch", None, None)
    if sub.cross and enc_out is not None:
        h = layers.norm_apply(p["norm_cross"], x, a.norm)
        mix = _cross_attn(p["cross"], h, enc_out, ctx)
        x = x + mix
    aux = jnp.asarray(aux0, jnp.float32)
    frac = frac0
    drop = drop0
    if sub.ffn == "mlp":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        x = x + layers.mlp_apply(p["ffn"], h, a.activation)
    elif sub.ffn == "moe":
        h = layers.norm_apply(p["norm2"], x, a.norm)
        y, metrics = _moe_block(p["ffn"], h, ctx, decode=False,
                                layer_idx=layer_idx)
        x = x + y
        aux = aux + metrics["aux_loss"]
        if frac is not None:
            frac = frac + metrics["frac_by_level"]
        if drop is not None:
            drop = drop + metrics["dropped"]
    x = sharding.constrain(x, "batch", None, None)
    return x, aux, frac, drop


def _cross_attn(p, x, enc_out, ctx: ModelCtx):
    """Simple full cross-attention (whisper decoder)."""
    cfg = ctx.attn_cfg
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"]).reshape(B, enc_out.shape[1], K, hd)
    v = (enc_out @ p["wv"]).reshape(B, enc_out.shape[1], K, hd)
    out = layers._sdpa(q, k, v, causal=False, sliding_window=0,
                       q_positions=jnp.arange(S),
                       k_positions=jnp.arange(enc_out.shape[1]))
    return out.reshape(B, S, -1) @ p["wo"]


def _run_encoder(params, frames, ctx: ModelCtx):
    esub, n_enc = encoder_plan(ctx.arch)

    def body(x, p):
        x, _, _, _ = _apply_sublayer(p["sub0"], x, esub[0], ctx)
        return x, None
    x, _ = jax.lax.scan(body, frames, params["enc_groups"])
    return layers.norm_apply(params["enc_norm"], x, ctx.arch.norm)


def _overrides_hit_groups(ctx: ModelCtx, n_prefix: int, group, n_groups: int,
                          decode: bool = False) -> bool:
    """True when a per-layer dispatch override actually changes a scanned
    group layer's dispatch — only then is the unroll (and its n_groups-fold
    HLO growth) warranted.  Prefix overrides never force an unroll (that
    loop is already Python-level), and neither do out-of-range indices,
    overrides on non-MoE sublayers, or overrides equal to the default
    path."""
    default = ctx.dispatch_for_layer(None, decode)
    n_layers = n_prefix + n_groups * len(group)
    for idx, name in (ctx.dispatch_override or ()):
        if not (n_prefix <= idx < n_layers) or name == default:
            continue
        if group[(idx - n_prefix) % len(group)].ffn == "moe":
            return True
    return False


def forward_features(params, batch, ctx: ModelCtx):
    """Full-sequence forward up to the final norm.

    Returns ``(x, aux, frac_by_level, dropped)``: features, the mean aux
    loss, the mean per-level dispatch-fraction vector over the MoE layers,
    and the mean dropped-token fraction (the engine's uniform ``dropped``
    metric — the step-health watermark reads it).  The latter two are None
    for models without MoE layers.
    """
    a = ctx.arch
    prefix, group, n_groups = layer_plan(a)

    x = layers.embed_apply(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)

    enc_out = None
    if a.family == "audio":
        enc_out = _run_encoder(params, batch["frontend"].astype(x.dtype), ctx)
    elif a.family == "vlm" and "frontend" in batch:
        patches = jax.nn.gelu(batch["frontend"].astype(x.dtype)
                              @ params["proj"]["w1"]) @ params["proj"]["w2"]
        n = patches.shape[1]
        x = jnp.concatenate([patches, x[:, n:]], axis=1)

    aux = jnp.float32(0.0)
    n_moe = n_groups * sum(1 for s in group if s.ffn == "moe")
    frac = jnp.zeros((ctx.frac_levels,), jnp.float32) if n_moe else None
    drop = jnp.float32(0.0) if n_moe else None
    for i, sub in enumerate(prefix):
        x, aux, frac, drop = _apply_sublayer(
            params[f"prefix{i}"], x, sub, ctx, enc_out=enc_out, aux0=aux,
            frac0=frac, drop0=drop, layer_idx=i)

    n_prefix = len(prefix)
    if _overrides_hit_groups(ctx, n_prefix, group, n_groups):
        # a per-layer dispatch override lands inside the scanned groups:
        # the schedule is layer-dependent, so unroll the group loop (each
        # group gets its own HLO with its own dispatch path).
        def run_group(carry, pg, base_idx):
            x, aux, frac, drop = carry
            for j, sub in enumerate(group):
                x, aux, frac, drop = _apply_sublayer(
                    pg[f"sub{j}"], x, sub, ctx, enc_out=enc_out, aux0=aux,
                    frac0=frac, drop0=drop, layer_idx=base_idx + j)
            return x, aux, frac, drop
        if ctx.remat:
            run_group = jax.checkpoint(run_group, static_argnums=(2,),
                                       prevent_cse=False)
        for g in range(n_groups):
            pg = jax.tree_util.tree_map(lambda a, g=g: a[g], params["groups"])
            x, aux, frac, drop = run_group((x, aux, frac, drop), pg,
                                           n_prefix + g * len(group))
    else:
        def body(carry, p):
            x, aux, frac, drop = carry
            for j, sub in enumerate(group):
                x, aux, frac, drop = _apply_sublayer(
                    p[f"sub{j}"], x, sub, ctx, enc_out=enc_out, aux0=aux,
                    frac0=frac, drop0=drop)
            return (x, aux, frac, drop), None

        if ctx.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux, frac, drop), _ = jax.lax.scan(body, (x, aux, frac, drop),
                                               params["groups"])

    x = layers.norm_apply(params["final_norm"], x, a.norm)
    if frac is not None:
        frac = frac / max(1, n_moe)
    if drop is not None:
        drop = drop / max(1, n_moe)
    return x, aux / max(1, n_groups * len(group)), frac, drop


def forward(params, batch, ctx: ModelCtx):
    """Full-sequence forward (train / prefill). Returns (logits, aux)."""
    x, aux, _, _ = forward_features(params, batch, ctx)
    logits = layers.unembed_apply(params["embed"], x)
    logits = sharding.constrain(logits, "batch", None, "model")
    return logits, aux


def _fused_xent(params, x, labels, ctx: ModelCtx):
    """Vocab-sharded cross entropy without materializing f32 logits or
    gathering the vocabulary axis (perf flag; EXPERIMENTS.md §Perf.1).

    logits stay bf16 and sharded over "model"; the max / sum-exp / label
    reductions over the sharded vocab axis lower to small all-reduces
    instead of a [B,S,V] all-gather; take_along_axis is replaced by an
    iota==label masked sum (elementwise on the sharded operand).
    """
    table = params["embed"]["table"]                  # [V, d]
    logits = x @ table.T.astype(x.dtype)              # bf16 [B,S,V]
    logits = sharding.constrain(logits, "batch", None, "model")
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    V = table.shape[0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
              == labels[..., None])
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    return lse - label_logit                          # [B,S]


def loss_fn(params, batch, ctx: ModelCtx, aux_weight: float = 1.0):
    labels = batch["labels"]
    x, aux, frac, drop = forward_features(params, batch, ctx)
    if ctx.fused_xent:
        nll = _fused_xent(params, x, labels, ctx)
    else:
        logits = layers.unembed_apply(params["embed"], x)
        logits = sharding.constrain(logits, "batch", None, "model")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    nll = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = nll + aux_weight * aux
    metrics = {"nll": nll, "aux": aux, "loss": total}
    if frac is not None:
        # mean per-level dispatch fractions over the MoE layers — the
        # level-indexed replacement for the old frac_near/frac_far pair
        metrics["frac_by_level"] = frac
    if drop is not None:
        # mean dropped-assignment fraction over the MoE layers (the
        # engine's uniform `dropped` metric) — feeds the step-health
        # dropped-token watermark
        metrics["dropped"] = drop
    return total, metrics
