"""Vision frontend STUB for InternVL2 (sanctioned carve-out).

The real frontend is InternViT-6B (448px, pixel-shuffle to 256 tokens per
tile) + an MLP projector.  Per the assignment the ViT is a stub:
``patch_spec``/``make_patches`` provide 256 patch embeddings at the ViT
output width (1024); the in-model 2-layer projector
(models/transformer.py, params["proj"]) maps them into d_model and they
replace the first ``frontend_len`` token positions at prefill.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

VIT_WIDTH = 1024          # stubbed vision-encoder output width
PATCHES_PER_IMAGE = 256


def patch_shape(batch: int, arch) -> tuple:
    return (batch, arch.frontend_len or PATCHES_PER_IMAGE, VIT_WIDTH)


def make_patches(rng: np.random.Generator, batch: int, arch) -> jnp.ndarray:
    return jnp.asarray(
        rng.standard_normal(patch_shape(batch, arch)).astype(np.float32))
