"""Mamba (S6) selective-state-space block, JAX-native.

Train/prefill run the selective scan with ``jax.lax.associative_scan``
(parallel over time — the TPU-friendly formulation); decode is the O(1)
recurrent step on carried state.  Used by the Jamba hybrid architecture.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0           # 0 -> ceil(d_model/16)
    dtype: jnp.dtype = jnp.bfloat16
    scan_chunk: int = 0        # >0: chunked scan (EXPERIMENTS.md §Perf.1) —
                               # bounds the f32 scan state working set to
                               # O(chunk * d_inner * d_state) instead of O(S·…)

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def dt_rank_(self):
        return self.dt_rank or -(-self.d_model // 16)


def init_mamba(key, cfg: MambaConfig):
    ks = jax.random.split(key, 8)
    d, di, n, rk = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank_
    s = 1.0 / np.sqrt(d)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": layers._norm_init(ks[0], (d, 2 * di), s).astype(cfg.dtype),
        "conv_w": (layers._norm_init(ks[1], (cfg.d_conv, di), 1.0)
                   * (1 / np.sqrt(cfg.d_conv))).astype(cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "w_x_dbc": layers._norm_init(ks[2], (di, rk + 2 * n),
                                     1 / np.sqrt(di)).astype(cfg.dtype),
        "w_dt": layers._norm_init(ks[3], (rk, di), 1 / np.sqrt(rk)).astype(cfg.dtype),
        "b_dt": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(jnp.float32),
        "A_log": jnp.log(A),                       # [di, n] f32
        "D": jnp.ones((di,), jnp.float32),
        "w_out": layers._norm_init(ks[4], (di, d), 1 / np.sqrt(di)).astype(cfg.dtype),
    }


def _conv_causal(x, w, b, state=None):
    """Depthwise causal conv. x: [B, S, di]; w: [K, di]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out + b, new_state


def _ssm_params(params, xc, cfg: MambaConfig):
    n, rk = cfg.d_state, cfg.dt_rank_
    dbc = xc @ params["w_x_dbc"]                       # [B,S,rk+2n]
    dt = jax.nn.softplus(dbc[..., :rk] @ params["w_dt"]
                         + params["b_dt"])            # [B,S,di] f32-ish
    Bm = dbc[..., rk:rk + n].astype(jnp.float32)       # [B,S,n]
    Cm = dbc[..., rk + n:].astype(jnp.float32)         # [B,S,n]
    A = -jnp.exp(params["A_log"])                      # [di,n]
    return dt.astype(jnp.float32), Bm, Cm, A


def _combine(l, r):
    al, bl = l
    ar, br = r
    return al * ar, br + ar * bl


def mamba_apply(params, x, cfg: MambaConfig):
    """x: [B, S, d] -> [B, S, d] via parallel associative scan.

    With cfg.scan_chunk > 0 the time axis is processed in chunks with a
    sequential carry: the associative scan (and its O(S) f32 (a, b, h)
    intermediates) only ever exists for one chunk at a time.
    """
    B, S, _ = x.shape
    xz = x @ params["w_in"]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _conv_causal(xc, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)

    dt, Bm, Cm, A = _ssm_params(params, xc, cfg)
    xf = xc.astype(jnp.float32)
    # discretize: a_t = exp(dt*A) [B,S,di,n]; b_t = dt*B*x
    a = jnp.exp(dt[..., None] * A[None, None])
    b = (dt * xf)[..., None] * Bm[:, :, None, :]

    ck = cfg.scan_chunk
    if ck and ck < S and S % ck == 0:
        nc = S // ck
        ac = a.reshape(B, nc, ck, *a.shape[2:]).transpose(1, 0, 2, 3, 4)
        bc = b.reshape(B, nc, ck, *b.shape[2:]).transpose(1, 0, 2, 3, 4)

        def chunk_step(h0, ab):
            ai, bi = ab
            acc, h = jax.lax.associative_scan(_combine, (ai, bi), axis=1)
            h = h + acc * h0[:, None]          # inject carry
            return h[:, -1], h
        h0 = jnp.zeros((B,) + a.shape[2:], jnp.float32)
        _, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
        h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, *a.shape[2:])
    else:
        _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm) + params["D"] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_out"]


def init_mamba_state(batch: int, cfg: MambaConfig):
    return {"h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), cfg.dtype)}


def mamba_decode(params, x, state, cfg: MambaConfig):
    """Single-token recurrent step. x: [B, 1, d]."""
    xz = x @ params["w_in"]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _conv_causal(xc, params["conv_w"], params["conv_b"],
                                  state["conv"])
    xc = jax.nn.silu(xc)
    dt, Bm, Cm, A = _ssm_params(params, xc, cfg)
    xf = xc.astype(jnp.float32)[:, 0]
    a = jnp.exp(dt[:, 0, :, None] * A[None])           # [B,di,n]
    b = (dt[:, 0] * xf)[..., None] * Bm[:, 0, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + params["D"] * xf
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["w_out"], {"h": h, "conv": conv_state}
