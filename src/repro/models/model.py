"""Top-level model API: context building, parameter init with shardings,
and ShapeDtypeStruct input specs for the multi-pod dry-run."""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.configs.base import ArchConfig, INPUT_SHAPES
from repro.core import capacity, gating, topology
from repro.core.dispatch import base as moe_base
from repro.models import transformer, decode as decode_lib


def default_rules(mesh) -> sharding.AxisRules:
    names = mesh.axis_names
    batch = sharding.hierarchy_axes(mesh)
    return sharding.AxisRules({
        "batch": batch if len(batch) > 1 else (batch[0] if batch else None),
        "model": "model" if "model" in names else None,
        "kv_len": "data" if "data" in names else None,
        "expert": batch if len(batch) > 1 else (batch[0] if batch else None),
    }, mesh=mesh)


def make_ep_spec(arch: ArchConfig, mesh) -> moe_base.EPSpec | None:
    """EP hierarchy for one mesh: experts span the longest *suffix* of the
    non-model axes (innermost outward) whose extent divides the expert
    count — the whole hierarchy when possible, fewer tiers otherwise (the
    unspanned outer axes stay pure data parallelism).  The dispatch plan's
    level count follows this span."""
    if not arch.is_moe:
        return None
    axes = sharding.hierarchy_axes(mesh)
    sizes = tuple(mesh.shape[a] for a in axes)
    while len(sizes) > 1 and sizes[0] == 1:   # degenerate outer tiers
        axes, sizes = axes[1:], sizes[1:]
    model = "model" if "model" in mesh.shape else None
    n = arch.moe.num_experts
    for k in range(len(axes)):                # longest suffix first
        world = 1
        for s in sizes[k:]:
            world *= s
        if k == len(axes) - 1 or (n % world == 0 and n >= world):
            return moe_base.EPSpec.from_axes(axes[k:], sizes[k:],
                                             model_axis=model)
    return moe_base.EPSpec.from_axes(axes[-1:], sizes[-1:], model_axis=model)


def make_plan(arch: ArchConfig, mesh, seq_len: int, global_batch: int,
              mode: str) -> capacity.DispatchPlan | None:
    if not arch.is_moe:
        return None
    ep = make_ep_spec(arch, mesh)
    nshard = 1
    for a in sharding.hierarchy_axes(mesh):
        nshard *= mesh.shape[a]
    tokens_per_device = max(1, (global_batch * seq_len) // nshard)
    return capacity.make_dispatch_plan(
        tokens_per_device=tokens_per_device,
        num_experts=arch.moe.num_experts, top_k=arch.moe.top_k,
        capacity_factor=arch.moe.capacity_factor,
        axis_sizes=ep.axis_sizes, axis_names=ep.axis_names, mode=mode,
        comm=topology.tree_topology_nd(ep.axis_sizes))


def make_gate_cfg(arch: ArchConfig, plan, ep, aux_mode: str,
                  ) -> gating.GateConfig | None:
    if not arch.is_moe:
        return None
    n_levels = max(3, len(plan.ratios) if plan is not None else 3)
    penalties = (1.0,) * n_levels
    if aux_mode == "ta" and plan is not None:
        # the plan carries the full Eq. (7) ratio vector and the per-level
        # member counts — no 2-level summary, works for any tree depth
        penalties = gating.ta_penalties(plan.ratios,
                                        level_sizes=plan.level_sizes)
        if len(penalties) < 3:
            penalties = penalties + (penalties[-1],) * (3 - len(penalties))
    return gating.GateConfig(
        num_experts=arch.moe.num_experts, top_k=arch.moe.top_k,
        capacity_factor=arch.moe.capacity_factor,
        aux_mode=aux_mode, penalty_by_level=penalties)


def resolve_num_chunks(arch: ArchConfig, plan, ep,
                       num_chunks: int = 0, *, mesh=None,
                       wire_codec=None) -> int:
    """Chunk count for pipelined dispatch; 0 = pick via the overlap model.

    With ``mesh`` given, the overlap model's alpha/beta come from *measured*
    links (an all-to-all micro-benchmark on that mesh, cached per mesh
    shape) instead of the ICI/DCI topology constants.  ``wire_codec``
    rescales the exchange bytes to the wire encoding, so a codec swap can
    legitimately change the chunk verdict.
    """
    if num_chunks > 0:
        return int(num_chunks)
    from repro.core import comm_model
    links = None
    if mesh is not None:
        links = comm_model.measured_ep_links(mesh, ep.axis_names)
    terms = comm_model.moe_overlap_terms(
        plan, d_model=arch.d_model, d_ff=arch.moe.d_ff_expert,
        bytes_per_el=2 if arch.jnp_dtype == jnp.bfloat16 else 4,
        activation=arch.activation, links=links, codec=wire_codec)
    return comm_model.choose_num_chunks(**terms)


def build_ctx(arch: ArchConfig, mesh, *, seq_len: int, global_batch: int,
              aux_mode: str = "ta", remat: bool = False,
              decode_replicated: bool = False,
              use_flash: bool = False,
              use_moe_kernel: bool = False,
              dispatch: str = "a2a",
              a2a_num_chunks: int = 0,
              dispatch_override: tuple = (),
              measured_comm: bool = False,
              use_pallas=None,
              wire_codec="",
              resilience=None) -> transformer.ModelCtx:
    from repro.core import dispatch as dispatch_lib
    from repro.core.dispatch import wire as wire_lib

    # config-time codec validation: unknown names fail here with the
    # registry listed, mirroring the dispatch-name check below
    codec = wire_lib.get_codec(wire_codec)

    # arch-level per-layer overrides are the base; explicit (run-level)
    # overrides win per layer index.
    if arch.is_moe and arch.moe.dispatch_override:
        merged = dict(arch.moe.dispatch_override)
        merged.update(dict(dispatch_override))
        dispatch_override = tuple(sorted(merged.items()))
    else:
        dispatch_override = tuple(sorted(dict(dispatch_override).items()))
    for name in (dispatch,) + tuple(n for _, n in dispatch_override):
        dispatch_lib.get_path(name)   # raises ValueError on unknown names

    dispatch_mode = {"lb": "even", "even": "even", "ta": "ta",
                     "hir": "hir", "none": "even"}[aux_mode]
    plan = make_plan(arch, mesh, seq_len, global_batch, dispatch_mode)
    ep = make_ep_spec(arch, mesh)
    gate_cfg = make_gate_cfg(arch, plan, ep, aux_mode)
    num_chunks = 1
    pipelined = (dispatch == "a2a_pipelined"
                 or any(n == "a2a_pipelined" for _, n in dispatch_override))
    if plan is not None and pipelined:
        num_chunks = resolve_num_chunks(arch, plan, ep, a2a_num_chunks,
                                        mesh=mesh if measured_comm else None,
                                        wire_codec=codec)
        plan = capacity.align_to_chunks(plan, num_chunks)
    return transformer.ModelCtx(
        arch=arch, mesh=mesh, ep=ep, plan=plan, gate_cfg=gate_cfg,
        remat=remat, decode_replicated=decode_replicated,
        use_flash=use_flash, use_moe_kernel=use_moe_kernel,
        dispatch=dispatch, a2a_num_chunks=num_chunks,
        dispatch_override=dispatch_override, use_pallas=use_pallas,
        wire_codec=codec, resilience=resilience)


# ---------------------------------------------------------------------------
# parameter sharding rules (path-regex -> PartitionSpec)
# ---------------------------------------------------------------------------


def param_spec_rules(arch: ArchConfig, ep) -> list:
    """Ordered (regex, spec) rules for build_param_specs.

    Group-stacked params have a leading layer axis — rules below are written
    for the *unstacked* layout; `stacked` variants prepend None.
    """
    exp = None
    if ep is not None:
        exp = (ep.ep_axes() if len(ep.ep_axes()) > 1 else ep.ep_axes()[0])
    rules = [
        # embeddings: vocab over model axis
        (r"embed/table", P("model", None)),
        # MoE experts
        (r"ffn/w_in$", P(None, exp, None, "model")),
        (r"ffn/w_gate$", P(None, exp, None, "model")),
        (r"ffn/w_out$", P(None, exp, "model", None)),
        (r"ffn/shared_(in|gate)", P(None, None, "model")),
        (r"ffn/shared_out", P(None, "model", None)),
        # attention projections (stacked: leading group axis)
        (r"mixer/w[qkv]$", P(None, None, "model")),
        (r"(mixer|cross)/wo$", P(None, "model", None)),
        (r"cross/w[qkv]$", P(None, None, "model")),
        # MLA
        (r"mixer/w_u[kvq]$", P(None, None, "model", None)),
        (r"mixer/w_q$", P(None, None, "model", None)),
        # mamba / xlstm / mlp: shard the wide inner dim
        (r"mixer/w_in$", P(None, None, "model")),
        (r"mixer/w_up$", P(None, None, "model")),
        (r"mixer/(w_out|w_down)$", P(None, "model", None)),
        (r"ffn/w_(in|gate)$", P(None, None, "model")),
        (r"ffn/w_out$", P(None, "model", None)),
        (r"proj/w1$", P(None, "model")),
        (r"proj/w2$", P("model", None)),
    ]
    # dense-arch MoE rules never fire; harmless.
    return rules


def init_params(key, ctx: transformer.ModelCtx, rules=None):
    """Initialize parameters; under a rules context the result is sharded."""
    params = transformer.init_model(key, ctx)
    if rules is None:
        return params
    specs = sharding.build_param_specs(
        params, param_spec_rules(ctx.arch, ctx.ep))
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), specs)
    params = jax.jit(lambda p: p, out_shardings=shardings)(params)
    return params


def param_shardings(params, ctx: transformer.ModelCtx):
    specs = sharding.build_param_specs(
        params, param_spec_rules(ctx.arch, ctx.ep))
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), specs)


def abstract_params(key, ctx: transformer.ModelCtx):
    """Shape-only params (no allocation) for the dry-run."""
    shapes = jax.eval_shape(lambda k: transformer.init_model(k, ctx), key)
    specs = sharding.build_param_specs(
        shapes, param_spec_rules(ctx.arch, ctx.ep))
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(ctx.mesh, sp)),
        shapes, specs)


def count_params(params_or_shapes) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params_or_shapes))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(arch: ArchConfig, shape_name: str, mesh,
                ctx: transformer.ModelCtx | None = None) -> dict:
    """ShapeDtypeStruct pytree for every model input of this shape."""
    sh = INPUT_SHAPES[shape_name]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    batch_axes = sharding.hierarchy_axes(mesh)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    nshard = 1
    for a in batch_axes:
        nshard *= mesh.shape[a]
    replicated = B < nshard            # long_500k: context parallelism
    bs = P() if replicated else P(bspec)

    def _frontend_spec():
        if arch.frontend == "vision":
            from repro.models import vlm
            shape = vlm.patch_shape(B, arch)
        else:
            from repro.models import whisper
            shape = whisper.frame_shape(B, arch)
        return _sds(shape, jnp.float32, mesh, P(*bs))

    if kind == "train":
        specs = {"tokens": _sds((B, S), jnp.int32, mesh, P(*bs)),
                 "labels": _sds((B, S), jnp.int32, mesh, P(*bs)),
                 "loss_mask": _sds((B, S), jnp.float32, mesh, P(*bs))}
        if arch.frontend:
            specs["frontend"] = _frontend_spec()
        return specs
    if kind == "prefill":
        specs = {"tokens": _sds((B, S), jnp.int32, mesh, P(*bs))}
        if arch.frontend:
            specs["frontend"] = _frontend_spec()
        return specs
    # decode: one token + cache
    assert ctx is not None
    cache_shapes = jax.eval_shape(
        lambda: decode_lib.init_cache(ctx, B, S))
    kv_axis = "data" if (replicated and "data" in mesh.shape) else None

    def cache_spec(path, s):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        leaf = names[-1]
        lead = [None] * (1 if "groups" in names else 0)  # stacked layer axis
        batch = None if replicated else bspec

        def model_ok(dim):
            return "model" in mesh.shape and dim % mesh.shape["model"] == 0
        if leaf in ("k", "v", "cross_k", "cross_v"):
            # [(g), B, L, K, hd]
            return P(*(lead + [batch, kv_axis,
                               "model" if model_ok(s.shape[-2]) else None,
                               None]))
        if leaf in ("c_kv", "k_rope"):
            # [(g), B, L, r]
            return P(*(lead + [batch, kv_axis, None]))
        if leaf == "pos" or replicated:
            return P(*lead) if lead else P()
        # recurrent states: [(g), B, ...] — batch-shard
        rest = s.ndim - len(lead) - 1
        return P(*(lead + [batch] + [None] * rest))

    cache = jax.tree_util.tree_map_with_path(
        lambda p, s: _sds(s.shape, s.dtype, mesh, cache_spec(p, s)),
        cache_shapes)
    tokens = _sds((B, 1), jnp.int32, mesh, P() if replicated else P(bspec))
    return {"tokens": tokens, "cache": cache}
