"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Train/prefill use the expanded form; decode uses the *absorbed* form that
keeps only the compressed latent cache (kv_lora_rank + rope dims per token),
which is the whole point of MLA for long-context serving: the long_500k
cache is 512+64 floats per token instead of 2*K*hd.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    num_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    q_lora_rank: int = 0          # 0 = full-rank q projection
    rope_theta: float = 1e4
    dtype: jnp.dtype = jnp.bfloat16
    use_blockwise: bool = False   # flash-style attention (no S x S scores)

    @property
    def qk_dim(self):
        return self.qk_nope_dim + self.qk_rope_dim


def init_mla(key, cfg: MLAConfig):
    ks = jax.random.split(key, 8)
    d, H = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_dim
    s = 1.0 / np.sqrt(d)
    p = {
        "w_dkv": layers._norm_init(ks[0], (d, r), s).astype(cfg.dtype),
        "w_uk": layers._norm_init(ks[1], (r, H, dn), 1 / np.sqrt(r)).astype(cfg.dtype),
        "w_uv": layers._norm_init(ks[2], (r, H, dv), 1 / np.sqrt(r)).astype(cfg.dtype),
        "w_kr": layers._norm_init(ks[3], (d, dr), s).astype(cfg.dtype),
        "w_o": layers._norm_init(ks[4], (H * dv, d), 1 / np.sqrt(H * dv)).astype(cfg.dtype),
        "kv_norm": {"scale": jnp.ones((r,), jnp.float32)},
    }
    if cfg.q_lora_rank:
        p["w_dq"] = layers._norm_init(ks[5], (d, cfg.q_lora_rank), s).astype(cfg.dtype)
        p["w_uq"] = layers._norm_init(
            ks[6], (cfg.q_lora_rank, H, cfg.qk_dim),
            1 / np.sqrt(cfg.q_lora_rank)).astype(cfg.dtype)
        p["q_norm"] = {"scale": jnp.ones((cfg.q_lora_rank,), jnp.float32)}
    else:
        p["w_q"] = layers._norm_init(ks[5], (d, H, cfg.qk_dim), s).astype(cfg.dtype)
    return p


def _q_proj(params, x, cfg: MLAConfig):
    if cfg.q_lora_rank:
        cq = layers.norm_apply(params["q_norm"], x @ params["w_dq"], "rmsnorm")
        q = jnp.einsum("bsr,rhd->bshd", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    return q  # [B, S, H, qk_dim]


def mla_apply(params, x, cfg: MLAConfig, positions=None):
    """Expanded-form MLA for train/prefill.  Returns (out, cache_entry)."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_dim
    if positions is None:
        positions = jnp.arange(S)

    q = _q_proj(params, x, cfg)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = layers.norm_apply(params["kv_norm"], x @ params["w_dkv"], "rmsnorm")
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uv"])
    k_rope = layers.apply_rope((x @ params["w_kr"])[:, :, None, :],
                               positions, cfg.rope_theta)  # [B,S,1,dr]
    k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, dr))

    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, k_rope_b], -1)
    if cfg.use_blockwise:
        out = layers._blockwise_sdpa(qf, kf, v, causal=True,
                                     sliding_window=0)
        out = out.astype(jnp.float32)
    else:
        scale = 1.0 / np.sqrt(cfg.qk_dim)
        logits = jnp.einsum("bqhd,bkhd->bhqk",
                            qf.astype(jnp.float32) * scale,
                            kf.astype(jnp.float32))
        mask = positions[:, None] >= positions[None, :]
        logits = jnp.where(mask, logits, -1e30)
        w = jax.nn.softmax(logits, -1)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    out = out.reshape(B, S, H * dv).astype(x.dtype) @ params["w_o"]
    return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def init_mla_cache(batch: int, max_len: int, cfg: MLAConfig):
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def mla_decode(params, x, cache, cfg: MLAConfig):
    """Absorbed-form single-token decode against the compressed cache.

    logits_h(l) = q_abs_h . c_kv(l) + q_rope_h . k_rope(l)
    with q_abs_h = q_nope_h @ w_uk_h  — the k up-projection is absorbed into
    the query, so attention runs in the rank-r latent space.
    """
    B = x.shape[0]
    H, dn, dr = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    pos = cache["pos"]

    q = _q_proj(params, x, cfg)[:, 0]          # [B, H, qk_dim]
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope[:, None].swapaxes(1, 2), pos[:, None],
                               cfg.rope_theta).swapaxes(1, 2)[:, 0]

    c_new = layers.norm_apply(params["kv_norm"],
                              x[:, 0] @ params["w_dkv"], "rmsnorm")
    kr_new = layers.apply_rope((x[:, 0] @ params["w_kr"])[:, None, None, :],
                               pos[:, None], cfg.rope_theta)[:, 0, 0]
    c_kv = jnp.asarray(cache["c_kv"]).at[jnp.arange(B), pos].set(c_new)
    k_rope = jnp.asarray(cache["k_rope"]).at[jnp.arange(B), pos].set(kr_new)

    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope, params["w_uk"])   # [B,H,r]
    scale = 1.0 / np.sqrt(cfg.qk_dim)
    logits = (jnp.einsum("bhr,blr->bhl", q_abs.astype(jnp.float32),
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bhd,bld->bhl", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    L = c_kv.shape[1]
    valid = jnp.arange(L)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, -1)
    ctx = jnp.einsum("bhl,blr->bhr", w, c_kv.astype(jnp.float32))  # latent ctx
    out = jnp.einsum("bhr,rhd->bhd", ctx, params["w_uv"].astype(jnp.float32))
    out = out.reshape(B, 1, -1).astype(x.dtype) @ params["w_o"]
    return out, {"c_kv": c_kv, "k_rope": k_rope, "pos": pos + 1}
