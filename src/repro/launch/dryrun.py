import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: the dry-run builds 16x16 and 2x16x16
# meshes out of placeholder host devices.  Never set this globally.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, print memory/cost analysis, and
emit the roofline terms.  No real buffers are allocated — all inputs are
ShapeDtypeStructs (see models/model.input_specs).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b \
        --shape train_4k --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh, make_production_mesh_3tier
from repro.models import model as model_lib
from repro.models import transformer
from repro.optim import adamw
from repro.training import trainer as trainer_lib
from repro.serving import engine
from repro.configs.base import RunConfig


def arch_variant(arch, shape_name: str):
    """Shape-specific arch tweaks per DESIGN.md input-shape policy."""
    if shape_name == "long_500k":
        if arch.family == "audio":
            return None, "skip: enc-dec audio (1500-frame encoder, 448-token decoder)"
        if (arch.family in ("dense", "vlm") and arch.mla is None
                and arch.sliding_window == 0):
            arch = dataclasses.replace(arch, sliding_window=8192)
            return arch, "sliding-window 8192 variant (sub-quadratic policy)"
    return arch, ""


def skip_reason(arch, shape_name: str):
    sh = INPUT_SHAPES[shape_name]
    if sh["kind"] == "decode" and arch.family == "audio" \
            and shape_name == "long_500k":
        return "enc-dec audio: no 500k decode"
    return None


def lower_one(arch_id: str, shape_name: str, multi_pod,
              aux_mode: str = "ta", use_remat: bool | None = None,
              optimized: bool = False, ctx_overrides: dict | None = None,
              tag: str = ""):
    """Returns (record, compiled) — record holds all analysis numbers.

    ``multi_pod``: False = pod1 (16x16), True = pod2 (2x16x16), or the
    string ``"pod3"`` for the 3-tier 2x2x8x16 pod/node/data/model mesh.
    """
    if multi_pod == "pod3":
        mesh, mesh_name = make_production_mesh_3tier(), "pod3"
    else:
        mesh = make_production_mesh(multi_pod=bool(multi_pod))
        mesh_name = "pod2" if multi_pod else "pod1"
    arch0 = get_config(arch_id)
    arch, note = arch_variant(arch0, shape_name)
    if arch is None:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": mesh_name,
                "status": "skipped", "note": note}, None
    sh = INPUT_SHAPES[shape_name]
    kind = sh["kind"]
    B, S = sh["global_batch"], sh["seq_len"]
    nshard = 1
    for a in sharding.hierarchy_axes(mesh):
        nshard *= mesh.shape[a]
    replicated = B < nshard
    remat = kind == "train" if use_remat is None else use_remat

    ctx = model_lib.build_ctx(arch, mesh, seq_len=S, global_batch=B,
                              aux_mode=aux_mode if arch.is_moe else "none",
                              remat=remat, decode_replicated=replicated)
    if optimized:
        import dataclasses as _dc
        ctx = _dc.replace(ctx, use_blockwise=True, fused_xent=True,
                          wire_codec="fp8e4m3" if arch.is_moe else None,
                          mamba_scan_chunk=512, xlstm_chunk=512)
        if kind == "prefill" and arch.is_moe:
            # inference prefill needs no drop headroom: cf 1.25 -> 1.0
            arch_cf1 = _dc.replace(
                arch, moe=_dc.replace(arch.moe, capacity_factor=1.0))
            ctx = _dc.replace(
                ctx, plan=model_lib.make_plan(
                    arch_cf1, mesh, S, B,
                    {"lb": "even", "ta": "ta", "hir": "hir"}[aux_mode]))
        if arch.is_moe and kind != "decode" and ctx.plan is not None:
            # comm–compute overlap: pipelined dispatch with the chunk count
            # chosen from alpha/beta *measured* on this mesh (cached per
            # mesh shape), not the ICI/DCI constants.
            from repro.core import capacity as capacity_lib
            nc = model_lib.resolve_num_chunks(arch, ctx.plan, ctx.ep, 0,
                                              mesh=mesh,
                                              wire_codec=ctx.wire_codec)
            ctx = _dc.replace(
                ctx, dispatch="a2a_pipelined", a2a_num_chunks=nc,
                plan=capacity_lib.align_to_chunks(ctx.plan, nc))
    if ctx_overrides:
        import dataclasses as _dc
        cfo = dict(ctx_overrides)
        cf = cfo.pop("capacity_factor", None)
        ctx = _dc.replace(ctx, **cfo)
        if cf is not None and arch.is_moe:
            arch_cf = _dc.replace(
                arch, moe=_dc.replace(arch.moe, capacity_factor=cf))
            ctx = _dc.replace(ctx, plan=model_lib.make_plan(
                arch_cf, mesh, S, B,
                {"lb": "even", "ta": "ta", "hir": "hir"}[aux_mode]))
    rules = model_lib.default_rules(mesh)
    t0 = time.time()
    with mesh, sharding.axis_rules(rules):
        aparams = model_lib.abstract_params(jax.random.PRNGKey(0), ctx)
        n_params = model_lib.count_params(aparams)
        specs = model_lib.input_specs(arch, shape_name, mesh, ctx=ctx)

        if kind == "train":
            run = RunConfig(seq_len=S, global_batch=B, aux_mode=aux_mode,
                            remat=remat)
            step = trainer_lib.make_train_step(ctx, run)
            aopt = jax.eval_shape(adamw.init_state, aparams)
            aopt = jax.tree_util.tree_map(
                lambda s, p: jax.ShapeDtypeStruct(
                    s.shape, s.dtype,
                    sharding=getattr(p, "sharding", None))
                if s.shape == getattr(p, "shape", None) else
                jax.ShapeDtypeStruct(s.shape, s.dtype),
                aopt, {"mu": aparams, "nu": aparams,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)})
            lowered = jax.jit(step).lower(aparams, aopt, specs)
        elif kind == "prefill":
            fn = engine.make_prefill(ctx)
            lowered = jax.jit(fn).lower(aparams, specs)
        else:  # decode
            fn = engine.make_decode_step(ctx)
            donate = (1,) if optimized else ()   # in-place cache update
            lowered = jax.jit(fn, donate_argnums=donate).lower(
                aparams, specs["cache"], specs["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        n_dev = mesh.size
        dpp = n_dev // mesh.shape.get("pod", 1)
        active = _active_params(arch, n_params)
        mf = analysis.model_flops_estimate(arch, S, B, kind, active)
        hlo = compiled.as_text()
        rl = analysis.roofline(compiled, num_devices=n_dev,
                               devices_per_pod=dpp, model_flops=mf,
                               hlo_text=hlo)
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok", "note": note, "kind": kind,
        "aux_mode": aux_mode, "optimized": optimized, "tag": tag,
        "dispatch": ctx.dispatch, "a2a_num_chunks": ctx.a2a_num_chunks,
        "dispatch_levels": (ctx.plan.num_stages
                            if getattr(ctx, "plan", None) is not None else 0),
        "caps_by_level": (list(ctx.plan.caps)
                          if getattr(ctx, "plan", None) is not None else []),
        "ctx_overrides": {k: str(v) for k, v in (ctx_overrides or {}).items()},
        "n_params": n_params, "active_params": active,
        "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                + getattr(mem, "argument_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "flops_per_chip": rl.flops_per_chip,
        "hbm_bytes_per_chip": rl.hbm_bytes_per_chip,
        "ici_bytes_per_chip": rl.ici_bytes_per_chip,
        "dci_bytes_per_chip": rl.dci_bytes_per_chip,
        "t_compute": rl.t_compute, "t_memory": rl.t_memory,
        "t_collective": rl.t_collective, "dominant": rl.dominant,
        "model_flops": mf, "useful_ratio": rl.useful_ratio,
        "collective_counts": rl.collective_counts,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
    }
    return rec, compiled


def _active_params(arch, n_params: int) -> float:
    """Active (per-token) parameter count: subtract non-selected experts."""
    if not arch.is_moe:
        return float(n_params)
    m = arch.moe
    # expert params per MoE layer (swiglu has the extra gate matrix)
    n_mats = 3 if arch.activation == "swiglu" else 2
    per_expert = arch.d_model * m.d_ff_expert * n_mats
    prefix, group, n_groups = transformer.layer_plan(arch)
    n_moe_layers = sum(1 for s in group if s.ffn == "moe") * n_groups
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return float(n_params - inactive)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="pod1",
                    choices=["pod1", "pod2", "pod3", "both", "all"])
    ap.add_argument("--aux-mode", default="ta", choices=["ta", "lb", "hir"])
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper perf flags (blockwise attn, fused "
                         "xent, cache donation)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"pod1": [False], "pod2": [True], "pod3": ["pod3"],
              "both": [False, True],
              "all": [False, True, "pod3"]}[args.mesh]

    failures = 0
    for arch_id in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = multi if isinstance(multi, str) else (
                    "pod2" if multi else "pod1")
                tag = f"{arch_id} x {shape_name} x {mesh_name}"
                try:
                    rec, compiled = lower_one(arch_id, shape_name, multi,
                                              aux_mode=args.aux_mode,
                                              optimized=args.opt)
                    if rec["status"] == "ok":
                        if rec.get("dispatch") == "a2a_pipelined":
                            tag += (f" [a2a_pipelined "
                                    f"chunks={rec['a2a_num_chunks']}]")
                        print(f"[ok] {tag}: dom={rec['dominant']} "
                              f"tC={rec['t_compute']*1e3:.2f}ms "
                              f"tM={rec['t_memory']*1e3:.2f}ms "
                              f"tX={rec['t_collective']*1e3:.2f}ms "
                              f"mem/dev={rec['bytes_per_device']/2**30:.2f}GiB "
                              f"(compile {rec['t_compile_s']}s)", flush=True)
                    else:
                        print(f"[skip] {tag}: {rec['note']}", flush=True)
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch_id, "shape": shape_name,
                           "mesh": mesh_name,
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc(limit=4)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
