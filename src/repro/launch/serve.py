"""Serving launcher: batched prefill + decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b \
        --reduced --devices 4 --mesh-shape 2,2 --batch 4 --steps 16
"""

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh-shape", default="1,1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--streams", type=int, default=0,
                    help="serve this many queued requests through the "
                         "continuous-batching engine instead of one "
                         "fixed-batch generate call")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from repro import sharding
    from repro.configs.base import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as model_lib
    from repro.serving import engine

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    dims = [int(x) for x in args.mesh_shape.split(",")]
    mesh = make_host_mesh(data=dims[0], model=dims[1])

    ctx = model_lib.build_ctx(arch, mesh, seq_len=args.cache_len,
                              global_batch=args.batch, aux_mode="none")
    rules = model_lib.default_rules(mesh)
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(jax.random.PRNGKey(0), ctx,
                                       rules=rules)
        if args.streams:
            import numpy as np
            from repro.serving.scheduler import Request
            rng = np.random.default_rng(1)
            reqs = [Request(uid=i,
                            tokens=rng.integers(
                                0, arch.vocab_size,
                                size=args.prompt_len).tolist(),
                            max_new_tokens=args.steps,
                            temperature=args.temperature)
                    for i in range(args.streams)]
            cfg = engine.ServeConfig(num_slots=args.batch,
                                     cache_len=args.cache_len,
                                     prefill_pack=min(args.batch, 4),
                                     prompt_buckets=(args.prompt_len,))
            report = engine.ServingEngine(params, ctx, cfg).run(reqs)
            print(f"served {len(report.streams)} streams at "
                  f"{report.tokens_per_sec:.2f} tok/s aggregate "
                  f"({report.decode_steps} decode steps, "
                  f"{report.prefill_calls} prefill packs)")
            return 0
        key = jax.random.PRNGKey(1)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                     0, arch.vocab_size, jnp.int32)
        res = engine.generate(params, ctx, prompts, steps=args.steps,
                              cache_len=args.cache_len,
                              temperature=args.temperature)
    print(f"generated {res.tokens.shape} tokens at "
          f"{res.steps_per_sec:.2f} decode steps/s")
    print("sample:", res.tokens[0][:16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
