"""Serving launcher: batched prefill + decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b \
        --reduced --devices 4 --mesh-shape 2,2 --batch 4 --steps 16
"""

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh-shape", default="1,1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from repro import sharding
    from repro.configs.base import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as model_lib
    from repro.serving import engine

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    dims = [int(x) for x in args.mesh_shape.split(",")]
    mesh = make_host_mesh(data=dims[0], model=dims[1])

    ctx = model_lib.build_ctx(arch, mesh, seq_len=args.cache_len,
                              global_batch=args.batch, aux_mode="none")
    rules = model_lib.default_rules(mesh)
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(jax.random.PRNGKey(0), ctx,
                                       rules=rules)
        key = jax.random.PRNGKey(1)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                     0, arch.vocab_size, jnp.int32)
        res = engine.generate(params, ctx, prompts, steps=args.steps,
                              cache_len=args.cache_len,
                              temperature=args.temperature)
    print(f"generated {res.tokens.shape} tokens at "
          f"{res.steps_per_sec:.2f} decode steps/s")
    print("sample:", res.tokens[0][:16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
