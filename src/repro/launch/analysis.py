"""Compiled-HLO analysis: collective-byte accounting + roofline terms.

cost_analysis() gives FLOPs and HBM bytes; collective traffic is NOT in
cost_analysis, so we parse the post-partitioning HLO text and sum wire
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, classified intra-pod (ICI) vs cross-pod (DCI) from the
replica groups.  Wire-byte factors use standard ring/all-to-all costs.
"""

from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

# TARGET hardware constants (TPU v5e-class; DCI assumed — see EXPERIMENTS.md)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (approx. per-chip a2a bw)
DCI_BW = 6.25e9              # bytes/s per chip, cross-pod

_DTYPE_BYTES = {
    "s4": 0.5, "u4": 0.5,    # packed 4-bit: bytes are ceil'd per shape
    "pred": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                             r"(?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in a line's result portion.

    Handles arbitrarily nested tuple shapes — ``(f32[8,4], (s8[16],
    u4[3]))`` — by summing every member, and sub-byte (4-bit) element
    types, whose packed byte count is ceil'd per shape member."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += math.ceil(n * _DTYPE_BYTES[dt])
    return total


def _parse_groups(line: str, num_devices: int):
    """Return list of device-id groups for a collective line."""
    m = _GROUPS_RE.search(line)
    if m:
        return [[int(x) for x in g.strip("{}").split(",") if x]
                for g in re.findall(r"\{[^}]*\}", m.group(1))]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, sz = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(ng, sz)
        return [list(r) for r in ids]
    m = _PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        return [[int(a), int(b)] for a, b in pairs]
    return [[i for i in range(num_devices)]]


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: float = 0.0       # wire bytes per chip over ICI
    dci_bytes: float = 0.0       # wire bytes per chip over DCI
    counts: dict = dataclasses.field(default_factory=dict)

    def add(self, kind, ici, dci):
        self.ici_bytes += ici
        self.dci_bytes += dci
        self.counts[kind] = self.counts.get(kind, 0) + 1


def collective_stats(hlo_text: str, *, num_devices: int,
                     devices_per_pod: int) -> CollectiveStats:
    """Per-chip wire bytes of all collectives in a compiled HLO module."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        # result portion = everything left of the op name; covers both
        # plain (bf16[...] all-reduce) and tuple ((f32[..], f32[..])
        # all-reduce) results that XLA's gradient-combiner emits
        nbytes = _shape_bytes(line[: m.start(1)])
        if kind.endswith("-done"):
            continue
        groups = _parse_groups(line, num_devices)
        n = max(len(groups[0]), 1)
        crosses_pod = any(len({d // devices_per_pod for d in g}) > 1
                          for g in groups)
        # per-chip wire bytes (ring / pairwise costs)
        if kind == "all-gather":
            wire = nbytes * (n - 1) / n          # result is the full buffer
        elif kind == "reduce-scatter":
            wire = nbytes * (n - 1)              # result is the shard
        elif kind == "all-reduce":
            wire = 2 * nbytes * (n - 1) / n
        elif kind == "all-to-all":
            wire = nbytes * (n - 1) / n
        else:  # collective-permute
            wire = nbytes
        if crosses_pod:
            stats.add(kind, 0.0, wire)
        else:
            stats.add(kind, wire, 0.0)
    return stats


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    ici_bytes_per_chip: float
    dci_bytes_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collective_counts: dict

    def table_row(self):
        return (f"{self.t_compute*1e3:9.3f} {self.t_memory*1e3:9.3f} "
                f"{self.t_collective*1e3:9.3f} {self.dominant:10s} "
                f"{self.useful_ratio:6.3f}")


def roofline(compiled, *, num_devices: int, devices_per_pod: int,
             model_flops: float = 0.0, hlo_text: str | None = None
             ) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    # cost_analysis reports the post-GSPMD per-device module: already per chip
    flops_per_chip = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cs = collective_stats(text, num_devices=num_devices,
                          devices_per_pod=devices_per_pod)
    t_comp = flops_per_chip / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = cs.ici_bytes / ICI_BW + cs.dci_bytes / DCI_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    useful = (model_flops / max(flops_per_chip * num_devices, 1.0)
              if model_flops else 0.0)
    return Roofline(flops_per_chip=flops_per_chip, hbm_bytes_per_chip=hbm,
                    ici_bytes_per_chip=cs.ici_bytes,
                    dci_bytes_per_chip=cs.dci_bytes,
                    t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
                    dominant=dom, model_flops=model_flops,
                    useful_ratio=useful, collective_counts=cs.counts)


def model_flops_estimate(arch, seq_len: int, global_batch: int,
                         kind: str, n_params_active: float) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd) with N = active params."""
    tokens = (global_batch * seq_len if kind in ("train", "prefill")
              else global_batch)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
