"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — critical because the dry-run must set
XLA_FLAGS before the first device query.

All meshes go through :func:`repro.compat.make_mesh`, which papers over the
``axis_types`` kwarg that only exists on jax >= 0.5.
"""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pods: int = 0):
    """Small mesh over however many (possibly forced-host) devices exist."""
    if pods:
        shape, axes = (pods, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return make_mesh(shape, axes)
