"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — critical because the dry-run must set
XLA_FLAGS before the first device query.

All meshes go through :func:`repro.compat.make_mesh`, which papers over the
``axis_types`` kwarg that only exists on jax >= 0.5.  Hierarchies deeper
than pod x data use the canonical ``pod / node* / data`` axis naming (see
``repro.core.capacity.default_axis_names``) so the level-indexed dispatch
plans line up with the mesh axes.
"""

from __future__ import annotations

from repro.compat import make_mesh
from repro.core import topology as topo_lib
from repro.core.capacity import default_axis_names


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_production_mesh_3tier():
    """2 pods x 2 nodes x 8 data x 16 model (512 chips): the 3-tier
    NVLink/ICI -> intra-pod DCN -> inter-pod regime."""
    return make_mesh((2, 2, 8, 16), ("pod", "node", "data", "model"))


def make_hierarchical_mesh(axis_sizes, model: int = 1):
    """N-tier mesh from outermost-first hierarchy sizes plus a model axis.

    ``axis_sizes=(2, 2, 2), model=1`` gives a 2x2x2x1 mesh with axes
    ``("pod", "node", "data", "model")``.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    names = default_axis_names(len(sizes))
    return make_mesh(sizes + (model,), names + ("model",))


def mesh_from_topology(spec, model: int = 1):
    """Mesh for a paper-notation nested topology spec (Fig. 2).

    ``[[2, 2], [2, 2]]`` -> a ("pod", "node", "data", "model") 2x2x2xmodel
    mesh.  Asymmetric specs are merged first (paper §4.2).
    """
    return make_hierarchical_mesh(topo_lib.axis_sizes_from_spec(spec),
                                  model=model)


def make_host_mesh(data: int = 1, model: int = 1, pods: int = 0,
                   nodes: int = 0):
    """Small mesh over however many (possibly forced-host) devices exist."""
    if nodes:
        shape = (max(pods, 1), nodes, data, model)
        axes = ("pod", "node", "data", "model")
    elif pods:
        shape, axes = (pods, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return make_mesh(shape, axes)
