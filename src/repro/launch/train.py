"""Training launcher.

CPU container usage (reduced smoke variant on forced host devices):
    PYTHONPATH=src python -m repro.launch.train --arch gpt3_medium_moe \
        --reduced --devices 4 --mesh-shape 2,2 --steps 50 --aux-mode ta

On a real TPU slice, drop --devices/--reduced and pass --production
(16x16) or --production --multi-pod (2x16x16).
"""

import argparse
import os
import sys


def _deep_tuple(spec):
    if isinstance(spec, int):
        return spec
    return tuple(_deep_tuple(s) for s in spec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing only)")
    ap.add_argument("--mesh-shape", default="1,1",
                    help="data,model (or pod,data,model / "
                         "pod,node,data,model)")
    ap.add_argument("--topology", default="",
                    help="nested topology spec (paper Fig. 2 notation), "
                         "e.g. '[[2,2],[2,2]]' for a 3-tier 8-device "
                         "hierarchy; overrides --mesh-shape's hierarchy "
                         "axes (a trailing model axis of 1 is added)")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--aux-mode", default="ta",
                    choices=["ta", "lb", "hir", "none"])
    ap.add_argument("--aux-weight", type=float, default=1.0)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import ast

    import jax  # noqa: E402,F401  (imported after XLA_FLAGS to pin devices)
    from repro.configs.base import RunConfig, get_config
    from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                   mesh_from_topology)
    from repro.training import trainer

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()

    topo_spec = ()
    if args.topology:
        topo_spec = ast.literal_eval(args.topology)

    if args.production:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif topo_spec:
        mesh = mesh_from_topology(topo_spec)
    else:
        dims = [int(x) for x in args.mesh_shape.split(",")]
        if len(dims) == 4:
            mesh = make_host_mesh(pods=dims[0], nodes=dims[1], data=dims[2],
                                  model=dims[3])
        elif len(dims) == 3:
            mesh = make_host_mesh(pods=dims[0], data=dims[1], model=dims[2])
        else:
            mesh = make_host_mesh(data=dims[0], model=dims[1])

    run = RunConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                    learning_rate=args.lr, total_steps=args.steps,
                    warmup_steps=max(1, args.steps // 10),
                    aux_mode=args.aux_mode, aux_weight=args.aux_weight,
                    microbatch=args.microbatch, remat=args.remat,
                    seed=args.seed, topology=_deep_tuple(topo_spec))
    res = trainer.train(arch, run, mesh, steps=args.steps,
                        aux_mode=args.aux_mode, log_every=args.log_every,
                        ckpt_path=args.ckpt)
    print(f"done: {args.steps} steps, {res.steps_per_sec:.3f} steps/s, "
          f"final loss {res.losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
