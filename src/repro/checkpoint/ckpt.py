"""Pytree checkpointing: flat-path .npz with structure manifest.

Deliberately simple and dependency-free (no orbax in the container):
leaves are saved as numpy arrays keyed by '/'-joined pytree paths; restore
rebuilds into an existing template (so shardings/dtypes are re-applied by
the caller via device_put).  Atomic via write-to-temp + rename.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat = {}

    def walk(path, node):
        leaves = jax.tree_util.tree_flatten_with_path(node)[0]
        for kp, leaf in leaves:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kp)
            flat[key] = np.asarray(leaf)
    walk((), tree)
    return flat


def save(path: str, tree, step: int | None = None):
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    meta = {"step": step, "num_leaves": len(flat)}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def restore(path: str, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for kp, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def latest_step(path: str):
    meta = path + ".meta.json"
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f).get("step")
