"""Pytree checkpointing: flat-path .npz with structure manifest.

Deliberately simple and dependency-free (no orbax in the container):
leaves are saved as numpy arrays keyed by '/'-joined pytree paths; restore
rebuilds into an existing template (so shardings/dtypes are re-applied by
the caller via device_put).  Both the payload and the ``.meta.json``
sidecar are written atomically (temp + rename), and the meta carries a
per-leaf sha256 manifest — ``restore`` verifies it, and ``verify`` lets
the resilience rollback path pick the newest *uncorrupted* rolling
checkpoint without raising.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat = {}

    def walk(path, node):
        leaves = jax.tree_util.tree_flatten_with_path(node)[0]
        for kp, leaf in leaves:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kp)
            flat[key] = np.asarray(leaf)
    walk((), tree)
    return flat


def _leaf_sha256(arr: np.ndarray) -> str:
    """Content hash covering dtype and shape as well as the bytes, so a
    silent dtype rewrite or reshape can't slip past the manifest."""
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(tuple(arr.shape)).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _write_atomic_json(path: str, obj) -> None:
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp.json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def save(path: str, tree, step: int | None = None):
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    meta = {"step": step, "num_leaves": len(flat),
            "manifest": {k: _leaf_sha256(v) for k, v in flat.items()}}
    _write_atomic_json(path + ".meta.json", meta)


def _load_meta(path: str) -> dict | None:
    meta = path + ".meta.json"
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f)


def restore(path: str, template, *, check_hashes: bool = True):
    """Restore into the structure of ``template``.

    Fails loudly — every mismatch is a ``ValueError`` naming the offending
    key: missing/extra keys, shape mismatches, dtype mismatches (no silent
    cast), and (when a manifest sidecar exists) per-leaf sha256 mismatches
    against what ``save`` wrote.  Checkpoints written before the manifest
    era restore without hash verification.
    """
    data = np.load(path)
    leaves, _ = jax.tree_util.tree_flatten_with_path(template)
    keys = {}
    for kp, leaf in leaves:
        keys["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp)] = leaf
    saved = set(data.files)
    missing = sorted(set(keys) - saved)
    extra = sorted(saved - set(keys))
    if missing:
        raise ValueError(
            f"checkpoint {path}: missing key {missing[0]!r}"
            + (f" (+{len(missing) - 1} more)" if len(missing) > 1 else ""))
    if extra:
        raise ValueError(
            f"checkpoint {path}: extra key {extra[0]!r} not in template"
            + (f" (+{len(extra) - 1} more)" if len(extra) > 1 else ""))
    meta = _load_meta(path) if check_hashes else None
    manifest = (meta or {}).get("manifest")
    out = []
    for kp, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"checkpoint {path}: key {key!r} has shape "
                             f"{arr.shape}, template wants "
                             f"{tuple(leaf.shape)}")
        if arr.dtype != np.dtype(leaf.dtype):
            raise ValueError(f"checkpoint {path}: key {key!r} has dtype "
                             f"{arr.dtype}, template wants "
                             f"{np.dtype(leaf.dtype)} (refusing to cast)")
        if manifest is not None:
            want = manifest.get(key)
            if want is None or _leaf_sha256(arr) != want:
                raise ValueError(f"checkpoint {path}: key {key!r} fails "
                                 f"sha256 manifest verification (corrupt "
                                 f"or stale payload)")
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def verify(path: str) -> bool:
    """True when the payload at ``path`` matches its sha256 manifest.

    Non-raising — any failure (unreadable payload, absent meta, key-set
    mismatch, hash mismatch) is ``False``.  The rollback policy uses this
    to walk rolling checkpoints newest-first and restore the first one
    that still proves integrity.
    """
    try:
        meta = _load_meta(path)
        if meta is None or "manifest" not in meta:
            return False
        manifest = meta["manifest"]
        data = np.load(path)
        if set(data.files) != set(manifest):
            return False
        return all(_leaf_sha256(data[k]) == manifest[k] for k in manifest)
    except Exception:
        return False


def latest_step(path: str):
    meta = _load_meta(path)
    return None if meta is None else meta.get("step")
