"""Logical-axis sharding rules and path-based parameter PartitionSpecs.

A thin layer between model code and the mesh: model code names *logical*
axes ("batch", "model", "expert", "kv_len"); the active :class:`AxisRules`
maps them to mesh axes.  ``constrain`` is a no-op outside a rules context so
the same model code runs on a single CPU device in tests.
"""

from __future__ import annotations

import contextlib
import re
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


class AxisRules:
    def __init__(self, mapping: dict, mesh=None):
        # logical name -> mesh axis (str | tuple | None)
        self.mapping = dict(mapping)
        self.mesh = mesh

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        return self.mapping.get(logical)

    def axis_size(self, logical: str) -> int:
        ax = self.resolve(logical)
        if ax is None or self.mesh is None:
            return 1
        if isinstance(ax, str):
            ax = (ax,)
        n = 1
        for a in ax:
            n *= self.mesh.shape[a]
        return n


def hierarchy_axes(mesh) -> tuple:
    """The mesh's batch/expert hierarchy axes, outermost-first.

    Every mesh axis except the tensor-parallel ``model`` axis, in mesh
    order — ``("data",)``, ``("pod", "data")``, ``("pod", "node", "data")``,
    ... for 1/2/3-tier meshes.  This is the single place the level-indexed
    stack derives its axis ordering from, so adding a topology tier only
    means constructing a deeper mesh (see launch/mesh.py).
    """
    return tuple(a for a in mesh.axis_names if a != "model")


def current_rules() -> AxisRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: AxisRules):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def logical_spec(*logical_axes, dims=None) -> P:
    """PartitionSpec from logical axis names; honours divisibility.

    ``dims``: optional concrete dim sizes — an axis whose size does not
    divide the mesh extent falls back to replication (e.g. 6 attention
    heads on a 16-way model axis).
    """
    rules = current_rules()
    if rules is None:
        return P()
    out = []
    for i, name in enumerate(logical_axes):
        ax = rules.resolve(name)
        if ax is not None and dims is not None:
            if dims[i] % rules.axis_size(name) != 0:
                ax = None
        out.append(ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = logical_spec(*logical_axes, dims=x.shape[: len(logical_axes)])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# parameter specs by pytree path
# ---------------------------------------------------------------------------


def build_param_specs(params, rules: list):
    """Assign a PartitionSpec to every leaf by regex on its '/'-joined path.

    ``rules`` is an ordered list of (regex, PartitionSpec); first match wins;
    default is fully replicated.  Specs longer than a leaf's rank or with
    non-divisible dims degrade gracefully (offending axis replicated).
    """
    compiled = [(re.compile(rx), spec) for rx, spec in rules]

    def path_str(path):
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    def assign(path, leaf):
        ps = path_str(path)
        for rx, spec in compiled:
            if rx.search(ps):
                return _fit_spec(spec, leaf)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def _fit_spec(spec: P, leaf) -> P:
    """Trim/repair a spec against a concrete leaf shape."""
    mesh_shape = None
    rules = current_rules()
    if rules is not None and rules.mesh is not None:
        mesh_shape = dict(rules.mesh.shape)
    dims = getattr(leaf, "shape", ())
    out = []
    for i, ax in enumerate(spec):
        if i >= len(dims):
            break
        if ax is None or mesh_shape is None:
            out.append(ax)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        extent = 1
        for a in axes:
            extent *= mesh_shape.get(a, 1)
        out.append(ax if dims[i] % extent == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)
