"""Composable MoE dispatch engine: routing → transport → compute → combine.

One pipeline, four (extensible) execution paths, resolved by name through a
registry — see engine.py for the path contract and ROADMAP.md for the
subsystem overview.

    from repro.core import dispatch
    eng = dispatch.make_engine("a2a_pipelined", cfg=cfg, ep=ep,
                               gate_cfg=gate_cfg, plan=plan, num_chunks=4)
    y, metrics = eng(params, x)          # inside shard_map over the EP axes
"""

from repro.core.dispatch.base import (          # noqa: F401
    EPSpec,
    MoEConfig,
    expert_ffn,
    expert_ffn_flat,
    init_moe_params,
    moe_param_specs,
    shared_ffn,
)
from repro.core.dispatch.engine import (        # noqa: F401
    METRIC_KEYS,
    DispatchEngine,
    DispatchPath,
    available,
    dispatch_moe,
    get_path,
    make_engine,
    register,
)
from repro.core.dispatch.routing import (       # noqa: F401
    DispatchIndices,
    Routing,
    Selection,
    build_indices,
    gather_inverse,
    pad_selection,
    route,
    score_matrix,
    select,
    slice_selection,
)
from repro.core.dispatch.schedule import software_pipeline  # noqa: F401
from repro.core.dispatch.wire import (          # noqa: F401
    CODECS,
    CastCodec,
    ScaledCodec,
    WireCodec,
    cast_codec,
    get_codec,
)
from repro.core.dispatch.transport import (     # noqa: F401
    A2ATransport,
    GatherTransport,
    Stage,
    expert_segments,
    plan_stages,
    stage_segments,
    wire_a2a,
)
