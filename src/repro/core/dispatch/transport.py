"""Transport stage: the collective movement primitives of each dispatch path.

A transport object owns *how bytes move between EP ranks* — nothing about
routing or scheduling.  Two families exist:

* :class:`A2ATransport` — equal-split staged ``lax.all_to_all``: one
  intra-pod stage over the data axis (``cap_near`` slots) and, on multipod
  meshes, a two-hop inter-pod delivery (pod axis then data axis,
  ``cap_far`` slots).  The wire-dtype cast (e.g. fp8 payload quantization)
  lives here, immediately around each collective, so only wire bytes are
  low-precision while compute stays in the model dtype.
* :class:`GatherTransport` — the weights-stationary decode regime: tokens
  are (all-)gathered to every EP rank and partial expert outputs are
  psum-combined; no all-to-all at all.

New transports (e.g. a ragged / sparsity-aware exchange) plug in by
implementing the same dispatch/combine surface and get picked up by a path
definition in engine.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dispatch.base import EPSpec


def wire_a2a(x, axis_name, *, split_axis, concat_axis, wire_dtype: str = ""):
    """all_to_all with optional on-the-wire quantization.

    The cast happens immediately around the collective so only the wire
    payload is low-precision; compute stays in the model dtype.  f8e4m3's
    +-448 range comfortably covers post-norm activations.
    """
    if wire_dtype:
        orig = x.dtype
        x = x.astype(jnp.dtype(wire_dtype))
        x = jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                               concat_axis=concat_axis, tiled=True)
        return x.astype(orig)
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


@dataclasses.dataclass(frozen=True)
class A2ATransport:
    """Equal-split staged all-to-all over the EP mesh axes."""

    ep: EPSpec
    wire_dtype: str = ""

    def dispatch_near(self, buf):
        """[P1, E_l, C, d] local buffer -> [E_l, P1*C, d] expert rows."""
        P1, E_l, C, d = buf.shape
        recv = wire_a2a(buf, self.ep.data_axis, split_axis=0, concat_axis=0,
                        wire_dtype=self.wire_dtype)
        return recv.transpose(1, 0, 2, 3).reshape(E_l, P1 * C, d)

    def dispatch_far(self, buf):
        """[Q, P1, E_l, C, d] local buffer -> [E_l, Q*P1*C, d] expert rows."""
        Q, P1, E_l, C, d = buf.shape
        # pod exchange: slice [q] -> pod q (carries tokens for (q, *) ranks)
        t = wire_a2a(buf, self.ep.pod_axis, split_axis=0, concat_axis=0,
                     wire_dtype=self.wire_dtype)
        # deliver within pod: axis 1 is the destination data index
        t = wire_a2a(t, self.ep.data_axis, split_axis=1, concat_axis=1,
                     wire_dtype=self.wire_dtype)
        # t[q, s]: tokens from rank (q, s) for my experts
        return t.transpose(2, 0, 1, 3, 4).reshape(E_l, Q * P1 * C, d)

    def combine_near(self, y):
        """[E_l, P1*C, d] expert outputs -> [P1, E_l, C, d] at the source."""
        P1 = self.ep.ep_per_pod
        E_l, R, d = y.shape
        y = y.reshape(E_l, P1, R // P1, d).transpose(1, 0, 2, 3)
        return wire_a2a(y, self.ep.data_axis, split_axis=0, concat_axis=0,
                        wire_dtype=self.wire_dtype)

    def combine_far(self, y):
        """[E_l, Q*P1*C, d] expert outputs -> [Q, P1, E_l, C, d] at source."""
        n_pods, P1 = self.ep.num_pods, self.ep.ep_per_pod
        E_l, R, d = y.shape
        y = y.reshape(E_l, n_pods, P1, R // (n_pods * P1), d)
        y = y.transpose(1, 2, 0, 3, 4)                   # [Q, P1, E_l, C, d]
        y = wire_a2a(y, self.ep.data_axis, split_axis=1, concat_axis=1,
                     wire_dtype=self.wire_dtype)
        return wire_a2a(y, self.ep.pod_axis, split_axis=0, concat_axis=0,
                        wire_dtype=self.wire_dtype)


@dataclasses.dataclass(frozen=True)
class GatherTransport:
    """Weights-stationary transport: gather tokens, psum partial outputs."""

    ep: EPSpec
    tokens_replicated: bool = False   # tokens already on every EP rank

    @property
    def multipod(self) -> bool:
        return self.ep.pod_axis is not None and self.ep.num_pods > 1

    def gather(self, x):
        """[T_local, d] -> [T_global, d] on every EP rank."""
        if self.tokens_replicated:
            return x
        xg = jax.lax.all_gather(x, self.ep.data_axis, axis=0, tiled=True)
        if self.multipod:
            xg = jax.lax.all_gather(xg, self.ep.pod_axis, axis=0, tiled=True)
        return xg

    def reduce(self, y):
        """Sum each rank's partial expert outputs across the EP axes."""
        y = jax.lax.psum(y, self.ep.data_axis)
        if self.multipod:
            y = jax.lax.psum(y, self.ep.pod_axis)
        return y

    def slice_local(self, y, my_rank, T: int):
        """[T_global, d] -> this rank's [T_local, d] slice (no-op when the
        tokens were replicated)."""
        if self.tokens_replicated:
            return y
        return jax.lax.dynamic_slice_in_dim(y, my_rank * T, T, axis=0)
