"""Transport stage: the collective movement primitives of each dispatch path.

A transport object owns *how bytes move between EP ranks* — nothing about
routing or scheduling.  Two families exist:

* :class:`A2ATransport` — equal-split staged ``lax.all_to_all`` driven by a
  list of :class:`Stage` objects derived from the level-indexed
  :class:`~repro.core.capacity.DispatchPlan`.  Stage ``s`` delivers over
  the innermost ``s + 1`` EP mesh axes as a chain of all_to_alls
  (outermost hop first), so a 2-axis mesh reproduces the PR-2 near/far
  pair and an N-axis mesh gets N stages with no new code.  The wire
  encoding (:mod:`repro.core.dispatch.wire` codec: cast, or per-segment
  scaled int8/fp8 quantization) lives here: the payload is encoded once
  before the hop chain, the f32 scale sideband rides the *same* chain the
  per-segment counts use, and decode happens after the final transpose —
  so only wire bytes are low-precision while compute stays in the model
  dtype (unless the codec opts delivered rows into quantized compute).
* :class:`GatherTransport` — the weights-stationary decode regime: tokens
  are (all-)gathered to every EP rank and partial expert outputs are
  psum-combined; no all-to-all at all.

Buffer layout contract with the moe_permute dispatch: the payload arrives
already (stage, destination, expert, slot)-sorted, so each stage's
delivered rows are *contiguous per-expert spans* — :func:`expert_segments`
derives the static segment-offset vector the grouped GEMM entry
(``moe_gemm.ops.grouped_ffn_segments``) consumes, and the all_to_all
chains themselves are unchanged (equal splits of a sorted buffer stay
sorted).

New transports (e.g. a ragged / sparsity-aware exchange) plug in by
implementing the same dispatch/combine surface and get picked up by a path
definition in engine.py.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.dispatch import wire as wire_lib
from repro.core.dispatch.base import EPSpec


def _a2a(x, axis_name, *, split_axis, concat_axis):
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def wire_a2a(x, axis_name, *, split_axis, concat_axis, wire_dtype: str = ""):
    """all_to_all with an optional (deprecated) on-the-wire dtype cast.

    ``wire_dtype=`` resolves to the cast-only codec with a
    DeprecationWarning; scaled codecs need the segment layout only
    :class:`A2ATransport` knows, so quantized wire goes through a
    transport built with ``codec=`` instead of this helper.
    """
    codec = wire_lib.resolve(None, wire_dtype)
    if codec is not None:
        payload, _ = codec.encode(x)
        payload = _a2a(payload, axis_name, split_axis=split_axis,
                       concat_axis=concat_axis)
        return codec.decode(payload, None, x.dtype)
    return _a2a(x, axis_name, split_axis=split_axis, concat_axis=concat_axis)


@dataclasses.dataclass(frozen=True)
class Stage:
    """One level-indexed exchange stage of a dispatch plan.

    ``axis_names``/``axis_sizes`` are the delivery chain, outermost hop
    first: stage ``index`` traverses the innermost ``index + 1`` EP mesh
    axes.  ``cap`` is the per-(source device, expert) token capacity the
    routing stage selects for this level.
    """

    index: int                    # dispatch stage (0 = innermost / "near")
    axis_names: tuple             # delivery chain, outermost hop first
    axis_sizes: tuple
    cap: int

    @property
    def num_dests(self) -> int:
        """Destination ranks addressed by this stage's buffer (incl. the
        lower-stage block that routing masks out)."""
        n = 1
        for s in self.axis_sizes:
            n *= s
        return n


def plan_stages(plan, ep: EPSpec) -> tuple:
    """Active :class:`Stage` list for one plan on one EP spec.

    The plan's ``level_axes`` name the canonical hierarchy; the EP spec is
    authoritative for the mesh axis names actually bound inside shard_map,
    so stages are rebuilt from ``ep.hierarchy`` and validated against the
    plan's stage count.
    """
    names, sizes = ep.axis_names, ep.axis_sizes
    n = len(names)
    assert plan.num_stages == n, (
        f"plan has {plan.num_stages} stages but the EP spec spans {n} mesh "
        f"axes {names}; rebuild the plan for this mesh")
    return tuple(Stage(index=s, axis_names=names[n - s - 1:],
                       axis_sizes=sizes[n - s - 1:], cap=plan.caps[s])
                 for s in range(n) if plan.caps[s] > 0)


def expert_segments(num_experts: int, rows_per_expert: int) -> tuple:
    """Static [E + 1] segment-offset vector of a delivered stage buffer:
    expert ``e`` owns flat rows ``offs[e]:offs[e + 1]`` of the
    [E * rows, d] view — the contract between the sorted a2a payload and
    ``moe_gemm.ops.grouped_ffn_segments``."""
    return tuple(rows_per_expert * e for e in range(num_experts + 1))


def stage_segments(num_experts: int, stage_widths) -> tuple:
    """Fine-grained ``(seg_offsets, seg_experts)`` of a delivered buffer
    concatenated over stages: flat row order is (expert, stage,
    destination, capacity-slot) and ``stage_widths`` is the static
    ``((num_dests, rows_per_dest), ...)`` stage list.  One segment per
    (expert, stage, source destination) — the granularity at which the
    delivered rows are a valid prefix, and therefore the granularity the
    occupancy-aware ragged GEMM masks at."""
    offs, exps = [0], []
    for e in range(num_experts):
        for num_dests, width in stage_widths:
            for _ in range(num_dests):
                offs.append(offs[-1] + width)
                exps.append(e)
    return tuple(offs), tuple(exps)


def _dispatch_perm(buf, stage: Stage):
    """Codec-free dispatch: the pure element permutation.  [*sizes, E_l,
    C, d] -> a2a chain (outermost hop first) -> [E_l, num_dests*C, d]."""
    k = len(stage.axis_names)
    for i in range(k):
        buf = _a2a(buf, stage.axis_names[i], split_axis=i, concat_axis=i)
    E_l, C, d = buf.shape[k:]
    perm = (k,) + tuple(range(k)) + (k + 1, k + 2)
    return buf.transpose(perm).reshape(E_l, stage.num_dests * C, d)


def _combine_perm(y, stage: Stage):
    """Inverse (== transpose) of :func:`_dispatch_perm`: [E_l,
    num_dests*C, d] -> reverse a2a chain -> [*sizes, E_l, C, d]."""
    sizes = stage.axis_sizes
    k = len(sizes)
    E_l, R, d = y.shape
    y = y.reshape((E_l,) + sizes + (R // stage.num_dests, d))
    perm = tuple(range(1, k + 1)) + (0, k + 1, k + 2)
    y = y.transpose(perm)                         # [*sizes, E_l, C, d]
    for i in range(k - 1, -1, -1):
        y = _a2a(y, stage.axis_names[i], split_axis=i, concat_axis=i)
    return y


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _dispatch_scaled(codec, stage: Stage, buf):
    """Scaled-codec dispatch: encode once, move (payload, scale) through
    the same chain, decode after the final transpose.

    Straight-through gradient: ``round`` and the float->int8 cast are
    non-differentiable, so the backward pass is the exact full-precision
    reverse permutation (the forward is a permutation up to rounding) —
    quantized wire on the way out, f32 cotangents on the way back.
    """
    k = len(stage.axis_names)
    payload, scale = codec.encode(buf, block_ndim=2)
    for i in range(k):
        ax = stage.axis_names[i]
        payload = _a2a(payload, ax, split_axis=i, concat_axis=i)
        scale = _a2a(scale, ax, split_axis=i, concat_axis=i)
    E_l, C, d = payload.shape[k:]
    perm = (k,) + tuple(range(k)) + (k + 1, k + 2)
    out = payload.transpose(perm).reshape(E_l, stage.num_dests, C, d)
    s = scale.transpose((k,) + tuple(range(k))).reshape(E_l, stage.num_dests)
    return codec.decode(out, s[:, :, None, None], buf.dtype).reshape(
        E_l, stage.num_dests * C, d)


def _dispatch_scaled_fwd(codec, stage, buf):
    return _dispatch_scaled(codec, stage, buf), None


def _dispatch_scaled_bwd(codec, stage, _res, g):
    # the cotangent already carries the source dtype (decode casts there)
    return (_combine_perm(g, stage),)


_dispatch_scaled.defvjp(_dispatch_scaled_fwd, _dispatch_scaled_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _combine_scaled(codec, stage: Stage, y):
    """Scaled-codec combine: transpose back to the send layout, encode,
    reverse chain, decode at the source.  Same straight-through backward
    as :func:`_dispatch_scaled`."""
    sizes = stage.axis_sizes
    k = len(sizes)
    E_l, R, d = y.shape
    orig = y.dtype
    y = y.reshape((E_l,) + sizes + (R // stage.num_dests, d))
    perm = tuple(range(1, k + 1)) + (0, k + 1, k + 2)
    y = y.transpose(perm)                         # [*sizes, E_l, C, d]
    payload, scale = codec.encode(y, block_ndim=2)
    for i in range(k - 1, -1, -1):
        ax = stage.axis_names[i]
        payload = _a2a(payload, ax, split_axis=i, concat_axis=i)
        scale = _a2a(scale, ax, split_axis=i, concat_axis=i)
    return codec.decode(payload, scale[..., None, None], orig)


def _combine_scaled_fwd(codec, stage, y):
    return _combine_scaled(codec, stage, y), None


def _combine_scaled_bwd(codec, stage, _res, g):
    return (_dispatch_perm(g, stage),)


_combine_scaled.defvjp(_combine_scaled_fwd, _combine_scaled_bwd)


@dataclasses.dataclass(frozen=True)
class A2ATransport:
    """Equal-split staged all-to-all over the EP mesh axes.

    ``codec`` (a :mod:`repro.core.dispatch.wire` codec, a registered codec
    name, or None for a raw wire) owns the payload encoding.  Scaled
    codecs compute one f32 scale per (destination, expert) ``[C, d]``
    block — shaped ``[*sizes, E_l]``, exactly the :meth:`dispatch_counts`
    metadata layout — and the scale sideband rides the identical
    split/concat chain as the payload, landing as ``[E_l, num_dests]`` at
    the receiver.  Scaled transfers differentiate straight-through: the
    backward pass moves full-precision cotangents over the exact reverse
    permutation, so quantized wire stays trainable.  ``wire_dtype`` is
    the deprecated stringly alias and resolves to the byte-identical cast
    codec with a DeprecationWarning.
    """

    ep: EPSpec
    wire_dtype: str = ""          # deprecated: use codec=
    codec: wire_lib.WireCodec | str | None = None

    def __post_init__(self):
        object.__setattr__(
            self, "codec",
            wire_lib.resolve(self.codec, self.wire_dtype, stacklevel=4))

    def dispatch(self, buf, stage: Stage):
        """[*sizes, E_l, C, d] local buffer -> [E_l, prod(sizes)*C, d]
        expert rows, via a chain of all_to_alls (outermost hop first)."""
        if self.codec is None:
            return _dispatch_perm(buf, stage)
        if self.codec.scaled:
            return _dispatch_scaled(self.codec, stage, buf)
        # cast codec: a plain dtype cast around the permutation (autodiff
        # handles the cast, so no straight-through wrapper is needed)
        payload, _ = self.codec.encode(buf, block_ndim=2)
        return self.codec.decode(_dispatch_perm(payload, stage), None,
                                 buf.dtype)

    def dispatch_counts(self, cnt, stage: Stage):
        """[*sizes, E_l] per-(destination, expert) valid-row counts ->
        [E_l, num_dests] per-(expert, source) counts at the receiver.

        Runs the *same* all_to_all chain and transpose as :meth:`dispatch`
        (minus the trailing [C, d] payload dims and the wire-dtype cast —
        counts travel exact), so entry ``[e, g]`` describes exactly the
        ``g``-th capacity chunk of expert ``e``'s delivered rows.  This is
        the tiny metadata exchange that lets the occupancy-aware grouped
        GEMM size its compute by realized tokens."""
        k = len(stage.axis_names)
        for i, ax in enumerate(stage.axis_names):
            cnt = jax.lax.all_to_all(cnt, ax, split_axis=i, concat_axis=i,
                                     tiled=True)
        perm = (k,) + tuple(range(k))
        return cnt.transpose(perm).reshape(cnt.shape[k], stage.num_dests)

    def combine(self, y, stage: Stage):
        """[E_l, prod(sizes)*C, d] expert outputs -> [*sizes, E_l, C, d]
        back at the source (reverse chain, innermost hop first)."""
        if self.codec is None:
            return _combine_perm(y, stage)
        if self.codec.scaled:
            return _combine_scaled(self.codec, stage, y)
        orig = y.dtype
        payload, _ = self.codec.encode(y, block_ndim=2)
        return self.codec.decode(_combine_perm(payload, stage), None, orig)

    # --- deprecated near/far wrappers (PR-2 compat) ------------------------

    def _stage2(self, index: int) -> Stage:
        names, sizes = self.ep.axis_names, self.ep.axis_sizes
        n = len(names)
        return Stage(index=index, axis_names=names[n - index - 1:],
                     axis_sizes=sizes[n - index - 1:], cap=0)

    def dispatch_near(self, buf):
        """Deprecated: ``dispatch(buf, stage 0)``."""
        return self.dispatch(buf, self._stage2(0))

    def dispatch_far(self, buf):
        """Deprecated: ``dispatch(buf, stage 1)``."""
        return self.dispatch(buf, self._stage2(1))

    def combine_near(self, y):
        """Deprecated: ``combine(y, stage 0)``."""
        return self.combine(y, self._stage2(0))

    def combine_far(self, y):
        """Deprecated: ``combine(y, stage 1)``."""
        return self.combine(y, self._stage2(1))


@dataclasses.dataclass(frozen=True)
class GatherTransport:
    """Weights-stationary transport: gather tokens, psum partial outputs."""

    ep: EPSpec
    tokens_replicated: bool = False   # tokens already on every EP rank

    def gather(self, x):
        """[T_local, d] -> [T_global, d] on every EP rank.

        Gathers innermost axis first so the global order is outermost-major
        rank order — matching the mixed-radix ``my_rank`` numbering."""
        if self.tokens_replicated:
            return x
        for ax in reversed(self.ep.axis_names):
            x = jax.lax.all_gather(x, ax, axis=0, tiled=True)
        return x

    def reduce(self, y):
        """Sum each rank's partial expert outputs across the EP axes."""
        for ax in self.ep.axis_names:
            y = jax.lax.psum(y, ax)
        return y

    def slice_local(self, y, my_rank, T: int):
        """[T_global, d] -> this rank's [T_local, d] slice (no-op when the
        tokens were replicated)."""
        if self.tokens_replicated:
            return y
        return jax.lax.dynamic_slice_in_dim(y, my_rank * T, T, axis=0)
