"""Transport stage: the collective movement primitives of each dispatch path.

A transport object owns *how bytes move between EP ranks* — nothing about
routing or scheduling.  Two families exist:

* :class:`A2ATransport` — equal-split staged ``lax.all_to_all`` driven by a
  list of :class:`Stage` objects derived from the level-indexed
  :class:`~repro.core.capacity.DispatchPlan`.  Stage ``s`` delivers over
  the innermost ``s + 1`` EP mesh axes as a chain of all_to_alls
  (outermost hop first), so a 2-axis mesh reproduces the PR-2 near/far
  pair and an N-axis mesh gets N stages with no new code.  The wire-dtype
  cast (e.g. fp8 payload quantization) lives here, immediately around each
  collective, so only wire bytes are low-precision while compute stays in
  the model dtype.
* :class:`GatherTransport` — the weights-stationary decode regime: tokens
  are (all-)gathered to every EP rank and partial expert outputs are
  psum-combined; no all-to-all at all.

Buffer layout contract with the moe_permute dispatch: the payload arrives
already (stage, destination, expert, slot)-sorted, so each stage's
delivered rows are *contiguous per-expert spans* — :func:`expert_segments`
derives the static segment-offset vector the grouped GEMM entry
(``moe_gemm.ops.grouped_ffn_segments``) consumes, and the all_to_all
chains themselves are unchanged (equal splits of a sorted buffer stay
sorted).

New transports (e.g. a ragged / sparsity-aware exchange) plug in by
implementing the same dispatch/combine surface and get picked up by a path
definition in engine.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dispatch.base import EPSpec


def wire_a2a(x, axis_name, *, split_axis, concat_axis, wire_dtype: str = ""):
    """all_to_all with optional on-the-wire quantization.

    The cast happens immediately around the collective so only the wire
    payload is low-precision; compute stays in the model dtype.  f8e4m3's
    +-448 range comfortably covers post-norm activations.
    """
    if wire_dtype:
        orig = x.dtype
        x = x.astype(jnp.dtype(wire_dtype))
        x = jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                               concat_axis=concat_axis, tiled=True)
        return x.astype(orig)
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


@dataclasses.dataclass(frozen=True)
class Stage:
    """One level-indexed exchange stage of a dispatch plan.

    ``axis_names``/``axis_sizes`` are the delivery chain, outermost hop
    first: stage ``index`` traverses the innermost ``index + 1`` EP mesh
    axes.  ``cap`` is the per-(source device, expert) token capacity the
    routing stage selects for this level.
    """

    index: int                    # dispatch stage (0 = innermost / "near")
    axis_names: tuple             # delivery chain, outermost hop first
    axis_sizes: tuple
    cap: int

    @property
    def num_dests(self) -> int:
        """Destination ranks addressed by this stage's buffer (incl. the
        lower-stage block that routing masks out)."""
        n = 1
        for s in self.axis_sizes:
            n *= s
        return n


def plan_stages(plan, ep: EPSpec) -> tuple:
    """Active :class:`Stage` list for one plan on one EP spec.

    The plan's ``level_axes`` name the canonical hierarchy; the EP spec is
    authoritative for the mesh axis names actually bound inside shard_map,
    so stages are rebuilt from ``ep.hierarchy`` and validated against the
    plan's stage count.
    """
    names, sizes = ep.axis_names, ep.axis_sizes
    n = len(names)
    assert plan.num_stages == n, (
        f"plan has {plan.num_stages} stages but the EP spec spans {n} mesh "
        f"axes {names}; rebuild the plan for this mesh")
    return tuple(Stage(index=s, axis_names=names[n - s - 1:],
                       axis_sizes=sizes[n - s - 1:], cap=plan.caps[s])
                 for s in range(n) if plan.caps[s] > 0)


def expert_segments(num_experts: int, rows_per_expert: int) -> tuple:
    """Static [E + 1] segment-offset vector of a delivered stage buffer:
    expert ``e`` owns flat rows ``offs[e]:offs[e + 1]`` of the
    [E * rows, d] view — the contract between the sorted a2a payload and
    ``moe_gemm.ops.grouped_ffn_segments``."""
    return tuple(rows_per_expert * e for e in range(num_experts + 1))


def stage_segments(num_experts: int, stage_widths) -> tuple:
    """Fine-grained ``(seg_offsets, seg_experts)`` of a delivered buffer
    concatenated over stages: flat row order is (expert, stage,
    destination, capacity-slot) and ``stage_widths`` is the static
    ``((num_dests, rows_per_dest), ...)`` stage list.  One segment per
    (expert, stage, source destination) — the granularity at which the
    delivered rows are a valid prefix, and therefore the granularity the
    occupancy-aware ragged GEMM masks at."""
    offs, exps = [0], []
    for e in range(num_experts):
        for num_dests, width in stage_widths:
            for _ in range(num_dests):
                offs.append(offs[-1] + width)
                exps.append(e)
    return tuple(offs), tuple(exps)


@dataclasses.dataclass(frozen=True)
class A2ATransport:
    """Equal-split staged all-to-all over the EP mesh axes."""

    ep: EPSpec
    wire_dtype: str = ""

    def dispatch(self, buf, stage: Stage):
        """[*sizes, E_l, C, d] local buffer -> [E_l, prod(sizes)*C, d]
        expert rows, via a chain of all_to_alls (outermost hop first)."""
        k = len(stage.axis_names)
        for i, ax in enumerate(stage.axis_names):
            buf = wire_a2a(buf, ax, split_axis=i, concat_axis=i,
                           wire_dtype=self.wire_dtype)
        E_l, C, d = buf.shape[k:]
        perm = (k,) + tuple(range(k)) + (k + 1, k + 2)
        return buf.transpose(perm).reshape(E_l, stage.num_dests * C, d)

    def dispatch_counts(self, cnt, stage: Stage):
        """[*sizes, E_l] per-(destination, expert) valid-row counts ->
        [E_l, num_dests] per-(expert, source) counts at the receiver.

        Runs the *same* all_to_all chain and transpose as :meth:`dispatch`
        (minus the trailing [C, d] payload dims and the wire-dtype cast —
        counts travel exact), so entry ``[e, g]`` describes exactly the
        ``g``-th capacity chunk of expert ``e``'s delivered rows.  This is
        the tiny metadata exchange that lets the occupancy-aware grouped
        GEMM size its compute by realized tokens."""
        k = len(stage.axis_names)
        for i, ax in enumerate(stage.axis_names):
            cnt = jax.lax.all_to_all(cnt, ax, split_axis=i, concat_axis=i,
                                     tiled=True)
        perm = (k,) + tuple(range(k))
        return cnt.transpose(perm).reshape(cnt.shape[k], stage.num_dests)

    def combine(self, y, stage: Stage):
        """[E_l, prod(sizes)*C, d] expert outputs -> [*sizes, E_l, C, d]
        back at the source (reverse chain, innermost hop first)."""
        sizes = stage.axis_sizes
        k = len(sizes)
        E_l, R, d = y.shape
        y = y.reshape((E_l,) + sizes + (R // stage.num_dests, d))
        perm = tuple(range(1, k + 1)) + (0, k + 1, k + 2)
        y = y.transpose(perm)                     # [*sizes, E_l, C, d]
        for i in range(k - 1, -1, -1):
            y = wire_a2a(y, stage.axis_names[i], split_axis=i, concat_axis=i,
                         wire_dtype=self.wire_dtype)
        return y

    # --- deprecated near/far wrappers (PR-2 compat) ------------------------

    def _stage2(self, index: int) -> Stage:
        names, sizes = self.ep.axis_names, self.ep.axis_sizes
        n = len(names)
        return Stage(index=index, axis_names=names[n - index - 1:],
                     axis_sizes=sizes[n - index - 1:], cap=0)

    def dispatch_near(self, buf):
        """Deprecated: ``dispatch(buf, stage 0)``."""
        return self.dispatch(buf, self._stage2(0))

    def dispatch_far(self, buf):
        """Deprecated: ``dispatch(buf, stage 1)``."""
        return self.dispatch(buf, self._stage2(1))

    def combine_near(self, y):
        """Deprecated: ``combine(y, stage 0)``."""
        return self.combine(y, self._stage2(0))

    def combine_far(self, y):
        """Deprecated: ``combine(y, stage 1)``."""
        return self.combine(y, self._stage2(1))


@dataclasses.dataclass(frozen=True)
class GatherTransport:
    """Weights-stationary transport: gather tokens, psum partial outputs."""

    ep: EPSpec
    tokens_replicated: bool = False   # tokens already on every EP rank

    def gather(self, x):
        """[T_local, d] -> [T_global, d] on every EP rank.

        Gathers innermost axis first so the global order is outermost-major
        rank order — matching the mixed-radix ``my_rank`` numbering."""
        if self.tokens_replicated:
            return x
        for ax in reversed(self.ep.axis_names):
            x = jax.lax.all_gather(x, ax, axis=0, tiled=True)
        return x

    def reduce(self, y):
        """Sum each rank's partial expert outputs across the EP axes."""
        for ax in self.ep.axis_names:
            y = jax.lax.psum(y, ax)
        return y

    def slice_local(self, y, my_rank, T: int):
        """[T_global, d] -> this rank's [T_local, d] slice (no-op when the
        tokens were replicated)."""
        if self.tokens_replicated:
            return y
        return jax.lax.dynamic_slice_in_dim(y, my_rank * T, T, axis=0)
