"""Shared MoE building blocks for every dispatch path.

This module owns the pieces that are *schedule-independent*: the layer /
expert-parallel configuration dataclasses, parameter init + partition
specs, and the grouped expert FFN (plus the DeepSeek-style shared-expert
FFN).  The dispatch stages compose around these:

    routing.py   — gate + per-level token selection (identical for all
                   staged paths; what makes their outputs equivalent)
    transport.py — the collective movement primitives (near/far a2a,
                   gather/psum) with the wire-dtype cast
    schedule.py  — the software-pipeline execution skeleton
    engine.py    — the registry that composes the above into named paths

Everything here runs INSIDE ``shard_map`` over the expert-parallel mesh
axes; see engine.py for the path contract.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gating
from repro.core.dispatch import wire as wire_lib


@dataclasses.dataclass(frozen=True)
class EPSpec:
    """How expert parallelism maps onto the mesh.

    The canonical description is ``hierarchy``: ordered
    ``(axis_name, size)`` pairs, outermost-first, covering every mesh axis
    the experts span (e.g. ``(("pod", 2), ("node", 2), ("data", 4))``).
    When omitted it is derived from the legacy 2-level
    ``num_pods``/``ep_per_pod``/``pod_axis``/``data_axis`` fields, which
    remain the constructor surface for 2-level callers.
    """
    num_pods: int                 # pods over which experts span (1 = no pod span)
    ep_per_pod: int               # "data"-axis size
    pod_axis: str | None       # mesh axis name, None when experts don't span pods
    data_axis: str
    model_axis: str | None     # tensor-parallel axis for d_ff
    hierarchy: tuple = ()         # ((axis_name, size), ...) outermost-first

    def __post_init__(self):
        if not self.hierarchy:
            # legacy multipod semantics: the pod tier only exists when the
            # experts actually span pods (pod_axis set AND num_pods > 1)
            multipod = self.pod_axis is not None and self.num_pods > 1
            h = (((self.pod_axis, self.num_pods),) if multipod else ()) \
                + ((self.data_axis, self.ep_per_pod),)
            object.__setattr__(self, "hierarchy", h)

    @classmethod
    def from_axes(cls, axis_names, axis_sizes, model_axis=None) -> EPSpec:
        """Build an N-level spec; the legacy fields become the 2-level
        summary (outer axes collapsed into ``num_pods``)."""
        names = tuple(axis_names)
        sizes = tuple(int(s) for s in axis_sizes)
        assert len(names) == len(sizes) and names, (names, sizes)
        outer = 1
        for s in sizes[:-1]:
            outer *= s
        return cls(num_pods=outer, ep_per_pod=sizes[-1],
                   pod_axis=names[0] if len(names) > 1 else None,
                   data_axis=names[-1], model_axis=model_axis,
                   hierarchy=tuple(zip(names, sizes)))

    @property
    def axis_names(self) -> tuple:
        """EP mesh-axis names, outermost-first."""
        return tuple(n for n, _ in self.hierarchy)

    @property
    def axis_sizes(self) -> tuple:
        """EP mesh extents, outermost-first."""
        return tuple(s for _, s in self.hierarchy)

    @property
    def num_stages(self) -> int:
        """Dispatch stages = EP mesh axes (stage 0 = innermost)."""
        return len(self.hierarchy)

    @property
    def ep_world(self) -> int:
        n = 1
        for s in self.axis_sizes:
            n *= s
        return n

    def ep_axes(self):
        return self.axis_names


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                     # per-expert intermediate size
    num_experts: int              # routed experts N
    top_k: int
    capacity_factor: float = 1.25
    num_shared_experts: int = 0   # DeepSeek-style always-on experts
    activation: str = "swiglu"    # "swiglu" | "gelu"
    dtype: jnp.dtype = jnp.bfloat16
    use_kernel: bool = False      # Pallas grouped GEMM for expert FFN
    a2a_dtype: str = ""           # deprecated alias for wire_codec: a raw
                                  # dtype name resolves to the cast-only
                                  # codec (DeprecationWarning)
    wire_codec: object = None     # wire.WireCodec | registered name | None:
                                  # what dispatch/combine payloads look
                                  # like on the a2a wire (§Perf.2)

    def __post_init__(self):
        # resolve once at config time: unknown names fail here with the
        # registry listed, not deep inside jnp.dtype at trace time
        object.__setattr__(
            self, "wire_codec",
            wire_lib.resolve(self.wire_codec, self.a2a_dtype, stacklevel=4))


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_moe_params(key, cfg: MoEConfig, ep: EPSpec, gate_cfg: gating.GateConfig):
    """Global (unsharded-view) parameter pytree for one MoE layer.

    Expert tensors carry the full N on axis 0; the caller shards axis 0 over
    the EP axes and the d_ff axis over ``model``.
    """
    keys = jax.random.split(key, 8)
    d, f, n = cfg.d_model, cfg.d_ff, cfg.num_experts
    s1 = (1.0 / np.sqrt(d))
    s2 = (1.0 / np.sqrt(f))
    p = {
        "gate": gating.init_gate_params(keys[0], d, gate_cfg),
        "w_in": jax.random.normal(keys[1], (n, d, f), cfg.dtype) * s1,
        "w_out": jax.random.normal(keys[2], (n, f, d), cfg.dtype) * s2,
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = jax.random.normal(keys[3], (n, d, f), cfg.dtype) * s1
    if cfg.num_shared_experts:
        fs = cfg.d_ff * cfg.num_shared_experts
        p["shared_in"] = jax.random.normal(keys[4], (d, fs), cfg.dtype) * s1
        p["shared_out"] = jax.random.normal(keys[5], (fs, d), cfg.dtype) * s2
        if cfg.activation == "swiglu":
            p["shared_gate"] = jax.random.normal(keys[6], (d, fs), cfg.dtype) * s1
    return p


def moe_param_specs(cfg: MoEConfig, ep: EPSpec):
    """PartitionSpec pytree matching init_moe_params."""
    from jax.sharding import PartitionSpec as P
    expert_axes = (ep.ep_axes() if len(ep.ep_axes()) > 1 else ep.data_axis)
    if isinstance(expert_axes, tuple) and len(expert_axes) == 1:
        expert_axes = expert_axes[0]
    m = ep.model_axis
    specs = {
        "gate": {"w": P(None, None)},
        "w_in": P(expert_axes, None, m),
        "w_out": P(expert_axes, m, None),
    }
    if cfg.activation == "swiglu":
        specs["w_gate"] = P(expert_axes, None, m)
    if cfg.num_shared_experts:
        specs["shared_in"] = P(None, m)
        specs["shared_out"] = P(m, None)
        if cfg.activation == "swiglu":
            specs["shared_gate"] = P(None, m)
    return specs


# ---------------------------------------------------------------------------
# expert FFN (grouped)
# ---------------------------------------------------------------------------


def _act(cfg, xin, params):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xin, params["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xin, params["w_in"]))
    return h


def expert_ffn(params, xin, cfg: MoEConfig, ep: EPSpec, *,
               chunk_granular: bool = False):
    """Grouped expert FFN on [E_local, C, d] -> [E_local, C, d].

    d_ff is sharded over the model axis; the output psum happens here so the
    caller sees full activations.  ``chunk_granular`` routes through the
    row-padding kernel entry sized for pipelined-dispatch chunk slices.
    """
    if cfg.use_kernel:
        from repro.kernels.moe_gemm import ops as moe_gemm_ops
        ffn = (moe_gemm_ops.grouped_ffn_chunk if chunk_granular
               else moe_gemm_ops.grouped_ffn)
        y = ffn(
            xin, params["w_in"],
            params.get("w_gate"), params["w_out"],
            activation=cfg.activation)
    else:
        h = _act(cfg, xin, params)
        y = jnp.einsum("ecf,efd->ecd", h, params["w_out"])
    if ep.model_axis is not None:
        y = jax.lax.psum(y, ep.model_axis)
    return y


def expert_ffn_flat(params, x_flat, seg_offsets, cfg: MoEConfig, ep: EPSpec,
                    *, seg_experts=None, rows_valid=None,
                    chunk_granular: bool = False, use_pallas=None,
                    slot_to_token=None, slot_w=None,
                    quantized: bool = False):
    """Segment-offset grouped expert FFN on a flat [R, d] row buffer.

    ``seg_offsets`` is the static offset vector of the contiguous sorted
    spans the moe_permute dispatch delivers; ``seg_experts`` names each
    segment's expert (default: one segment per expert, in order) and
    ``rows_valid`` optionally carries the *runtime* realized-row count per
    segment — the occupancy view of TA-MoE's capacity slack.  Semantics
    match :func:`expert_ffn` on the segment-reshaped view — same model-axis
    psum, same zero-slot convention (callers keep rows past the valid count
    zero-filled; outputs there are zero either way, computed-from-zeros or
    skipped).

    Fused mode: passing ``slot_to_token`` / ``slot_w`` (the flat sort-order
    maps of ``routing.build_indices``) switches the meaning of ``x_flat``
    from the segment-sorted slot buffer to the **raw [T, d] token buffer**
    — dispatch gather, expert FFN, and the gate-weighted combine run as one
    ``moe_fused.local_moe`` call and the return value is the [T, d] float32
    combined output.  The model-axis psum still happens here (the
    down-projection partials commute with the linear combine scatter), so
    callers see full activations either way.

    Quantized compute: ``quantized=True`` (the engine sets it when the
    wire codec opts delivered rows into low-precision compute) routes the
    non-fused call through the AQT-style int8 grouped GEMM — per-segment
    int8 activations x per-expert int8 ``w_in``/``w_gate`` with i32
    accumulation, full-precision backward (straight-through) — regardless
    of the Pallas backend decision.

    Backend routing: with the Pallas kernels active for ``use_pallas``
    (``moe_gemm.ops.use_ragged``) every non-fused call goes through the
    occupancy-aware ragged entry, so FLOPs scale with delivered tokens;
    otherwise equal fully-occupied per-expert spans reshape onto the dense
    einsum / ``cfg.use_kernel`` path exactly as before, and any genuinely
    ragged static layout falls back to the ragged jnp reference.
    """
    from repro.kernels.moe_gemm import ops as moe_gemm_ops
    offs = tuple(int(o) for o in seg_offsets)
    d = x_flat.shape[-1]
    if slot_to_token is not None:
        from repro.kernels.moe_fused import ops as moe_fused_ops
        y = moe_fused_ops.local_moe(
            x_flat, slot_to_token, slot_w, offs, seg_experts, rows_valid,
            params["w_in"], params.get("w_gate"), params["w_out"],
            activation=cfg.activation, use_pallas=use_pallas)
        if ep.model_axis is not None:
            y = jax.lax.psum(y, ep.model_axis)
        return y
    if quantized or moe_gemm_ops.use_ragged(use_pallas) or cfg.use_kernel:
        y = moe_gemm_ops.grouped_ffn_segments(
            x_flat, offs, params["w_in"], params.get("w_gate"),
            params["w_out"], activation=cfg.activation,
            row_align=128 if chunk_granular else 1,
            seg_experts=seg_experts, rows_valid=rows_valid,
            use_pallas=use_pallas, quantized=quantized)
    else:
        # jnp path: collapse the (contiguous, expert-major) segments to
        # per-expert spans — zero-filled slack rows make the dense compute
        # equal to the masked one, so occupancy info is simply dropped here
        if seg_experts is None:
            per_expert = offs
        else:
            assert tuple(seg_experts) == tuple(sorted(seg_experts)), \
                "segments must be expert-major for the jnp path"
            E = params["w_in"].shape[0]
            per_expert = [0] * (E + 1)
            for s, e in enumerate(seg_experts):
                per_expert[e + 1] = offs[s + 1]
            for e in range(E):                 # experts with no segments
                per_expert[e + 1] = max(per_expert[e + 1], per_expert[e])
            per_expert = tuple(per_expert)
        E = len(per_expert) - 1
        widths = {per_expert[e + 1] - per_expert[e] for e in range(E)}
        if len(widths) == 1:
            xg = x_flat.reshape(E, per_expert[1] - per_expert[0], d)
            h = _act(cfg, xg, params)
            y = jnp.einsum("ecf,efd->ecd", h, params["w_out"]).reshape(-1, d)
        else:
            y = moe_gemm_ops.grouped_ffn_ragged(
                x_flat, per_expert, tuple(range(E)), None,
                params["w_in"], params.get("w_gate"), params["w_out"],
                activation=cfg.activation, use_pallas=False)
    if ep.model_axis is not None:
        y = jax.lax.psum(y, ep.model_axis)
    return y


def shared_ffn(params, x, cfg: MoEConfig, ep: EPSpec):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_in"])
    else:
        h = jax.nn.gelu(x @ params["shared_in"])
    y = h @ params["shared_out"]
    if ep.model_axis is not None:
        y = jax.lax.psum(y, ep.model_axis)
    return y
