"""DispatchEngine: the registry that composes routing→transport→compute→
combine into named MoE dispatch paths.

Paths are registered by name (the string carried by ``RunConfig.dispatch``
and per-layer ``MoEArch.dispatch_override`` entries) and resolved through
:func:`make_engine`.  Every path returns ``(y, metrics)`` with the uniform
schema :data:`METRIC_KEYS` — missing keys are filled with neutral defaults
by the engine so callers (shard_map out_specs, trainers, benchmarks) never
branch on the path.  ``frac_by_level`` is a fixed-length ``[num_stages]``
vector (one entry per dispatch stage of the EP hierarchy, stage 0 folding
in the self level); ``frac_near`` / ``frac_far`` are derived 2-level
aliases kept during the near/far deprecation window.

Built-in paths:

    a2a            staged hierarchical all-to-all (train / prefill); the
                   software pipeline at num_chunks=1, i.e. fully serialized
    a2a_pipelined  same routing/capacities, chunked 3-stage comm–compute
                   overlap schedule (num_chunks > 1)
    gather         weights-stationary decode regime: all-gather + psum
    einsum         the GShard/DeepSpeed one-hot [T, N, C] formulation —
                   shard-local (no collectives), kept as the §2 baseline
                   and the equivalence oracle for the selection-based paths

Adding a path: implement ``fn(params, x, eng) -> (y, metrics)`` where
``eng`` is the resolved :class:`DispatchEngine` (cfg/ep/plan/gate_cfg and
schedule knobs), then decorate with ``@register("name")``.  Compose the
stage modules rather than re-implementing them — routing is what makes
cross-path outputs comparable.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.core import gating
from repro.core.capacity import DispatchPlan
from repro.core.dispatch import routing, schedule, transport
from repro.core.dispatch.base import (EPSpec, MoEConfig, expert_ffn,
                                      expert_ffn_flat, shared_ffn)
from repro.core.dispatch.routing import _prod
from repro.kernels.moe_fused import ops as moe_fused_ops
from repro.kernels.moe_gemm import ops as moe_gemm_ops
from repro.kernels.moe_permute import ops as permute_ops

#: Uniform metrics schema every path resolves to.  ``frac_by_level`` is a
#: ``[num_stages]`` vector; ``frac_near``/``frac_far`` are deprecated
#: scalar aliases (``frac_by_level[0]`` and ``1 - frac_by_level[0]``).
METRIC_KEYS = ("aux_loss", "frac_by_level", "frac_near", "frac_far",
               "dropped")


@dataclasses.dataclass(frozen=True)
class DispatchPath:
    """A registered dispatch implementation."""
    name: str
    fn: Callable
    needs_plan: bool = False


_REGISTRY: dict = {}


def register(name: str, *, needs_plan: bool = False):
    """Decorator registering ``fn(params, x, eng) -> (y, metrics)``."""
    def deco(fn):
        _REGISTRY[name] = DispatchPath(name=name, fn=fn, needs_plan=needs_plan)
        return fn
    return deco


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_path(name: str) -> DispatchPath:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown dispatch {name!r}; "
                         f"registered paths: {available()}") from None


@dataclasses.dataclass(frozen=True)
class DispatchEngine:
    """A dispatch path resolved against one MoE layer's static config.

    Callable on ``(params, x)`` INSIDE shard_map over the EP axes, with
    ``x: [T_local, d]``; returns ``(y, metrics)`` where metrics carries
    exactly :data:`METRIC_KEYS`.
    """

    path: DispatchPath
    cfg: MoEConfig
    ep: EPSpec
    gate_cfg: gating.GateConfig
    plan: DispatchPlan | None = None
    num_chunks: int = 1               # a2a_pipelined schedule depth
    capacity: int | None = None    # einsum buffer capacity (None = cf rule)
    tokens_replicated: bool = False   # gather: tokens already on every rank
    # Token-permutation implementation for the dispatch/combine hot path:
    # None = auto (Pallas kernels on TPU/GPU, the jnp reference elsewhere);
    # True/False force it.  See repro.kernels.moe_permute.ops.
    use_pallas: bool | None = None

    @property
    def name(self) -> str:
        return self.path.name

    @property
    def num_stages(self) -> int:
        """Length of the ``frac_by_level`` metric vector."""
        return self.plan.num_stages if self.plan is not None \
            else self.ep.num_stages

    def __call__(self, params, x):
        y, metrics = self.path.fn(params, x, self)
        S = self.num_stages
        fb = metrics.get("frac_by_level")
        if fb is None:
            # neutral default: everything stays at the innermost stage
            fb = jnp.zeros((S,), jnp.float32).at[0].set(1.0)
        fb = jnp.asarray(fb, jnp.float32)
        out = {"aux_loss": metrics["aux_loss"],
               "frac_by_level": fb,
               # deprecated 2-level aliases derived from the vector
               "frac_near": fb[0],
               "frac_far": 1.0 - fb[0],
               "dropped": jnp.asarray(metrics.get("dropped", 0.0),
                                      jnp.float32)}
        return y, out


def make_engine(name: str, *, cfg: MoEConfig, ep: EPSpec,
                gate_cfg: gating.GateConfig,
                plan: DispatchPlan | None = None, num_chunks: int = 1,
                capacity: int | None = None,
                tokens_replicated: bool = False,
                use_pallas: bool | None = None) -> DispatchEngine:
    """Resolve ``name`` against the registry and bind the static config."""
    path = get_path(name)
    if path.needs_plan and plan is None:
        raise ValueError(f"dispatch {name!r} requires a DispatchPlan")
    return DispatchEngine(path=path, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                          plan=plan, num_chunks=max(1, int(num_chunks)),
                          capacity=capacity,
                          tokens_replicated=tokens_replicated,
                          use_pallas=use_pallas)


def dispatch_moe(name: str, params, x, *, cfg: MoEConfig, ep: EPSpec,
                 gate_cfg: gating.GateConfig, **kwargs):
    """One-shot convenience: resolve + apply in a single call."""
    return make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg, **kwargs)(
        params, x)


# ---------------------------------------------------------------------------
# staged a2a paths (sync == num_chunks 1, pipelined == num_chunks k)
# ---------------------------------------------------------------------------


def _staged_a2a(params, x, eng: DispatchEngine, num_chunks: int):
    """The one staged implementation behind both ``a2a`` and
    ``a2a_pipelined``: shared routing, the shared sort-based buffer builder
    (``routing.build_indices`` + the moe_permute kernels), chunk-sliced
    stage-list transport, and the software-pipeline schedule (serialized
    when ``num_chunks == 1``).

    Dispatch is one fused permute per chunk — tokens gathered straight into
    the (stage, destination, expert)-sorted capacity buffers — and combine
    is the inverse permutation with the gate-weight multiply fused in
    (``eng.use_pallas`` picks kernel vs reference).  Routing, capacities and
    combine weights are identical across chunk counts, so outputs are
    allclose at matched capacities (the per-token accumulation order over
    chunks may differ in the last ulp).

    Occupancy: when the Pallas GEMM is active (``moe_gemm.ops.use_ragged``)
    the runtime per-(destination, expert) valid-row counts that
    ``routing.build_indices`` derives ride along each chunk's payload
    (``A2ATransport.dispatch_counts`` — a tiny exact all_to_all of the
    count vector), and the expert compute goes through the occupancy-aware
    ragged grouped GEMM: row blocks past a segment's delivered tokens do
    zero MXU work, so FLOPs track Eq. (7)'s *realized* skewed load instead
    of the static worst-case capacity.  Numerically this changes nothing —
    the skipped rows are the permute sentinel's zero-filled slack, whose
    FFN output is zero either way.

    Fused local path: when the kernels are active
    (``moe_fused.ops.use_fused``), stages whose delivery chain is the
    identity — every delivery axis has size 1, i.e. the folded-in self
    level of a unit mesh axis — skip the permute → a2a → GEMM → a2a →
    unpermute round trip entirely.  Their selections are flattened by the
    same ``build_indices`` into a local index set and computed in one
    ``moe_fused.local_moe`` megakernel call (through
    ``expert_ffn_flat(slot_to_token=...)``): no sorted [S, d] capacity
    buffer in HBM, no collectives, gather + grouped GEMM + gate-weighted
    combine in a single pass.  Remote stages keep the permute → a2a chain
    unchanged — a (token, expert) pair occupies at most one slot globally,
    so the local and remote index sets partition the slots and their
    combined outputs simply add.  The local contribution is computed once,
    outside the chunk pipeline (it has no comm to overlap).
    """
    cfg, ep, plan, gate_cfg = eng.cfg, eng.ep, eng.plan, eng.gate_cfg
    T, d = x.shape
    tr = transport.A2ATransport(ep=ep, codec=cfg.wire_codec)
    stages = transport.plan_stages(plan, ep)
    # codecs may opt delivered rows into quantized expert compute — only
    # the remote staged GEMMs; the fused local path never hits the wire
    quant = cfg.wire_codec is not None and cfg.wire_codec.quantize_compute

    routed = routing.route(params, x, cfg, ep, plan, gate_cfg,
                           with_bufs=False)
    kept_unpadded = sum(sel.valid.sum() for _, sel in routed.sels)
    num_chunks = max(1, int(num_chunks))
    chunked = num_chunks > 1
    topk_idx = routed.gate_out["topk_idx"]

    # split the active stages: purely local delivery fuses, the rest keep
    # the staged transport.  Per-stage state for the remote group:
    # (transport stage, padded selection, capacity axis, per-chunk
    # capacity, expert-row count per chunk)
    fused_on = moe_fused_ops.use_fused(eng.use_pallas)
    local_work, work = [], []
    for (s, sel), stage in zip(routed.sels, stages):
        if fused_on and stage.num_dests == 1:
            local_work.append((stage, sel))
            continue
        cap_axis = s + 2
        sel = routing.pad_selection(sel, axis=cap_axis, multiple=num_chunks)
        cpc = sel.idx.shape[cap_axis] // num_chunks
        work.append((stage, sel, cap_axis, cpc, stage.num_dests * cpc))

    out_local = None
    if local_work:
        # the fused megakernel path: flatten the local stages' selections
        # with the same shared builder, then one local_moe call — permute,
        # ragged GEMM, and weighted combine in a single kernel, segment
        # occupancy (rows_per_expert) consumed directly (no count exchange:
        # the rows never leave the device)
        E_l = params["w_in"].shape[0]
        li = routing.build_indices(
            tuple((stage.index, sel) for stage, sel in local_work),
            topk_idx, T)
        offs, exps = [0], []
        for _stage, sel in local_work:
            width = sel.idx.shape[-1]
            for e in range(E_l):
                offs.append(offs[-1] + width)
                exps.append(e)
        out_local = expert_ffn_flat(
            params, x, tuple(offs), cfg, ep, seg_experts=tuple(exps),
            rows_valid=li.rows_per_expert, slot_to_token=li.slot_to_token,
            slot_w=li.slot_w, use_pallas=eng.use_pallas)        # [T, d] f32

    # the shared buffer builder: chunk j's capacity slice of every remote
    # stage, flattened into one sort-order index set (sync == chunk 0)
    indices = [routing.build_indices(
        tuple((stage.index,
               routing.slice_selection(sel, cap_axis, j * cpc, cpc))
              for stage, sel, cap_axis, cpc, _ in work),
        topk_idx, T) for j in range(num_chunks)] if work else []

    # occupancy-aware compute: only pay for the count exchange when the
    # ragged Pallas entry will actually consume it
    ragged = moe_gemm_ops.use_ragged(eng.use_pallas)

    def dispatch(j):
        di = indices[j]
        flat = permute_ops.permute(x, di.slot_to_token,
                                   use_pallas=eng.use_pallas)      # [S_j, d]
        parts, cnts = [], None
        for (stage, *_), (_, off, shape) in zip(work, di.stage_spans()):
            buf = jax.lax.slice_in_dim(flat, off, off + _prod(shape), axis=0)
            parts.append(tr.dispatch(buf.reshape(shape + (d,)), stage))
        if ragged:
            cnts = tuple(
                tr.dispatch_counts(
                    jax.lax.slice_in_dim(di.rows_per_expert, off,
                                         off + _prod(shape),
                                         axis=0).reshape(shape), stage)
                for (stage, *_), (_, off, shape) in zip(work,
                                                        di.expert_spans()))
        xin = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        return xin, cnts

    def compute(j, v):
        # contiguous expert spans -> the segment-offset grouped GEMM entry
        xin, cnts = v
        E_l, R, _ = xin.shape
        if cnts is None:
            segs, exps, valid = transport.expert_segments(E_l, R), None, None
        else:
            # one segment per (expert, stage, source): the granularity at
            # which delivered rows are a valid prefix
            segs, exps = transport.stage_segments(
                E_l, tuple((stage.num_dests, cpc)
                           for stage, _, _, cpc, _ in work))
            valid = jnp.concatenate(cnts, axis=1).reshape(-1) \
                if len(cnts) > 1 else cnts[0].reshape(-1)
        y = expert_ffn_flat(params, xin.reshape(E_l * R, d), segs, cfg, ep,
                            seg_experts=exps, rows_valid=valid,
                            chunk_granular=chunked,
                            use_pallas=eng.use_pallas, quantized=quant)
        return y.reshape(E_l, R, d)

    def combine(out, j, y_exp):
        if out is None:
            out = jnp.zeros((T, d), y_exp.dtype)
        di = indices[j]
        flats, off = [], 0
        for stage, _, _, _, rows in work:
            back = tr.combine(y_exp[:, off:off + rows], stage)
            off += rows
            flats.append(back.reshape(-1, d))
        y_flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, 0)
        mixed = permute_ops.unpermute(y_flat, di.inv_idx, di.inv_w,
                                      use_pallas=eng.use_pallas)
        return out + mixed.astype(out.dtype)

    out = schedule.software_pipeline(num_chunks, dispatch, compute, combine,
                                     None) if work else jnp.zeros((T, d),
                                                                  x.dtype)
    if out_local is not None:
        # like shared_ffn: independent of every chunk, added after the
        # pipeline drains
        out = out + out_local.astype(out.dtype)

    if cfg.num_shared_experts:
        # independent of every chunk: another overlap opportunity for the
        # scheduler, issued after the pipeline drains.
        out = out + shared_ffn(params, x, cfg, ep).astype(out.dtype)

    frac = gating.dispatch_fractions(routed.gate_out["topk_idx"],
                                     cfg.num_experts)
    metrics = {
        "aux_loss": routed.aux,
        "frac_by_level": gating.frac_by_level(frac, routed.levels,
                                              plan.num_stages),
        "dropped": 1.0 - jnp.minimum(
            kept_unpadded / (T * gate_cfg.top_k), 1.0),
    }
    return out.astype(x.dtype), metrics


@register("a2a", needs_plan=True)
def _a2a_path(params, x, eng: DispatchEngine):
    """Sync staged all-to-all: the pipeline schedule at num_chunks=1."""
    return _staged_a2a(params, x, eng, 1)


@register("a2a_pipelined", needs_plan=True)
def _a2a_pipelined_path(params, x, eng: DispatchEngine):
    """Chunked comm–compute-overlap schedule over the same routing."""
    return _staged_a2a(params, x, eng, eng.num_chunks)


# ---------------------------------------------------------------------------
# gather path (decode)
# ---------------------------------------------------------------------------


@register("gather")
def _gather_path(params, x, eng: DispatchEngine):
    """Decode-time MoE: weights stationary, tokens gathered.

    x: [T_local, d].  When ``eng.tokens_replicated`` the same tokens exist
    on every EP rank already (long_500k batch=1) and no gather/slice is
    done.  Bandwidth-optimal for single-token steps (no all-to-all).
    """
    cfg, ep, gate_cfg = eng.cfg, eng.ep, eng.gate_cfg
    E_l = max(1, -(-cfg.num_experts // ep.ep_world))
    tr = transport.GatherTransport(ep=ep,
                                   tokens_replicated=eng.tokens_replicated)
    coords = tuple(jax.lax.axis_index(a) for a in ep.axis_names)
    my_rank = jnp.int32(0)
    for c, s in zip(coords, ep.axis_sizes):
        my_rank = my_rank * s + c

    xg = tr.gather(x)
    levels = gating.expert_levels_nd(cfg.num_experts, E_l, ep.axis_sizes,
                                     coords)
    # levels=None for the gate itself: the hir bias is rank-relative and
    # every rank gates the *gathered* tokens here, so biasing would make
    # the implied routing rank-dependent.  The aux loss below does use the
    # levels — gather is a first-class training path, so it reports the
    # real balance/topology loss (decode callers ignore metrics anyway).
    gate_out = gating.gate_forward(params["gate"], xg, gate_cfg, None)
    aux = gating.aux_loss(gate_out, gate_cfg, levels)

    Tg, d = xg.shape
    if moe_fused_ops.use_fused(eng.use_pallas):
        # fused decode grid: the dense [E_l, Tg] slot space is never
        # materialized (nor is the [E_l, Tg, d] broadcast buffer) — slot
        # ``e * Tg + t`` maps token ``t`` through expert ``e``, so the
        # megakernel gathers each expert's rows straight from the gathered
        # tokens and scatter-accumulates with the gate weights fused in.
        # An expert picked by *no* gathered token is pure slack: its whole
        # Tg-row segment is a skipped zero-valid segment, exactly the
        # whole-segment skip the ragged GEMM did here before.
        wts = routing.gather_weights(gate_out, my_rank, E_l)     # [Tg, E_l]
        valid = jnp.where(jnp.any(wts > 0, axis=0), Tg, 0).astype(jnp.int32)
        slot_tok = jnp.tile(jnp.arange(Tg, dtype=jnp.int32), E_l)
        y = expert_ffn_flat(params, xg, transport.expert_segments(E_l, Tg),
                            cfg, ep, seg_experts=tuple(range(E_l)),
                            rows_valid=valid, slot_to_token=slot_tok,
                            slot_w=wts.T.reshape(-1),
                            use_pallas=eng.use_pallas)           # [Tg, d]
    else:
        xin = jnp.broadcast_to(xg, (E_l,) + xg.shape)            # [E_l, Tg, d]
        y = expert_ffn(params, xin, cfg, ep)                     # [E_l, Tg, d]
        # combine through the same weighted inverse-permutation the staged
        # paths use: the dense [E_l, Tg] grid is a degenerate slot buffer
        inv_idx, inv_w = routing.gather_inverse(gate_out, my_rank, E_l, Tg)
        y = permute_ops.unpermute(y.reshape(E_l * Tg, -1), inv_idx, inv_w,
                                  use_pallas=eng.use_pallas)     # [Tg, d]
    y = y.astype(x.dtype)

    y = tr.reduce(y)
    y = tr.slice_local(y, my_rank, x.shape[0])

    if cfg.num_shared_experts:
        y = y + shared_ffn(params, x, cfg, ep).astype(y.dtype)

    frac = gating.dispatch_fractions(gate_out["topk_idx"], cfg.num_experts)
    metrics = {"aux_loss": aux,
               "frac_by_level": gating.frac_by_level(frac, levels,
                                                     eng.num_stages),
               "dropped": 0.0}   # no capacity limit: nothing ever drops
    return y.astype(x.dtype), metrics


# ---------------------------------------------------------------------------
# GShard/DeepSpeed-style einsum dispatch (baseline from the paper's §2)
# ---------------------------------------------------------------------------


@register("einsum")
def _einsum_path(params, x, eng: DispatchEngine):
    """The classic einsum formulation: one-hot dispatch/combine tensors of
    shape [T, N, C] route tokens through a zero-padded [N, C, d] buffer.

    This is the DeepSpeed-MoE / GShard baseline the paper describes as
    introducing "redundant zero computation and extra memory consumption"
    (§2) — kept for comparison and as the equivalence oracle for the
    selection-based paths.  Runs shard-local (no collectives): suitable for
    pjit auto-sharding or single-rank tests only.
    """
    cfg, ep, gate_cfg = eng.cfg, eng.ep, eng.gate_cfg
    T, d = x.shape
    N, K = cfg.num_experts, cfg.top_k
    capacity = eng.capacity
    if capacity is None:
        capacity = max(1, int(T * K * cfg.capacity_factor / N))

    gate_out = gating.gate_forward(params["gate"], x, gate_cfg, None)
    aux = gating.aux_loss(gate_out, gate_cfg, None)
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]

    # position of each (token, slot) within its expert's capacity buffer
    dispatch = jnp.zeros((T, N, capacity), jnp.float32)
    combine = jnp.zeros((T, N, capacity), jnp.float32)
    counts = jnp.zeros((N,), jnp.int32)
    for s in range(K):
        e = topk_idx[:, s]                       # [T]
        onehot = jax.nn.one_hot(e, N, dtype=jnp.int32)        # [T, N]
        pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot   # [T, N]
        pos = jnp.sum(pos_in_e, axis=1) + counts[e]            # [T]
        keep = pos < capacity
        slot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
        mask = (onehot.astype(jnp.float32) * keep[:, None].astype(jnp.float32))
        d_s = mask[:, :, None] * slot[:, None, :]              # [T, N, C]
        dispatch = dispatch + d_s
        combine = combine + d_s * topk_w[:, s][:, None, None]
        counts = counts + jnp.sum(onehot * keep[:, None], axis=0)

    xin = jnp.einsum("tnc,td->ncd", dispatch, x.astype(jnp.float32))
    y_exp = expert_ffn(params, xin.astype(x.dtype), cfg, ep)   # [N, C, d]
    y = jnp.einsum("tnc,ncd->td", combine, y_exp.astype(jnp.float32))
    if cfg.num_shared_experts:
        y = y + shared_ffn(params, x, cfg, ep).astype(y.dtype)
    metrics = {"aux_loss": aux,
               "dropped": 1.0 - dispatch.sum() / (T * K)}
    return y.astype(x.dtype), metrics
