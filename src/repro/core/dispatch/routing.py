"""Routing stage: gate forward + per-topology-level token selection.

Every staged dispatch path (``a2a``, ``a2a_pipelined``) runs this *identical*
routing — same gate, same per-level top-``cap`` selection, same combine
weights — which is exactly what makes their outputs equivalent at matched
capacities.  The execution schedule (transport.py / schedule.py) is the only
thing that differs between them.

Selections are ``Selection(w, idx, valid, buf)`` named tuples:

    w      [..., cap]      combine weight per selected slot (-1 = empty)
    idx    [..., cap]      source-token index of each slot
    valid  [..., cap]      1.0 where the slot holds a real token
    buf    [..., cap, d]   the gathered (and masked) token payload

Stage ``s``'s selection has ``s + 1`` leading destination dims (the
innermost ``s + 1`` EP mesh axes, outermost first), so its capacity axis is
``s + 2`` and its payload feeds the matching transport
:class:`~repro.core.dispatch.transport.Stage` directly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gating
from repro.core.capacity import DispatchPlan
from repro.core.dispatch.base import EPSpec, MoEConfig


class Selection(NamedTuple):
    """Per-(destination, capacity-slot) token selection."""
    w: jnp.ndarray
    idx: jnp.ndarray
    valid: jnp.ndarray
    buf: jnp.ndarray


class Routing(NamedTuple):
    """Output of :func:`route` — shared by all staged paths.

    ``sels[i]`` is ``(stage_index, Selection)`` for each *active* plan
    stage, in stage order.  ``near`` / ``far`` are deprecated 2-level views.
    """
    sels: tuple
    gate_out: dict
    aux: jnp.ndarray
    levels: jnp.ndarray

    @property
    def near(self):
        """Deprecated: the stage-0 selection."""
        return self.sels[0][1] if self.sels and self.sels[0][0] == 0 else None

    @property
    def far(self):
        """Deprecated: the stage-1 selection (None on single-stage plans)."""
        for s, sel in self.sels:
            if s == 1:
                return sel
        return None


def score_matrix(gate_out, num_experts: int):
    """[N, T] combine-weight matrix; -1 marks 'token did not pick expert'."""
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]
    T = topk_idx.shape[0]
    s = jnp.full((T, num_experts), -1.0, jnp.float32)
    s = s.at[jnp.arange(T)[:, None], topk_idx].set(topk_w.astype(jnp.float32))
    return s.T


def select(score_rows, x, cap: int) -> Selection:
    """Top-``cap`` tokens for each leading row of score_rows [..., T]."""
    cap = min(cap, score_rows.shape[-1])
    w, idx = jax.lax.top_k(score_rows, cap)
    valid = (w > 0).astype(x.dtype)
    buf = jnp.take(x, idx, axis=0) * valid[..., None]
    return Selection(w, idx, valid, buf)


def _prod(xs) -> int:
    out = 1
    for v in xs:
        out *= int(v)
    return out


def _rank_offsets(inner_sizes) -> jnp.ndarray:
    """Mixed-radix rank offsets of shape ``inner_sizes`` (outermost-major)."""
    offs = jnp.zeros(tuple(inner_sizes), jnp.int32)
    stride = 1
    for j in range(len(inner_sizes) - 1, -1, -1):
        shape = [1] * len(inner_sizes)
        shape[j] = inner_sizes[j]
        offs = offs + jnp.arange(inner_sizes[j]).reshape(shape) * stride
        stride *= inner_sizes[j]
    return offs


def route(params, x, cfg: MoEConfig, ep: EPSpec, plan: DispatchPlan,
          gate_cfg: gating.GateConfig) -> Routing:
    """Gating + per-level token selection for the staged (a2a) paths.

    Stage ``s`` targets the experts of ranks sharing this rank's outer
    coordinates on all axes above the innermost ``s + 1`` (delivered by the
    matching transport stage at capacity ``plan.caps[s]``).  Destinations
    already reachable at a lower stage are masked to -1 — except at stage 0,
    whose buffer also carries the folded-in self traffic.
    """
    sizes = ep.axis_sizes
    n = len(sizes)
    assert plan.num_stages == n, (
        f"plan has {plan.num_stages} stages but the EP spec spans {n} mesh "
        f"axes {ep.axis_names}; rebuild the plan for this mesh")
    E_l = plan.experts_per_rank
    coords = tuple(jax.lax.axis_index(a) for a in ep.axis_names)
    my_rank = jnp.int32(0)
    for c, s in zip(coords, sizes):
        my_rank = my_rank * s + c

    levels = gating.expert_levels_nd(cfg.num_experts, E_l, sizes, coords)
    gate_out = gating.gate_forward(params["gate"], x, gate_cfg, levels)
    aux = gating.aux_loss(gate_out, gate_cfg, levels)

    score = score_matrix(gate_out, cfg.num_experts)  # [N, T]

    sels = []
    for s in range(plan.num_stages):
        cap = plan.caps[s]
        if cap <= 0:
            continue
        k = n - s - 1                      # outermost free axis position
        inner = sizes[k:]
        block = _prod(inner)
        base = (my_rank // block) * block  # my rank with inner coords zeroed
        ranks = base + _rank_offsets(inner)                 # [*inner]
        eids = ranks[..., None] * E_l + jnp.arange(E_l)     # [*inner, E_l]
        sc = jnp.take(score, eids, axis=0)                  # [*inner, E_l, T]
        if s > 0:
            # destinations sharing my axis-k coordinate are served by a
            # lower stage; stage 0 keeps them (self traffic folds in)
            own = (jnp.arange(sizes[k]) == coords[k]).reshape(
                (sizes[k],) + (1,) * (len(inner) + 1))
            sc = jnp.where(own, -1.0, sc)
        sels.append((s, select(sc, x, cap)))
    return Routing(tuple(sels), gate_out, aux, levels)


def pad_selection(sel: Selection, axis: int, multiple: int) -> Selection:
    """Zero-pad a selection's capacity axis up to a multiple of ``multiple``.

    Padded slots carry ``valid == 0`` and ``idx == 0``: their FFN output is
    exactly zero (no biases anywhere in the expert FFN) and their combine
    weight is zero, so they contribute nothing — this keeps every chunk
    equal-split per level even when the plan capacity was clamped to the
    local token count.
    """
    pad = (-sel.w.shape[axis]) % multiple
    if pad == 0:
        return sel

    def _pad(a):
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)
    return Selection(*(_pad(a) for a in sel))


def gather_weights(gate_out, my_rank, experts_per_rank: int):
    """[Tg, E_l] combine weight of each of this rank's experts per token
    (0 where the token did not select the expert) — the routing stage of the
    weights-stationary ``gather`` path."""
    my_eids = my_rank * experts_per_rank + jnp.arange(experts_per_rank)
    sel = (gate_out["topk_idx"][:, :, None] == my_eids[None, None, :])
    return jnp.sum(jnp.where(sel, gate_out["topk_weight"][:, :, None], 0.0),
                   axis=1)
