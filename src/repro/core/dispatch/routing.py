"""Routing stage: gate forward + per-topology-level token selection.

Every staged dispatch path (``a2a``, ``a2a_pipelined``) runs this *identical*
routing — same gate, same per-level top-``cap`` selection, same combine
weights — which is exactly what makes their outputs equivalent at matched
capacities.  The execution schedule (transport.py / schedule.py) is the only
thing that differs between them.

Selections are ``Selection(w, idx, valid, buf, eid)`` named tuples:

    w      [..., cap]      combine weight per selected slot (-1 = empty)
    idx    [..., cap]      source-token index of each slot
    valid  [..., cap]      1.0 where the slot holds a real token
    buf    [..., cap, d]   the gathered (and masked) token payload, or None
                           when the engine builds buffers through the
                           moe_permute kernels (``route(with_bufs=False)``)
    eid    [..., cap]      global expert id each slot feeds

Stage ``s``'s selection has ``s + 1`` leading destination dims (the
innermost ``s + 1`` EP mesh axes, outermost first), so its capacity axis is
``s + 2`` and its payload feeds the matching transport
:class:`~repro.core.dispatch.transport.Stage` directly.

The hot path does not consume ``buf`` at all any more: :func:`build_indices`
flattens the selections of every active stage into the
(stage, destination, expert, slot) sort order — one ``slot_to_token`` index
vector, per-stage segment shapes, and the inverse ``[T, K]`` pick map — and
the ``repro.kernels.moe_permute`` pair moves the payload in one fused
gather each way.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gating
from repro.core.capacity import DispatchPlan
from repro.core.dispatch.base import EPSpec, MoEConfig


class Selection(NamedTuple):
    """Per-(destination, capacity-slot) token selection."""
    w: jnp.ndarray
    idx: jnp.ndarray
    valid: jnp.ndarray
    buf: jnp.ndarray | None = None
    eid: jnp.ndarray | None = None


class Routing(NamedTuple):
    """Output of :func:`route` — shared by all staged paths.

    ``sels[i]`` is ``(stage_index, Selection)`` for each *active* plan
    stage, in stage order.  ``near`` / ``far`` are deprecated 2-level views.
    """
    sels: tuple
    gate_out: dict
    aux: jnp.ndarray
    levels: jnp.ndarray

    @property
    def near(self):
        """Deprecated: the stage-0 selection."""
        return self.sels[0][1] if self.sels and self.sels[0][0] == 0 else None

    @property
    def far(self):
        """Deprecated: the stage-1 selection (None on single-stage plans)."""
        for s, sel in self.sels:
            if s == 1:
                return sel
        return None


def score_matrix(gate_out, num_experts: int):
    """[N, T] combine-weight matrix; -1 marks 'token did not pick expert'."""
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]
    T = topk_idx.shape[0]
    s = jnp.full((T, num_experts), -1.0, jnp.float32)
    s = s.at[jnp.arange(T)[:, None], topk_idx].set(topk_w.astype(jnp.float32))
    return s.T


def select(score_rows, x, cap: int, eids=None,
           with_buf: bool = True) -> Selection:
    """Top-``cap`` tokens for each leading row of score_rows [..., T].

    ``eids`` (same shape as the leading dims) records the global expert id
    of each row; ``with_buf=False`` skips materializing the per-slot gather
    (the engine builds the payload buffers through the moe_permute kernels
    from the flattened indices instead).
    """
    cap = min(cap, score_rows.shape[-1])
    w, idx = jax.lax.top_k(score_rows, cap)
    valid = (w > 0).astype(x.dtype)
    buf = jnp.take(x, idx, axis=0) * valid[..., None] if with_buf else None
    eid = (jnp.broadcast_to(eids[..., None], idx.shape)
           if eids is not None else None)
    return Selection(w, idx, valid, buf, eid)


def _prod(xs) -> int:
    out = 1
    for v in xs:
        out *= int(v)
    return out


def _rank_offsets(inner_sizes) -> jnp.ndarray:
    """Mixed-radix rank offsets of shape ``inner_sizes`` (outermost-major)."""
    offs = jnp.zeros(tuple(inner_sizes), jnp.int32)
    stride = 1
    for j in range(len(inner_sizes) - 1, -1, -1):
        shape = [1] * len(inner_sizes)
        shape[j] = inner_sizes[j]
        offs = offs + jnp.arange(inner_sizes[j]).reshape(shape) * stride
        stride *= inner_sizes[j]
    return offs


def route(params, x, cfg: MoEConfig, ep: EPSpec, plan: DispatchPlan,
          gate_cfg: gating.GateConfig, with_bufs: bool = True) -> Routing:
    """Gating + per-level token selection for the staged (a2a) paths.

    Stage ``s`` targets the experts of ranks sharing this rank's outer
    coordinates on all axes above the innermost ``s + 1`` (delivered by the
    matching transport stage at capacity ``plan.caps[s]``).  Destinations
    already reachable at a lower stage are masked to -1 — except at stage 0,
    whose buffer also carries the folded-in self traffic.
    """
    sizes = ep.axis_sizes
    n = len(sizes)
    assert plan.num_stages == n, (
        f"plan has {plan.num_stages} stages but the EP spec spans {n} mesh "
        f"axes {ep.axis_names}; rebuild the plan for this mesh")
    E_l = plan.experts_per_rank
    coords = tuple(jax.lax.axis_index(a) for a in ep.axis_names)
    my_rank = jnp.int32(0)
    for c, s in zip(coords, sizes):
        my_rank = my_rank * s + c

    levels = gating.expert_levels_nd(cfg.num_experts, E_l, sizes, coords)
    gate_out = gating.gate_forward(params["gate"], x, gate_cfg, levels)
    aux = gating.aux_loss(gate_out, gate_cfg, levels)

    score = score_matrix(gate_out, cfg.num_experts)  # [N, T]

    sels = []
    for s in range(plan.num_stages):
        cap = plan.caps[s]
        if cap <= 0:
            continue
        k = n - s - 1                      # outermost free axis position
        inner = sizes[k:]
        block = _prod(inner)
        base = (my_rank // block) * block  # my rank with inner coords zeroed
        ranks = base + _rank_offsets(inner)                 # [*inner]
        eids = ranks[..., None] * E_l + jnp.arange(E_l)     # [*inner, E_l]
        sc = jnp.take(score, eids, axis=0)                  # [*inner, E_l, T]
        if s > 0:
            # destinations sharing my axis-k coordinate are served by a
            # lower stage; stage 0 keeps them (self traffic folds in)
            own = (jnp.arange(sizes[k]) == coords[k]).reshape(
                (sizes[k],) + (1,) * (len(inner) + 1))
            sc = jnp.where(own, -1.0, sc)
        sels.append((s, select(sc, x, cap, eids=eids, with_buf=with_bufs)))
    return Routing(tuple(sels), gate_out, aux, levels)


def pad_selection(sel: Selection, axis: int, multiple: int) -> Selection:
    """Zero-pad a selection's capacity axis up to a multiple of ``multiple``.

    Padded slots carry ``valid == 0`` and ``idx == 0``: their FFN output is
    exactly zero (no biases anywhere in the expert FFN) and their combine
    weight is zero, so they contribute nothing — this keeps every chunk
    equal-split per level even when the plan capacity was clamped to the
    local token count.
    """
    pad = (-sel.w.shape[axis]) % multiple
    if pad == 0:
        return sel

    def _pad(a):
        if a is None:
            return None
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)
    return Selection(*(_pad(a) for a in sel))


def slice_selection(sel: Selection, axis: int, start: int,
                    size: int) -> Selection:
    """Static slice of a selection's capacity axis (one pipeline chunk)."""
    def _slice(a):
        if a is None:
            return None
        return jax.lax.slice_in_dim(a, start, start + size, axis=axis)
    return Selection(*(_slice(a) for a in sel))


class DispatchIndices(NamedTuple):
    """Flattened sort-order view of one set of per-stage selections.

    The flat slot order is (stage, destination..., expert, capacity-slot) —
    exactly the layout the staged all-to-all transports and the grouped
    expert GEMM consume, so dispatch is one fused gather
    (``moe_permute.permute``) and combine is its weighted inverse
    (``moe_permute.unpermute``) with the gate multiply fused in.

    ``slot_to_token[s]`` is the source token of slot ``s`` (sentinel ``T``
    for empty slots); ``slot_w`` its combine weight (0 when empty);
    ``inv_idx[t, k]`` / ``inv_w[t, k]`` locate and weight token ``t``'s
    ``k``-th expert pick among the slots (sentinel ``S`` when the pick was
    dropped or lives outside this selection set, e.g. another pipeline
    chunk).  ``shapes`` are the static per-stage ``idx`` shapes, in stage
    order, for carving stage buffers back out of the flat [S, d] payload.

    ``rows_per_expert`` is the *runtime* occupancy view of the same buffer:
    one int32 valid-row count per (stage, destination..., expert) capacity
    segment, flattened in slot order.  Valid slots are a prefix of every
    segment (top-k sorts live weights first; padding appends empties), so
    the count fully describes which rows of a segment hold delivered
    tokens — this is what the occupancy-aware ragged grouped GEMM consumes
    after the transport forwards the counts to the receiving rank
    (``A2ATransport.dispatch_counts``).

    The same (stage, destination..., expert) segment granularity is the
    wire-codec scale block (``core.dispatch.wire``): a scaled codec emits
    one f32 scale per segment's [C, d] slab, shaped exactly like the count
    tensor, and the transport moves the scale sideband over the identical
    collective chain the counts ride.  Because valid slots are a
    zero-filled prefix per segment, capacity slack can never inflate a
    segment's quantization absmax.
    """
    slot_to_token: jnp.ndarray    # [S] int32, sentinel T
    slot_w: jnp.ndarray           # [S] f32, 0 for empty slots
    inv_idx: jnp.ndarray          # [T, K] int32, sentinel S
    inv_w: jnp.ndarray            # [T, K] f32, 0 for dropped picks
    shapes: tuple                 # ((stage_idx, idx_shape), ...)
    rows_per_expert: jnp.ndarray | None = None   # [num segments] int32

    @property
    def num_slots(self) -> int:
        return self.slot_to_token.shape[0]

    def stage_spans(self) -> tuple:
        """Static (stage_idx, start, shape) row spans of the flat buffer."""
        spans, off = [], 0
        for s, shape in self.shapes:
            n = _prod(shape)
            spans.append((s, off, shape))
            off += n
        return tuple(spans)

    def expert_spans(self) -> tuple:
        """Static (stage_idx, start, shape) spans of ``rows_per_expert`` —
        ``shape`` is the per-stage count tensor shape [*dests, E_local]
        (the ``idx`` shape minus its capacity axis)."""
        spans, off = [], 0
        for s, shape in self.shapes:
            spans.append((s, off, shape[:-1]))
            off += _prod(shape[:-1])
        return tuple(spans)


def build_indices(sels, topk_idx, num_tokens: int) -> DispatchIndices:
    """The shared buffer builder: selections -> sort indices + inverse map.

    ``sels`` is ``((stage_idx, Selection), ...)`` — the active stages of a
    :func:`route` result, optionally capacity-sliced into one pipeline
    chunk (:func:`slice_selection`).  Selections must carry ``eid``
    (``route`` always attaches it).  ``topk_idx`` is the gate's [T, K]
    expert choice used to invert the permutation: a (token, expert) pair
    occupies at most one slot globally — each expert is reachable at
    exactly one stage and appears in one top-``cap`` row there — so the
    inverse is a plain scatter with no collisions.
    """
    parts_tok, parts_w, parts_valid, parts_eid = [], [], [], []
    shapes, parts_cnt = [], []
    for s, sel in sels:
        assert sel.eid is not None, "build_indices needs Selection.eid"
        shapes.append((s, tuple(sel.idx.shape)))
        parts_tok.append(sel.idx.reshape(-1))
        parts_w.append(sel.w.reshape(-1))
        parts_valid.append(sel.valid.reshape(-1))
        parts_eid.append(sel.eid.reshape(-1))
        # per-(destination, expert) valid-row count: valid slots are a
        # prefix of the capacity axis (top-k descending, pads appended)
        parts_cnt.append(jnp.sum(sel.valid > 0, axis=-1,
                                 dtype=jnp.int32).reshape(-1))

    def _cat(parts):
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    tok = _cat(parts_tok).astype(jnp.int32)
    valid = _cat(parts_valid) > 0
    w = jnp.where(valid, _cat(parts_w).astype(jnp.float32), 0.0)
    eid = _cat(parts_eid).astype(jnp.int32)
    S = tok.shape[0]
    K = topk_idx.shape[1]

    slot_to_token = jnp.where(valid, tok, jnp.int32(num_tokens))
    # which of its token's K picks each slot serves (valid slots always
    # match: w > 0 means the token picked this slot's expert)
    match = jnp.take(topk_idx, tok, axis=0) == eid[:, None]       # [S, K]
    k_of_slot = jnp.argmax(match, axis=1).astype(jnp.int32)
    t_scatter = jnp.where(valid, tok, jnp.int32(num_tokens))      # OOB drop
    inv_idx = jnp.full((num_tokens, K), S, jnp.int32)
    inv_idx = inv_idx.at[t_scatter, k_of_slot].set(
        jnp.arange(S, dtype=jnp.int32), mode="drop")
    inv_w = jnp.zeros((num_tokens, K), jnp.float32)
    inv_w = inv_w.at[t_scatter, k_of_slot].set(w, mode="drop")
    return DispatchIndices(slot_to_token, w, inv_idx, inv_w, tuple(shapes),
                           _cat(parts_cnt))


def gather_inverse(gate_out, my_rank, experts_per_rank: int,
                   num_tokens: int):
    """Inverse pick map for the weights-stationary ``gather`` path.

    The gather path's dense [E_l, Tg] expert-output grid is a degenerate
    slot buffer — slot ``e * Tg + t`` holds expert ``e``'s output for token
    ``t`` — so its combine resolves through the same
    ``moe_permute.unpermute`` as the staged paths.  Returns
    ``(inv_idx, inv_w)`` of shape [Tg, K] (sentinel ``E_l * Tg`` for picks
    owned by other ranks).
    """
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]
    e_local = topk_idx - my_rank * experts_per_rank
    local = (e_local >= 0) & (e_local < experts_per_rank)
    sentinel = jnp.int32(experts_per_rank * num_tokens)
    t = jnp.arange(num_tokens, dtype=jnp.int32)[:, None]
    inv_idx = jnp.where(local, e_local.astype(jnp.int32) * num_tokens + t,
                        sentinel)
    inv_w = jnp.where(local, topk_w, 0.0).astype(jnp.float32)
    return inv_idx, inv_w


def gather_weights(gate_out, my_rank, experts_per_rank: int):
    """[Tg, E_l] combine weight of each of this rank's experts per token
    (0 where the token did not select the expert) — the routing stage of the
    weights-stationary ``gather`` path."""
    my_eids = my_rank * experts_per_rank + jnp.arange(experts_per_rank)
    sel = (gate_out["topk_idx"][:, :, None] == my_eids[None, None, :])
    return jnp.sum(jnp.where(sel, gate_out["topk_weight"][:, :, None], 0.0),
                   axis=1)
