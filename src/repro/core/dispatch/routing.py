"""Routing stage: gate forward + per-topology-level token selection.

Every staged dispatch path (``a2a``, ``a2a_pipelined``) runs this *identical*
routing — same gate, same per-level top-``cap`` selection, same combine
weights — which is exactly what makes their outputs equivalent at matched
capacities.  The execution schedule (transport.py / schedule.py) is the only
thing that differs between them.

Selections are ``Selection(w, idx, valid, buf)`` named tuples:

    w      [..., cap]      combine weight per selected slot (-1 = empty)
    idx    [..., cap]      source-token index of each slot
    valid  [..., cap]      1.0 where the slot holds a real token
    buf    [..., cap, d]   the gathered (and masked) token payload
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import gating
from repro.core.capacity import CapacityPlan
from repro.core.dispatch.base import EPSpec, MoEConfig


class Selection(NamedTuple):
    """Per-(destination, capacity-slot) token selection."""
    w: jnp.ndarray
    idx: jnp.ndarray
    valid: jnp.ndarray
    buf: jnp.ndarray


class Routing(NamedTuple):
    """Output of :func:`route` — shared by all staged paths."""
    near: Selection                # capacity axis 2: [P1, E_l, C, ...]
    far: Optional[Selection]       # capacity axis 3: [Q, P1, E_l, C, ...]
    gate_out: dict
    aux: jnp.ndarray
    levels: jnp.ndarray


def score_matrix(gate_out, num_experts: int):
    """[N, T] combine-weight matrix; -1 marks 'token did not pick expert'."""
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]
    T = topk_idx.shape[0]
    s = jnp.full((T, num_experts), -1.0, jnp.float32)
    s = s.at[jnp.arange(T)[:, None], topk_idx].set(topk_w.astype(jnp.float32))
    return s.T


def select(score_rows, x, cap: int) -> Selection:
    """Top-``cap`` tokens for each leading row of score_rows [..., T]."""
    cap = min(cap, score_rows.shape[-1])
    w, idx = jax.lax.top_k(score_rows, cap)
    valid = (w > 0).astype(x.dtype)
    buf = jnp.take(x, idx, axis=0) * valid[..., None]
    return Selection(w, idx, valid, buf)


def route(params, x, cfg: MoEConfig, ep: EPSpec, plan: CapacityPlan,
          gate_cfg: gating.GateConfig) -> Routing:
    """Gating + per-level token selection for the staged (a2a) paths.

    ``near`` targets the experts of this rank's own pod (delivered over the
    data axis at capacity ``plan.cap_near``); ``far`` targets other pods
    (two-stage delivery at ``plan.cap_far``; None on single-pod meshes).
    """
    P1 = ep.ep_per_pod
    E_l = plan.experts_per_rank
    n_pods = ep.num_pods
    multipod = ep.pod_axis is not None and n_pods > 1

    my_data = jax.lax.axis_index(ep.data_axis)
    my_pod = jax.lax.axis_index(ep.pod_axis) if multipod else jnp.int32(0)

    levels = gating.expert_levels(cfg.num_experts, E_l, P1,
                                  n_pods, my_pod, my_data)
    gate_out = gating.gate_forward(params["gate"], x, gate_cfg, levels)
    aux = gating.aux_loss(gate_out, gate_cfg, levels)

    score = score_matrix(gate_out, cfg.num_experts)  # [N, T]

    # near: experts of my own pod, delivered over the data axis
    near_rank = my_pod * P1 + jnp.arange(P1)                       # [P1]
    near_eids = near_rank[:, None] * E_l + jnp.arange(E_l)         # [P1, E_l]
    s_near = jnp.take(score, near_eids, axis=0)                    # [P1, E_l, T]
    near = select(s_near, x, plan.cap_near)

    far = None
    if multipod and plan.cap_far > 0:
        all_rank = (jnp.arange(n_pods)[:, None] * P1
                    + jnp.arange(P1)[None, :])                      # [Q, P1]
        far_eids = all_rank[..., None] * E_l + jnp.arange(E_l)      # [Q, P1, E_l]
        s_far = jnp.take(score, far_eids, axis=0)                   # [Q, P1, E_l, T]
        own = (jnp.arange(n_pods) == my_pod)[:, None, None, None]
        s_far = jnp.where(own, -1.0, s_far)  # own pod handled by near stage
        far = select(s_far, x, plan.cap_far)
    return Routing(near, far, gate_out, aux, levels)


def pad_selection(sel: Selection, axis: int, multiple: int) -> Selection:
    """Zero-pad a selection's capacity axis up to a multiple of ``multiple``.

    Padded slots carry ``valid == 0`` and ``idx == 0``: their FFN output is
    exactly zero (no biases anywhere in the expert FFN) and their combine
    weight is zero, so they contribute nothing — this keeps every chunk
    equal-split per level even when the plan capacity was clamped to the
    local token count.
    """
    pad = (-sel.w.shape[axis]) % multiple
    if pad == 0:
        return sel

    def _pad(a):
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)
    return Selection(*(_pad(a) for a in sel))


def gather_weights(gate_out, my_rank, experts_per_rank: int):
    """[Tg, E_l] combine weight of each of this rank's experts per token
    (0 where the token did not select the expert) — the routing stage of the
    weights-stationary ``gather`` path."""
    my_eids = my_rank * experts_per_rank + jnp.arange(experts_per_rank)
    sel = (gate_out["topk_idx"][:, :, None] == my_eids[None, None, :])
    return jnp.sum(jnp.where(sel, gate_out["topk_weight"][:, :, None], 0.0),
                   axis=1)
