"""Schedule stage: execution skeletons over routing + transport + compute.

The only schedule today is the 3-stage software pipeline.  The sync a2a
path is *literally* :func:`software_pipeline` with ``num_chunks == 1`` —
one dispatch, one compute, one combine, fully serialized — so the engine
has a single staged implementation and the schedules differ only in chunk
count.  The dispatch/compute/combine callables the engine hands in are
built by iterating the plan's level-indexed stage list
(``transport.plan_stages``), so the skeleton is agnostic to how many
topology levels the mesh has — 2-level near/far and N-level hierarchies
run the identical pipeline.  Later async features (shadowed experts,
quantized-a2a overlap, decode batching) reuse the skeleton by swapping
the stage callables.
"""

from __future__ import annotations


def software_pipeline(num_chunks: int, dispatch, compute, combine, carry):
    """Unrolled 3-stage software pipeline over ``num_chunks`` chunks.

    At pipeline tick ``t`` this issues, in order: the dispatch of chunk
    ``t`` (first, so its exchange is in flight as early as possible), the
    compute of chunk ``t-1``, and the combine of chunk ``t-2``.  The three
    live chunks are mutually independent, so a backend with async
    collectives can run chunk ``t``'s exchange concurrently with chunk
    ``t-1``'s GEMM and chunk ``t-2``'s reverse exchange; the double-buffer
    working set (one in-flight dispatch + one in-flight compute) has
    non-overlapping lifetimes that XLA's buffer assignment reuses in place.

    ``dispatch(j)`` produces chunk ``j``'s in-flight value, ``compute(j, v)``
    transforms it, and ``combine(carry, j, v)`` folds it into ``carry``.
    With ``num_chunks == 1`` the loop degenerates to the sync schedule:
    dispatch(0); compute(0); combine(0).
    """
    in_dispatch = None            # (j, dispatched chunk j)
    in_compute = None             # (j, computed chunk j)
    for t in range(num_chunks + 2):
        nxt = (t, dispatch(t)) if t < num_chunks else None
        cmp = (in_dispatch[0], compute(*in_dispatch)) \
            if in_dispatch is not None else None
        if in_compute is not None:
            carry = combine(carry, *in_compute)
        in_dispatch, in_compute = nxt, cmp
    return carry
