"""Wire codecs: first-class payload encodings for the dispatch collectives.

A :class:`WireCodec` describes *what travels over the a2a wire* — the
payload dtype, whether a per-segment scale sideband rides the chain, and
whether delivered rows should also *compute* in low precision.  One codec
object is the single source of truth consumed by three layers that must
never drift:

* ``transport.A2ATransport`` — encodes once before the hop chain, moves
  the (payload, scale) pair through the same tiled all_to_all chain, and
  decodes after the final transpose;
* ``core.comm_model`` / ``core.capacity`` byte accounting — so
  ``choose_num_chunks`` and the overlap model are solved against the
  bytes that actually hit the wire;
* ``analysis.hlo_check`` — the expectation builder derives the collective
  inventory (payload dtype + scale sideband) from the same object.

Scale layout contract: scales are computed **per (destination, expert)
block** over each ``[C, d]`` capacity slab, i.e. one f32 scalar per
delivered segment, shaped ``[*sizes, E_l]`` before the chain — exactly
the shape of the ``dispatch_counts`` metadata exchange, so the scale
sideband rides the identical split/concat chain and lands as
``[E_l, num_dests]`` next to the per-segment valid-row counts.  Routing's
zero-filled slack rows cannot inflate the absmax, so occupancy slack
never costs quantization range.

Registering a codec::

    from repro.core.dispatch import wire
    wire.CODECS["my4bit"] = wire.ScaledCodec(
        name="my4bit", wire_dtype="int8", qmax=7.0)

Deprecated alias: the legacy stringly ``wire_dtype=`` / ``a2a_dtype=``
knobs resolve (with a DeprecationWarning) to :func:`cast_codec` — a
scale-free cast that is byte-identical to the old per-hop cast.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Base codec: how a dispatch payload is represented on the wire.

    ``scaled`` — a per-segment f32 scale sideband rides the a2a chain.
    ``quantize_compute`` — delivered rows also run the expert GEMMs in
    the wire integer dtype (AQT-style, i32 accumulate); only meaningful
    for integer codecs.
    """

    name: str
    wire_dtype: str               # jnp dtype name of the wire payload
    scaled: bool = False
    quantize_compute: bool = False

    @property
    def wire_bytes_per_elem(self) -> int:
        return jnp.dtype(self.wire_dtype).itemsize

    def encode(self, x, *, block_ndim: int = 2):
        """[..., *block] -> (payload, scale | None).

        ``block_ndim`` trailing dims form one scale block; the returned
        scale drops those dims (f32).  Cast-only codecs return None."""
        raise NotImplementedError

    def decode(self, payload, scale, out_dtype):
        """Inverse of :meth:`encode`; ``scale`` must already be broadcast
        to the payload's shape by the caller (or None for cast codecs)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class CastCodec(WireCodec):
    """Scale-free cast around the wire — the legacy ``wire_dtype`` path."""

    def encode(self, x, *, block_ndim: int = 2):
        return x.astype(jnp.dtype(self.wire_dtype)), None

    def decode(self, payload, scale, out_dtype):
        return payload.astype(out_dtype)


@dataclasses.dataclass(frozen=True)
class ScaledCodec(WireCodec):
    """Symmetric per-block absmax scaling into a narrow wire dtype.

    ``qmax`` is the largest representable magnitude of the wire dtype
    (127 for int8, 448 for f8e4m3).  Empty / all-zero blocks encode with
    scale ``1`` so the round trip stays exact on zero-filled slack rows.
    """

    scaled: bool = True
    qmax: float = 127.0

    def encode(self, x, *, block_ndim: int = 2):
        axes = tuple(range(x.ndim - block_ndim, x.ndim))
        xf = x.astype(jnp.float32)
        absmax = jnp.max(jnp.abs(xf), axis=axes)
        scale = jnp.where(absmax > 0, absmax, self.qmax) / self.qmax
        q = xf / scale.reshape(scale.shape + (1,) * block_ndim)
        wd = jnp.dtype(self.wire_dtype)
        if jnp.issubdtype(wd, jnp.integer):
            q = jnp.clip(jnp.round(q), -self.qmax, self.qmax)
        return q.astype(wd), scale

    def decode(self, payload, scale, out_dtype):
        return (payload.astype(jnp.float32) * scale).astype(out_dtype)


CODECS = {
    "bf16": CastCodec(name="bf16", wire_dtype="bfloat16"),
    "int8": ScaledCodec(name="int8", wire_dtype="int8", qmax=127.0,
                        quantize_compute=True),
    "fp8e4m3": ScaledCodec(name="fp8e4m3", wire_dtype="float8_e4m3fn",
                           qmax=448.0),
}


def get_codec(spec) -> WireCodec | None:
    """Resolve a codec spec: None/"" -> None, a codec -> itself, a
    registered name -> the codec; anything else is a config-time error
    naming the registry (the old path died deep inside ``jnp.dtype``)."""
    if spec is None or spec == "":
        return None
    if isinstance(spec, WireCodec):
        return spec
    codec = CODECS.get(spec)
    if codec is None:
        raise ValueError(
            f"unknown wire codec {spec!r}; registered codecs: "
            f"{sorted(CODECS)} (or pass a WireCodec instance)")
    return codec


def cast_codec(dtype_str: str) -> CastCodec:
    """Cast-only codec for a raw dtype name — the deprecated
    ``wire_dtype=``/``a2a_dtype=`` compatibility surface."""
    try:
        jnp.dtype(dtype_str)
    except TypeError:
        raise ValueError(
            f"unknown wire dtype {dtype_str!r}; not a jnp dtype and not a "
            f"registered codec name {sorted(CODECS)}") from None
    return CastCodec(name=f"cast:{dtype_str}", wire_dtype=dtype_str)


def resolve(codec, wire_dtype: str, *, stacklevel: int = 3):
    """One resolved codec from the (codec, deprecated wire_dtype) pair.

    ``codec`` wins when set; a bare ``wire_dtype`` warns and maps to the
    byte-identical cast codec."""
    if codec is not None and codec != "":
        return get_codec(codec)
    if wire_dtype:
        warnings.warn(
            "wire_dtype=/a2a_dtype= is deprecated; pass a wire codec "
            "(e.g. wire_codec=\"bf16\"|\"int8\"|\"fp8e4m3\") instead",
            DeprecationWarning, stacklevel=stacklevel)
        return cast_codec(wire_dtype)
    return None
