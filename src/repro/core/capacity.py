"""Per-level static capacity tables for the hierarchical all-to-all.

TA-MoE's Eq. (7) solution is piecewise-constant per topology level, so the
paper's DeepSpeed-style local capacities ``C_ie ∝ c_hat_ie`` reduce to one
integer capacity per (source, destination-level) pair.  These feed the
equal-split all-to-all stages of core/moe.py with fully static shapes.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import topology as topo_lib


def _round_to(x: float, multiple: int) -> int:
    """Round up to a hardware-friendly multiple (>=1)."""
    return max(multiple, int(math.ceil(x / multiple)) * multiple)


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Static dispatch capacities for one MoE layer on one EP topology.

    ``level_of_stage[s]`` maps all-to-all stage s to a topology level and
    ``cap_per_stage[s]`` is the per-(source device, expert) token capacity
    used for that stage.  Even dispatch (the DeepSpeed-MoE / FastMoE
    baseline) is the same structure with all capacities equal.
    """

    tokens_per_device: int          # S_local * k assignments emitted
    num_experts: int                # N (global routed experts)
    experts_per_rank: int           # E_local on each EP rank
    cap_near: int                   # per-(src, expert) tokens, intra-pod
    cap_far: int                    # per-(src, expert) tokens, inter-pod (0 if single level)
    ratios: tuple                   # per-level multipliers from Eq. (7)
    mode: str                       # "even" | "ta" | "hir"
    num_chunks: int = 1             # pipelined dispatch: chunks per capacity

    @property
    def is_hierarchical(self) -> bool:
        return self.cap_far > 0

    @property
    def chunk_near(self) -> int:
        """Per-chunk near capacity (capacities are chunk-aligned)."""
        return self.cap_near // self.num_chunks

    @property
    def chunk_far(self) -> int:
        return self.cap_far // self.num_chunks


def make_plan(*, tokens_per_device: int, num_experts: int, top_k: int,
              capacity_factor: float, num_pods: int, ep_per_pod: int,
              mode: str = "ta", hir_ratio: float = 4.0,
              round_multiple: int = 8) -> CapacityPlan:
    """Build the per-level capacity plan.

    mode="even": uniform capacity  C = k*S*cf/N         (paper baseline)
    mode="ta"  : per-level C_l = ratio_l * C            (Eq. 7)
    mode="hir" : FasterMoE-style compulsory ratio — intra capacity is
                 ``hir_ratio`` times the inter capacity regardless of beta,
                 renormalized to preserve total sent volume.
    """
    ep_world = num_pods * ep_per_pod
    experts_per_rank = max(1, math.ceil(num_experts / ep_world))
    assignments = tokens_per_device * top_k
    # even per-(src, expert) capacity
    c_even = assignments * capacity_factor / num_experts

    model = topo_lib.tpu_topology(num_pods, ep_per_pod)
    ratios = topo_lib.per_level_ratios(model)  # [L]; level 0=self,1=ICI,2=DCI

    if mode == "even":
        near = far = c_even
    elif mode == "ta":
        # level 1 governs intra-pod targets, level 2 inter-pod.  Level 0
        # (self) is folded into the intra-pod stage: the self chunk never
        # leaves the device, all_to_all keeps it local.  With a single
        # device per pod level 1 has no members (its ratio is 0 by
        # convention) and the near stage carries only self traffic.
        near = c_even * float(ratios[1] if ep_per_pod > 1 else ratios[0])
        far = c_even * float(ratios[2]) if num_pods > 1 else 0.0
    elif mode == "hir":
        if num_pods == 1:
            near, far = c_even, 0.0
        else:
            # hard ratio near:far = hir_ratio:1, preserving the total
            n_near, n_far = ep_per_pod, (num_pods - 1) * ep_per_pod
            total = c_even * (n_near + n_far)
            far = total / (n_near * hir_ratio + n_far)
            near = far * hir_ratio
    else:
        raise ValueError(f"unknown mode {mode!r}")

    cap_near = _round_to(near, round_multiple)
    cap_far = _round_to(far, round_multiple) if (num_pods > 1) else 0
    return CapacityPlan(tokens_per_device=tokens_per_device,
                        num_experts=num_experts,
                        experts_per_rank=experts_per_rank,
                        cap_near=cap_near, cap_far=cap_far,
                        ratios=tuple(float(r) for r in ratios), mode=mode)


def align_to_chunks(plan: CapacityPlan, num_chunks: int) -> CapacityPlan:
    """Round the plan's capacities up to multiples of ``num_chunks``.

    The pipelined dispatch slices each capacity buffer into ``num_chunks``
    equal static chunks per level; rounding *up* preserves losslessness (a
    chunk-aligned plan never drops a token the unaligned plan kept — padding
    slots ride along as zero-weight rows).  ``num_chunks == 1`` returns the
    plan unchanged.
    """
    num_chunks = max(1, int(num_chunks))
    if num_chunks == 1:
        return dataclasses.replace(plan, num_chunks=1)
    cap_near = _round_to(plan.cap_near, num_chunks)
    cap_far = _round_to(plan.cap_far, num_chunks) if plan.cap_far else 0
    return dataclasses.replace(plan, cap_near=cap_near, cap_far=cap_far,
                               num_chunks=num_chunks)


def a2a_bytes(plan: CapacityPlan, d_model: int, bytes_per_el: int,
              num_pods: int, ep_per_pod: int) -> dict:
    """Bytes each device moves per all-to-all stage (send side), for the
    roofline collective term and the benchmark comm model."""
    E = plan.experts_per_rank
    near = plan.cap_near * E * (ep_per_pod - 1) * d_model * bytes_per_el
    far = 0
    if plan.cap_far:
        far = (plan.cap_far * E * (num_pods - 1) * ep_per_pod
               * d_model * bytes_per_el)
    return {"near_bytes": near, "far_bytes": far}
