"""Level-indexed static capacity plans for the hierarchical all-to-all.

TA-MoE's Eq. (7) solution is piecewise-constant per topology level, so the
paper's DeepSpeed-style local capacities ``C_ie ∝ c_hat_ie`` reduce to one
integer capacity per (source, destination-level) pair.  :class:`DispatchPlan`
carries that vector — one capacity per *dispatch stage* of the EP mesh
hierarchy — and feeds the equal-split all-to-all stages of
``core/dispatch`` with fully static shapes.

Dispatch stages vs topology levels: stage ``s`` delivers over the innermost
``s + 1`` mesh axes and serves topology level ``s + 1``; the self level
(level 0) is folded into stage 0 because equal-split ``all_to_all`` keeps
the self chunk on-device anyway.  A 2-axis ``pod x data`` mesh therefore
has stages ``(near, far)`` — the PR-2-era pair — and an N-axis mesh has N
stages indexed by level.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import topology as topo_lib


def _round_to(x: float, multiple: int) -> int:
    """Round up to a hardware-friendly multiple (>=1)."""
    return max(multiple, int(math.ceil(x / multiple)) * multiple)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def default_axis_names(n: int) -> tuple:
    """Canonical EP mesh-axis names, outermost-first: pod / node* / data."""
    if n == 1:
        return ("data",)
    if n == 2:
        return ("pod", "data")
    mids = tuple("node" if n == 3 else f"node{i}" for i in range(n - 2))
    return ("pod",) + mids + ("data",)


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Static dispatch capacities for one MoE layer on one EP topology.

    ``caps[s]`` is the per-(source device, expert) token capacity of
    dispatch stage ``s`` (0 = innermost; ``caps[s] == 0`` marks an inactive
    stage, e.g. the far stage of a single-pod mesh).  ``level_axes[s]`` is
    the mesh-axis chain stage ``s``'s exchange traverses (outermost-first),
    and ``axis_sizes`` are the EP mesh extents those chains are drawn from.
    Even dispatch (the DeepSpeed-MoE / FastMoE baseline) is the same
    structure with all active capacities equal.

    ``cap_near`` / ``cap_far`` (and the chunk variants) are deprecated
    2-level aliases kept for PR-2-era callers; new code indexes ``caps``.
    """

    tokens_per_device: int          # S_local * k assignments emitted
    num_experts: int                # N (global routed experts)
    experts_per_rank: int           # E_local on each EP rank
    caps: tuple                     # per-stage per-(src, expert) capacities
    ratios: tuple                   # full per-level multipliers from Eq. (7)
    mode: str                       # "even" | "ta" | "hir"
    axis_sizes: tuple = ()          # EP mesh extents, outermost-first
    level_axes: tuple = (("data",),)  # mesh-axis chain per stage
    level_sizes: tuple = ()         # |G_l| member counts per topology level
    num_chunks: int = 1             # pipelined dispatch: chunks per capacity

    @property
    def num_stages(self) -> int:
        return len(self.caps)

    @property
    def is_hierarchical(self) -> bool:
        return any(c > 0 for c in self.caps[1:])

    def active_stages(self) -> tuple:
        """Indices of stages with non-zero capacity."""
        return tuple(s for s, c in enumerate(self.caps) if c > 0)

    def chunk_cap(self, stage: int) -> int:
        """Per-chunk capacity of one stage (capacities are chunk-aligned)."""
        return self.caps[stage] // self.num_chunks

    def stage_dests(self, stage: int) -> int:
        """Remote destination ranks served by one stage."""
        n = len(self.axis_sizes)
        k = n - stage - 1
        return (self.axis_sizes[k] - 1) * _prod(self.axis_sizes[k + 1:])

    def stage_block(self, stage: int) -> int:
        """Ranks addressed by one stage's capacity buffer — the remote
        destinations plus the lower-stage block routing masks out (whose
        padded rows the expert FFN still computes)."""
        n = len(self.axis_sizes)
        return _prod(self.axis_sizes[n - stage - 1:])

    # --- deprecated 2-level aliases (PR-2 compat) --------------------------

    @property
    def cap_near(self) -> int:
        """Deprecated: ``caps[0]``."""
        return self.caps[0]

    @property
    def cap_far(self) -> int:
        """Deprecated: ``caps[1]`` (0 when the plan has a single stage)."""
        return self.caps[1] if len(self.caps) > 1 else 0

    @property
    def chunk_near(self) -> int:
        """Deprecated: per-chunk stage-0 capacity."""
        return self.chunk_cap(0)

    @property
    def chunk_far(self) -> int:
        """Deprecated: per-chunk stage-1 capacity."""
        return self.cap_far // self.num_chunks


#: Deprecated name for :class:`DispatchPlan` (the PR-2 near/far-era class).
CapacityPlan = DispatchPlan


def stage_ratio(ratios, level_sizes, stage: int) -> float:
    """Eq. (7) capacity multiplier for one dispatch stage.

    Stage ``s`` serves topology level ``s + 1``.  Degenerate
    single-member-level rule, stated explicitly: when a level has no
    members beyond self (``level_sizes[s + 1] == 0``, e.g. one device per
    pod), its Eq. (7) ratio is 0 by convention — for stage 0, which also
    carries the folded-in self traffic, the *self* ratio
    (``ratios[0]``) applies instead so the self chunk is never starved;
    for any outer stage the stage is simply inactive (capacity 0).
    """
    if level_sizes[stage + 1] > 0:
        return float(ratios[stage + 1])
    return float(ratios[0]) if stage == 0 else 0.0


def scale_comm_model(model, level_beta_scale) -> "topo_lib.CommModel":
    """Scale a CommModel's per-level inverse bandwidths.

    ``level_beta_scale[l] > 1`` marks topology level ``l`` as observed
    slower than the model's constant (a degraded link); ``math.inf``
    marks it unusable — its Eq. (7) ratio becomes exactly 0 (``1/inf``),
    collapsing the level toward local dispatch with the same convention
    :func:`stage_ratio` pins for memberless levels.  Scales shorter than
    the level count pad with 1.0.
    """
    scales = tuple(float(s) for s in level_beta_scale)
    scales = scales + (1.0,) * (len(model.beta) - len(scales))
    beta = tuple(b * s for b, s in zip(model.beta, scales))
    return topo_lib.CommModel(topo=model.topo, alpha=model.alpha, beta=beta)


def make_dispatch_plan(*, tokens_per_device: int, num_experts: int,
                       top_k: int, capacity_factor: float,
                       axis_sizes, axis_names=None, mode: str = "ta",
                       hir_ratio: float = 4.0, round_multiple: int = 8,
                       comm=None, level_beta_scale=None) -> DispatchPlan:
    """Build the level-indexed capacity plan for an N-axis EP hierarchy.

    ``axis_sizes`` are the EP mesh extents outermost-first (e.g.
    ``(pods, nodes, data)``); ``axis_names`` default to the canonical
    pod/node/data naming.  ``comm`` optionally supplies the per-level
    alpha-beta :class:`~repro.core.topology.CommModel` (defaults to the
    hardware-constant ladder of :func:`~repro.core.topology.tree_topology_nd`).
    ``level_beta_scale`` applies :func:`scale_comm_model` — the
    degraded-topology fallback re-solves the plan through it with the
    *observed* per-level slowdowns.

    mode="even": uniform capacity  C = k*S*cf/N         (paper baseline)
    mode="ta"  : per-stage C_s = ratio_{s+1} * C        (Eq. 7)
    mode="hir" : FasterMoE-style compulsory ratio — stage-0 capacity is
                 ``hir_ratio`` times the remote capacity regardless of
                 beta, renormalized to preserve total sent volume.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    n = len(sizes)
    names = tuple(axis_names) if axis_names else default_axis_names(n)
    assert len(names) == n, (names, sizes)
    ep_world = _prod(sizes)
    experts_per_rank = max(1, math.ceil(num_experts / ep_world))
    assignments = tokens_per_device * top_k
    # even per-(src, expert) capacity
    c_even = assignments * capacity_factor / num_experts

    model = comm or topo_lib.tree_topology_nd(sizes)
    if level_beta_scale is not None:
        model = scale_comm_model(model, level_beta_scale)
    ratios = topo_lib.per_level_ratios(model)        # [n + 1]
    level_sizes = tuple(int(x) for x in model.topo.level_sizes(0))

    def active(s: int) -> bool:
        return s == 0 or sizes[n - s - 1] > 1

    if mode == "even":
        want = [c_even if active(s) else 0.0 for s in range(n)]
    elif mode == "ta":
        want = [c_even * stage_ratio(ratios, level_sizes, s) if active(s)
                else 0.0 for s in range(n)]
    elif mode == "hir":
        n_near = level_sizes[0] + level_sizes[1]
        n_far = sum(level_sizes[2:])
        if n_far == 0:
            want = [c_even if active(s) else 0.0 for s in range(n)]
        else:
            # hard ratio near:far = hir_ratio:1, preserving the total
            total = c_even * (n_near + n_far)
            far = total / (n_near * hir_ratio + n_far)
            want = [far * hir_ratio if s == 0 else
                    (far if active(s) else 0.0) for s in range(n)]
    else:
        raise ValueError(f"unknown mode {mode!r}")

    caps = tuple(_round_to(w, round_multiple) if w > 0 else 0 for w in want)
    level_axes = tuple(names[n - s - 1:] for s in range(n))
    return DispatchPlan(tokens_per_device=tokens_per_device,
                        num_experts=num_experts,
                        experts_per_rank=experts_per_rank,
                        caps=caps,
                        ratios=tuple(float(r) for r in ratios), mode=mode,
                        axis_sizes=sizes, level_axes=level_axes,
                        level_sizes=level_sizes)


def make_plan(*, tokens_per_device: int, num_experts: int, top_k: int,
              capacity_factor: float, num_pods: int, ep_per_pod: int,
              mode: str = "ta", hir_ratio: float = 4.0,
              round_multiple: int = 8) -> DispatchPlan:
    """2-level (pod x data) wrapper over :func:`make_dispatch_plan`.

    Kept as the PR-2-era entry point; produces byte-identical capacities to
    the near/far implementation it replaces (same ``tpu_topology`` model,
    same rounding).
    """
    if num_pods > 1:
        sizes, names = (num_pods, ep_per_pod), ("pod", "data")
    else:
        sizes, names = (ep_per_pod,), ("data",)
    return make_dispatch_plan(
        tokens_per_device=tokens_per_device, num_experts=num_experts,
        top_k=top_k, capacity_factor=capacity_factor, axis_sizes=sizes,
        axis_names=names, mode=mode, hir_ratio=hir_ratio,
        round_multiple=round_multiple,
        comm=topo_lib.tpu_topology(num_pods, ep_per_pod))


def align_to_chunks(plan: DispatchPlan, num_chunks: int) -> DispatchPlan:
    """Round the plan's capacities up to multiples of ``num_chunks``.

    The pipelined dispatch slices each capacity buffer into ``num_chunks``
    equal static chunks per stage; rounding *up* preserves losslessness (a
    chunk-aligned plan never drops a token the unaligned plan kept — padding
    slots ride along as zero-weight rows).  ``num_chunks == 1`` returns the
    plan unchanged.
    """
    num_chunks = max(1, int(num_chunks))
    if num_chunks == 1:
        return dataclasses.replace(plan, num_chunks=1)
    caps = tuple(_round_to(c, num_chunks) if c else 0 for c in plan.caps)
    return dataclasses.replace(plan, caps=caps, num_chunks=num_chunks)


def a2a_bytes(plan: DispatchPlan, d_model: int, bytes_per_el: int,
              num_pods: int = 0, ep_per_pod: int = 0, codec=None) -> dict:
    """Bytes each device moves per all-to-all stage (send side), for the
    roofline collective term and the benchmark comm model.

    Returns ``by_level`` (one entry per dispatch stage) plus the deprecated
    ``near_bytes`` / ``far_bytes`` 2-level aliases.  ``num_pods`` /
    ``ep_per_pod`` are accepted for backward compatibility and ignored —
    the plan itself carries the mesh extents.

    ``codec`` (a ``repro.core.dispatch.wire`` codec or registered name)
    overrides the payload element size with the codec's wire dtype and, for
    scaled codecs, adds the f32 per-(destination, expert) scale sideband —
    so chunk choices and overlap estimates are solved against the bytes
    that actually hit the wire.
    """
    if isinstance(codec, str):
        from repro.core.dispatch import wire as wire_lib  # lazy: no cycle
        codec = wire_lib.get_codec(codec)
    payload_b = bytes_per_el if codec is None else codec.wire_bytes_per_elem
    scaled = codec is not None and codec.scaled
    E = plan.experts_per_rank

    def stage_bytes(s: int) -> int:
        if not plan.caps[s]:
            return 0
        b = plan.caps[s] * E * plan.stage_dests(s) * d_model * payload_b
        if scaled:
            b += E * plan.stage_dests(s) * 4   # one f32 scale per segment
        return b

    by_level = tuple(stage_bytes(s) for s in range(plan.num_stages))
    return {"by_level": by_level,
            "near_bytes": by_level[0],
            "far_bytes": sum(by_level[1:])}
