"""Topology abstraction and the TA-MoE dispatch-pattern solver.

Implements the paper's §4.1-4.2:

* tree topologies written as nested lists (paper Fig. 2), e.g. ``[[2, 2], [2]]``
  is a 3-layer asymmetric tree: two 2-device nodes under one switch plus a
  separate 2-device node;
* the alpha-beta communication model and Eq. (5) level smoothing;
* the min-max dispatch optimization of Eq. (6) and its closed-form
  near-optimal solution Eq. (7);
* asymmetric -> symmetric merging (paper §4.2, "[[2,2],[2]] can be merged as
  [[2,2,2]]").

The key structural fact exploited throughout the repo: Eq. (7)'s solution
``c_hat[i, e]`` depends on (i, e) only through the *topology level* separating
device ``i`` from the device hosting expert ``e``.  On a TPU mesh this means
TA-MoE's ragged dispatch becomes a small vector of per-level capacities that
feed equal-split ``lax.all_to_all`` stages (see core/moe.py).
"""

from __future__ import annotations

import dataclasses
import math
from collections.abc import Sequence

import numpy as np

Nested = Sequence  # nested list of ints (leaf node sizes) or deeper lists


# ---------------------------------------------------------------------------
# Tree topology
# ---------------------------------------------------------------------------


def _leaves_per_subtree(spec) -> int:
    if isinstance(spec, int):
        return spec
    return sum(_leaves_per_subtree(s) for s in spec)


def _depth(spec) -> int:
    """Number of switch layers in the spec (an int leaf-group = 1 switch)."""
    if isinstance(spec, int):
        return 1
    return 1 + max(_depth(s) for s in spec)


def _assign_paths(spec, prefix=()):
    """Yield (device_index_order, path) pairs; path = tuple of child indices."""
    if isinstance(spec, int):
        for d in range(spec):
            yield prefix + (d,)
        return
    for ci, child in enumerate(spec):
        yield from _assign_paths(child, prefix + (ci,))


@dataclasses.dataclass(frozen=True)
class TreeTopology:
    """A hierarchical network topology (paper Fig. 2 (a), (c), (d)).

    ``spec`` is the nested-list notation of the paper.  Devices are numbered
    depth-first.  ``level(i, j)`` is the number of switches on the shortest
    path between devices i and j (0 = same device), i.e. the paper's
    ``G^i_t`` grouping index.
    """

    spec: tuple

    def __post_init__(self):
        object.__setattr__(self, "_paths", tuple(_assign_paths(self.spec)))

    @property
    def num_devices(self) -> int:
        return len(self._paths)

    @property
    def num_levels(self) -> int:
        """Levels run 0 (self) .. depth (across the root switch)."""
        return _depth(self.spec) + 1

    def level(self, i: int, j: int) -> int:
        """Switches crossed between devices i and j (0 when i == j)."""
        if i == j:
            return 0
        pi, pj = self._paths[i], self._paths[j]
        # pad to equal length (asymmetric trees give unequal path lengths)
        n = max(len(pi), len(pj))
        pi = (0,) * (n - len(pi)) + tuple(pi)
        pj = (0,) * (n - len(pj)) + tuple(pj)
        # find first differing component from the root
        for k in range(n):
            if pi[k] != pj[k]:
                return n - k
        return 0

    def level_matrix(self) -> np.ndarray:
        P = self.num_devices
        m = np.zeros((P, P), dtype=np.int64)
        for i in range(P):
            for j in range(P):
                m[i, j] = self.level(i, j)
        return m

    def level_sizes(self, i: int = 0) -> np.ndarray:
        """n_l = |G^i_l| for each level l (including level 0 = self)."""
        lm = self.level_matrix()[i]
        return np.bincount(lm, minlength=self.num_levels)

    def is_symmetric(self) -> bool:
        """True iff every device sees identical level-group sizes."""
        lm = self.level_matrix()
        counts = [tuple(np.bincount(lm[i], minlength=self.num_levels))
                  for i in range(self.num_devices)]
        return len(set(counts)) == 1


@dataclasses.dataclass(frozen=True)
class RingTopology:
    """Ring topology (paper Fig. 2(b)): P devices, level(i, j) = hop count.

    "The ring topology also shows a hierarchical characteristic and the
    solution for ring topology has the same pattern as symmetric trees"
    (§4.2) — every device sees the same per-hop group sizes, so Eq. 7
    applies unchanged with per-hop beta values (communication between
    non-adjacent devices hops through intermediates; the slowest link on
    the path dominates, which the per-hop beta encodes).
    """

    num_devices_: int

    @property
    def num_devices(self) -> int:
        return self.num_devices_

    @property
    def num_levels(self) -> int:
        return self.num_devices_ // 2 + 1

    def level(self, i: int, j: int) -> int:
        d = abs(i - j)
        return min(d, self.num_devices_ - d)

    def level_matrix(self) -> np.ndarray:
        P = self.num_devices_
        i = np.arange(P)
        d = np.abs(i[:, None] - i[None, :])
        return np.minimum(d, P - d)

    def level_sizes(self, i: int = 0) -> np.ndarray:
        lm = self.level_matrix()[i]
        return np.bincount(lm, minlength=self.num_levels)

    def is_symmetric(self) -> bool:
        return True


def symmetrize(topo: TreeTopology) -> TreeTopology:
    """Merge an asymmetric tree into the closest symmetric structure.

    Paper §4.2: "[[2,2],[2]] in figure 2(d) can be merged as symmetric
    structure [[2,2,2]]" — separate nodes are merged into the close symmetric
    sub-trees.  We implement this by collapsing the tree to its innermost
    leaf-groups and re-attaching all of them under a single root switch,
    equalizing group sizes to the most common leaf-group arity (splitting
    larger groups / merging stragglers as needed).
    """
    if topo.is_symmetric():
        return topo

    def leaf_groups(spec):
        if isinstance(spec, int):
            return [spec]
        out = []
        for s in spec:
            out.extend(leaf_groups(s))
        return out

    groups = leaf_groups(topo.spec)
    total = sum(groups)
    # most common group arity
    arities = {}
    for g in groups:
        arities[g] = arities.get(g, 0) + 1
    arity = max(sorted(arities), key=lambda a: arities[a])
    if total % arity != 0:  # fall back to gcd so every device is kept
        arity = math.gcd(arity, total)
        arity = max(arity, 1)
    n_groups = total // arity
    return TreeTopology(tuple([arity] * n_groups))


# ---------------------------------------------------------------------------
# alpha-beta model + Eq. (5) smoothing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommModel:
    """alpha-beta cost model over a TreeTopology.

    ``alpha[l]`` (seconds) and ``beta[l]`` (seconds/byte) are per-level
    constants — either supplied directly (hardware datasheet) or produced by
    :func:`smooth_profile` from a profiled per-pair matrix (paper Eq. 5).
    """

    topo: TreeTopology
    alpha: tuple  # per level, seconds
    beta: tuple   # per level, seconds per byte

    def __post_init__(self):
        assert len(self.alpha) == self.topo.num_levels, (
            len(self.alpha), self.topo.num_levels)
        assert len(self.beta) == self.topo.num_levels

    def alpha_beta_matrices(self):
        """Hierarchical matrices of Eq. (5): alpha_hat[i,j], beta_hat[i,j]."""
        lm = self.topo.level_matrix()
        a = np.asarray(self.alpha)[lm]
        b = np.asarray(self.beta)[lm]
        return a, b

    def p2p_time(self, i: int, j: int, nbytes: float) -> float:
        l = self.topo.level(i, j)
        return self.alpha[l] + self.beta[l] * nbytes


def smooth_profile(topo: TreeTopology, alpha_ij: np.ndarray,
                   beta_ij: np.ndarray) -> CommModel:
    """Eq. (5): average the profiled per-pair alpha/beta within each level.

    alpha_l = sum_{i<j, j in G_l^i} alpha_ij / #pairs(l); likewise beta.
    This "precisely characterizes the underlying topology and eliminates the
    noise of profiling" (paper §4.2).
    """
    lm = topo.level_matrix()
    L = topo.num_levels
    alpha, beta = [], []
    for l in range(L):
        if l == 0:
            mask = np.eye(topo.num_devices, dtype=bool)
        else:
            mask = np.triu(lm == l, k=1)
        if mask.sum() == 0:
            alpha.append(0.0)
            beta.append(np.inf)
            continue
        alpha.append(float(alpha_ij[mask].mean()))
        beta.append(float(beta_ij[mask].mean()))
    return CommModel(topo=topo, alpha=tuple(alpha), beta=tuple(beta))


# ---------------------------------------------------------------------------
# Eq. (7): target dispatch pattern
# ---------------------------------------------------------------------------


def target_dispatch(model: CommModel, tokens_sent: float,
                    experts_per_device: int = 1) -> np.ndarray:
    """Near-optimal dispatch chunk sizes c_hat[i, e] of Eq. (7).

    ``tokens_sent`` is k*S — the number of (token, expert) assignments each
    device emits per step.  Returns c_hat with shape [P, N] where
    N = P * experts_per_device; c_hat[i, e] is the number of tokens device i
    should send to expert e.

        c_hat[i,e] = k*S / (E * sum_j 1/beta_hat[i,j]) * 1/beta_hat[i, dev(e)]

    Row sums equal k*S exactly (constraint Eq. 3).  On symmetric topologies
    column sums equal k*S*P/N (constraint Eq. 4) by symmetry.
    """
    topo = model.topo
    if not topo.is_symmetric():
        # paper §4.2: merge asymmetric topologies into the closest symmetric
        # structure, then optimize the lower bound on that structure.
        sym = symmetrize(topo)
        model = CommModel(topo=sym, alpha=model.alpha[: sym.num_levels],
                          beta=model.beta[: sym.num_levels])
        topo = sym
    P = topo.num_devices
    E = experts_per_device
    N = P * E
    _, beta_hat = model.alpha_beta_matrices()
    inv = 1.0 / beta_hat  # [P, P]
    denom = inv.sum(axis=1, keepdims=True)  # sum_j 1/beta_hat[i,j]
    c_dev = tokens_sent * inv / denom  # [P, P] tokens from i to device j
    # split evenly across the E experts of each device
    c = np.repeat(c_dev / E, E, axis=1)  # [P, N]
    return c


def per_level_ratios(model: CommModel) -> np.ndarray:
    """TA-MoE capacity multipliers per level (vs. even dispatch).

    ratio[l] = c_hat(level l) / c_even, with c_even = k*S/N.  Derived from
    Eq. (7): ratio[l] = P * (1/beta_l) / sum_l' n_l'/beta_l'.  These feed the
    per-level static capacities of the hierarchical all-to-all (core/moe.py).
    """
    topo = model.topo
    if not topo.is_symmetric():
        sym = symmetrize(topo)
        model = CommModel(topo=sym, alpha=model.alpha[: sym.num_levels],
                          beta=model.beta[: sym.num_levels])
        topo = sym
    n = topo.level_sizes(0).astype(np.float64)  # [L]
    beta = np.asarray(model.beta, dtype=np.float64)
    inv = np.where(n > 0, 1.0 / beta, 0.0)
    denom = float((n * inv).sum())
    P = topo.num_devices
    return P * inv / denom  # [L]


def penalty_weights(c_hat_row: np.ndarray, norm: str = "sum") -> np.ndarray:
    """p_i = Norm(1 / c_hat_i) of Eq. (8) for one source device.

    ``norm='sum'`` normalizes to mean 1 so the topology loss keeps the
    magnitude of the classic load-balance loss; ``norm='softmax'`` is the
    paper's suggested alternative that enlarges slow-link penalties.
    """
    inv = 1.0 / np.maximum(c_hat_row, 1e-12)
    if norm == "sum":
        return inv / inv.mean()
    if norm == "softmax":
        z = inv / inv.mean()
        e = np.exp(z - z.max())
        p = e / e.sum()
        return p / p.mean()
    raise ValueError(f"unknown norm {norm!r}")


# ---------------------------------------------------------------------------
# TPU production topologies
# ---------------------------------------------------------------------------

# Hardware constants for the TARGET system (TPU v5e-class), used both by the
# dispatch solver and the roofline analysis.  DCI (inter-pod) bandwidth is an
# assumption, stated in EXPERIMENTS.md.
ICI_BW = 50e9          # bytes/s per link, intra-pod
DCI_BW = 6.25e9        # bytes/s, inter-pod data-center interconnect
NODE_BW = 12.5e9       # bytes/s, intra-pod inter-node DCN (3-tier meshes)
LOCAL_BW = 819e9       # HBM-speed "self" transfers
ICI_ALPHA = 1e-6       # s
DCI_ALPHA = 10e-6      # s
NODE_ALPHA = 5e-6      # s, intra-pod DCN hop


def tpu_topology(num_pods: int, devices_per_pod: int) -> CommModel:
    """The production EP topology: pods of devices over ICI, pods over DCI.

    Levels: 0 = self, 1 = intra-pod (ICI), 2 = inter-pod (DCI).  The self
    level is deliberately folded into ICI bandwidth (beta_0 = beta_ICI):
    this is exactly the paper's Eq. (5) smoothing rationale — an extreme
    beta_0 (HBM) would starve remote experts of data ("expert isolation",
    §4.2), and equal-split all_to_all keeps the self chunk on-device anyway
    so its capacity must match the intra-pod peers'.
    """
    if num_pods == 1:
        topo = TreeTopology(devices_per_pod)  # flat: one switch level
        return CommModel(topo=topo,
                         alpha=(0.0, ICI_ALPHA),
                         beta=(1.0 / ICI_BW, 1.0 / ICI_BW))
    topo = TreeTopology(tuple([devices_per_pod] * num_pods))
    return CommModel(topo=topo,
                     alpha=(0.0, ICI_ALPHA, DCI_ALPHA),
                     beta=(1.0 / ICI_BW, 1.0 / ICI_BW, 1.0 / DCI_BW))


def nested_spec(axis_sizes: Sequence):
    """Symmetric TreeTopology spec for an N-axis mesh hierarchy.

    ``axis_sizes`` are outermost-first, e.g. ``(2, 2, 2)`` (pod x node x
    data) gives the paper-notation spec ``((2, 2), (2, 2))`` — the nested
    [[2, 2], [2, 2]] of Fig. 2.  A single axis yields the flat int spec.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    if not sizes:
        raise ValueError("axis_sizes must be non-empty")
    spec = sizes[-1]
    for s in reversed(sizes[:-1]):
        spec = (spec,) * s
    return spec


def axis_sizes_from_spec(spec) -> tuple:
    """Per-axis sizes (outermost-first) of a *symmetric* nested spec.

    Inverse of :func:`nested_spec`: ``[[2, 2], [2, 2]] -> (2, 2, 2)``.
    Asymmetric specs are merged first (paper §4.2) so every spec yields a
    concrete mesh hierarchy.
    """
    def _tup(s):
        return s if isinstance(s, int) else tuple(_tup(c) for c in s)

    topo = TreeTopology(_tup(spec))
    if not topo.is_symmetric():
        topo = symmetrize(topo)
    sizes = []
    node = topo.spec
    while not isinstance(node, int):
        sizes.append(len(node))
        node = node[0]
    sizes.append(node)
    return tuple(sizes)


def tree_topology_nd(axis_sizes: Sequence, *, alpha=None,
                     beta=None) -> CommModel:
    """alpha-beta CommModel for an N-axis hierarchical mesh.

    ``axis_sizes`` are outermost-first (``(pods, nodes, data)``).  For one
    or two axes this is exactly :func:`tpu_topology` (byte-identical plans
    for existing 2-level configs); deeper hierarchies get the default
    bandwidth ladder innermost ICI -> intermediate DCN (``NODE_BW``) ->
    outermost DCI, with the self level folded into the innermost link as
    always (Eq. 5 smoothing rationale; see :func:`tpu_topology`).
    Explicit per-level ``alpha``/``beta`` tuples (length ``n_axes + 1``,
    level 0 = self) override the ladder.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    n = len(sizes)
    if alpha is None and beta is None and n <= 2:
        if n == 1:
            return tpu_topology(1, sizes[0])
        return tpu_topology(sizes[0], sizes[1])
    topo = TreeTopology(nested_spec(sizes))
    if beta is None:
        # level 1 = innermost (ICI, with self folded in), top level = DCI,
        # everything between = intra-pod DCN
        beta = (1.0 / ICI_BW, 1.0 / ICI_BW) \
            + (1.0 / NODE_BW,) * (n - 2) + (1.0 / DCI_BW,)
    if alpha is None:
        alpha = (0.0, ICI_ALPHA) + (NODE_ALPHA,) * (n - 2) + (DCI_ALPHA,)
    return CommModel(topo=topo, alpha=tuple(alpha), beta=tuple(beta))
