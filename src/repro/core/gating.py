"""MoE gates and auxiliary losses: load-balance (Eq. 1), TA-MoE topology loss
(Eq. 8), and the FasterMoE-style compulsory-ratio baseline.

Everything here is per-shard math designed to run inside ``shard_map`` over
the expert-parallel mesh axes; callers psum/pmean the returned metrics.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GateConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_mode: str = "lb"          # "lb" (Eq 1) | "ta" (Eq 8) | "hir" | "none"
    aux_weight: float = 1.0       # paper uses 1.0
    # normalized per-level penalties p (level 0=self, 1=intra-pod, 2=inter-pod),
    # produced by core.topology.penalty_weights on the level-constant c_hat.
    penalty_by_level: tuple = (1.0, 1.0, 1.0)
    # hir: additive logit bias toward intra-pod experts (compulsory preference)
    hir_bias: float = 2.0
    router_dtype: jnp.dtype = jnp.float32


def init_gate_params(key, d_model: int, cfg: GateConfig):
    scale = 1.0 / np.sqrt(d_model)
    return {"w": jax.random.normal(key, (d_model, cfg.num_experts),
                                   dtype=jnp.float32) * scale}


def expert_levels_nd(num_experts: int, experts_per_rank: int,
                     axis_sizes, my_coords) -> jnp.ndarray:
    """Topology level of each global expert relative to this rank (N-level).

    ``axis_sizes`` are the EP mesh extents outermost-first and
    ``my_coords`` this rank's matching coordinates.  Expert ``e`` lives on
    EP rank ``e // experts_per_rank`` with outermost-major rank order.
    Returns int array [N]: 0 = my own experts, ``n_axes - i`` when the
    owning rank first differs from mine at axis ``i`` — i.e. 1 = innermost
    neighbours, ``n_axes`` = across the root switch.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    n = len(sizes)
    e = jnp.arange(num_experts)
    rank = e // experts_per_rank
    lvl = jnp.zeros_like(rank)
    stride = 1
    for i in range(n - 1, -1, -1):
        c = (rank // stride) % sizes[i]
        lvl = jnp.maximum(lvl, jnp.where(c != my_coords[i], n - i, 0))
        stride *= sizes[i]
    return lvl


def expert_levels(num_experts: int, experts_per_rank: int, ep_per_pod: int,
                  num_pods: int, my_pod, my_data) -> jnp.ndarray:
    """Deprecated 2-level wrapper over :func:`expert_levels_nd`.

    Returns int array [N]: 0 = my own experts, 1 = same pod, 2 = other pod.
    """
    if num_pods > 1:
        return expert_levels_nd(num_experts, experts_per_rank,
                                (num_pods, ep_per_pod), (my_pod, my_data))
    return expert_levels_nd(num_experts, experts_per_rank,
                            (ep_per_pod,), (my_data,))


def gate_forward(params, x, cfg: GateConfig, levels: jnp.ndarray | None):
    """Compute router probabilities and top-k selection.

    x: [T, d] local tokens. Returns dict with probs [T, N], topk_idx [T, k],
    topk_weight [T, k] (combine weights), logits.
    """
    logits = (x.astype(cfg.router_dtype)
              @ params["w"].astype(cfg.router_dtype))  # [T, N]
    if cfg.aux_mode == "hir" and levels is not None:
        # FasterMoE-style compulsory preference: bias the gate toward
        # low-level (near) experts.  This is the accuracy-damaging hard
        # mechanism TA-MoE replaces with a loss.
        logits = logits + jnp.where(levels <= 1, cfg.hir_bias, 0.0)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_weight, topk_idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.top_k > 1:
        # GShard-style renormalization of the selected experts' weights
        topk_weight = topk_weight / (topk_weight.sum(-1, keepdims=True) + 1e-9)
    return {"logits": logits, "probs": probs,
            "topk_idx": topk_idx, "topk_weight": topk_weight}


def dispatch_fractions(topk_idx, num_experts: int) -> jnp.ndarray:
    """c_e / (k*S): fraction of assignments routed to each expert. [N]"""
    one_hot = jax.nn.one_hot(topk_idx, num_experts,
                             dtype=jnp.float32)  # [T, k, N]
    counts = one_hot.sum(axis=(0, 1))  # [N]
    total = topk_idx.shape[0] * topk_idx.shape[1]
    return counts / total


def frac_by_level(frac, levels, num_stages: int) -> jnp.ndarray:
    """Aggregate per-expert dispatch fractions into per-stage fractions.

    Stage ``s`` serves topology level ``s + 1``; level 0 (self) is folded
    into stage 0, matching the capacity-plan convention.  Returns a fixed
    ``[num_stages]`` vector summing to 1 — the uniform ``frac_by_level``
    metric of the dispatch engine.
    """
    stage = jnp.clip(levels - 1, 0, num_stages - 1)
    onehot = jax.nn.one_hot(stage, num_stages, dtype=jnp.float32)   # [N, S]
    return jnp.einsum("ns,n->s", onehot, frac.astype(jnp.float32))


def aux_loss(gate_out, cfg: GateConfig,
             levels: jnp.ndarray | None = None) -> jnp.ndarray:
    """Auxiliary loss for this shard's tokens.

    lb (Eq. 1):  l_aux  = N * sum_e m_e * f_e
    ta (Eq. 8):  l_topo = N * sum_e p_e * m_e * f_e   with p from the
                 topology plan (normalized to mean 1, so magnitudes match —
                 the paper's N*P factor against its un-normalized p).
    hir:         same as lb (the compulsory mechanism lives in the gate bias
                 and the capacity plan, mirroring FasterMoE).
    """
    if cfg.aux_mode == "none":
        return jnp.asarray(0.0, jnp.float32)
    probs = gate_out["probs"]
    m = probs.mean(axis=0)                                    # m_e  [N]
    f = dispatch_fractions(gate_out["topk_idx"], cfg.num_experts)  # f_e [N]
    if cfg.aux_mode == "ta":
        assert levels is not None, "ta aux loss needs expert levels"
        pen = jnp.asarray(cfg.penalty_by_level, jnp.float32)[levels]  # [N]
        return cfg.num_experts * jnp.sum(pen * m * f)
    return cfg.num_experts * jnp.sum(m * f)


def ta_penalties(ratios: tuple, norm: str = "sum",
                 level_sizes: tuple | None = None) -> tuple:
    """Per-level penalty weights p_l = Norm(1/c_hat_l) (Eq. 8).

    ``ratios`` are the per-level capacity multipliers from
    core.topology.per_level_ratios (level-constant c_hat, up to a common
    factor).  Normalization is the *population* mean over experts — slow
    levels contain many more experts, so we weight by level sizes when
    provided.
    """
    inv = np.array([1.0 / max(r, 1e-9) for r in ratios], dtype=np.float64)

    def _pop_mean(v):
        if level_sizes is not None:
            w = np.asarray(level_sizes, dtype=np.float64)
            return float((v * w).sum() / max(w.sum(), 1.0))
        return float(v.mean())

    p = inv / max(_pop_mean(inv), 1e-12)
    if norm == "softmax":
        # softmax reweighting of the mean-normalized inverse capacities,
        # rescaled back to population mean 1 so the loss magnitude stays
        # comparable with norm="sum".  (The old expression
        # ``e / e.mean() / e.sum() * e.sum()`` cancelled to ``e / e.mean()``
        # — an *unweighted* mean that broke the mean-1 invariant whenever
        # level_sizes were given.)
        e = np.exp(p - p.max())
        p = e / max(_pop_mean(e), 1e-12)
    elif norm != "sum":
        raise ValueError(f"unknown norm {norm!r}; expected 'sum' or 'softmax'")
    return tuple(float(v) for v in p)
