"""Deprecated compatibility shim over :mod:`repro.core.dispatch`.

The MoE layer used to live here as four hand-rolled dispatch functions;
it is now the composable ``core/dispatch`` package (routing / transport /
schedule / engine).  This module keeps the old import surface working —
``MoEConfig``, ``EPSpec``, parameter init/specs, the expert FFNs, the
``software_pipeline`` skeleton, and ``moe_apply_*`` wrappers that resolve
through the :class:`~repro.core.dispatch.DispatchEngine` registry.

New code should import from ``repro.core.dispatch`` directly (or go through
``models/transformer._moe_block``, which already does); each ``moe_apply_*``
wrapper emits a ``DeprecationWarning`` on use.  Note one schema change the
wrappers inherit: every path now returns the uniform metrics dict
``("aux_loss", "frac_by_level", "frac_near", "frac_far", "dropped")`` —
``frac_by_level`` is the level-indexed vector, ``frac_near``/``frac_far``
its deprecated 2-level aliases.
"""

from __future__ import annotations

import warnings

from repro.core import dispatch as _dispatch
from repro.core.dispatch import (          # noqa: F401  (re-exports)
    EPSpec,
    MoEConfig,
    expert_ffn,
    init_moe_params,
    moe_param_specs,
    shared_ffn,
    software_pipeline,
)
from repro.core.dispatch.base import _act  # noqa: F401  (legacy private name)
from repro.core.dispatch.routing import (  # noqa: F401  (legacy private names)
    pad_selection as _pad_selection,
    route as _route,
    score_matrix as _score_matrix,
    select as _select,
)
from repro.core.dispatch.transport import wire_a2a as _a2a  # noqa: F401


def _deprecated(wrapper: str, path: str):
    warnings.warn(
        f"repro.core.moe.{wrapper} is deprecated; use "
        f"repro.core.dispatch.dispatch_moe({path!r}, ...) or make_engine "
        f"instead", DeprecationWarning, stacklevel=3)


def moe_apply_a2a(params, x, cfg, ep, plan, gate_cfg):
    """x: [T_local, d] inside shard_map. Returns (y, metrics)."""
    _deprecated("moe_apply_a2a", "a2a")
    return _dispatch.dispatch_moe("a2a", params, x, cfg=cfg, ep=ep,
                                  gate_cfg=gate_cfg, plan=plan)


def moe_apply_a2a_pipelined(params, x, cfg, ep, plan, gate_cfg,
                            num_chunks: int = 2):
    """Chunked, software-pipelined variant of :func:`moe_apply_a2a`."""
    _deprecated("moe_apply_a2a_pipelined", "a2a_pipelined")
    return _dispatch.dispatch_moe("a2a_pipelined", params, x, cfg=cfg, ep=ep,
                                  gate_cfg=gate_cfg, plan=plan,
                                  num_chunks=num_chunks)


def moe_apply_gather(params, x, cfg, ep, gate_cfg,
                     tokens_replicated: bool = False):
    """Decode-time MoE: weights stationary, tokens gathered."""
    _deprecated("moe_apply_gather", "gather")
    return _dispatch.dispatch_moe("gather", params, x, cfg=cfg, ep=ep,
                                  gate_cfg=gate_cfg,
                                  tokens_replicated=tokens_replicated)


def moe_apply_einsum(params, x, cfg, ep, gate_cfg,
                     capacity: int | None = None):
    """GShard/DeepSpeed einsum baseline (paper §2)."""
    _deprecated("moe_apply_einsum", "einsum")
    return _dispatch.dispatch_moe("einsum", params, x, cfg=cfg, ep=ep,
                                  gate_cfg=gate_cfg, capacity=capacity)
