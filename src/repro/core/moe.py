"""Expert-parallel MoE layer with TA-MoE hierarchical dispatch.

The layer body runs INSIDE ``shard_map`` over the expert-parallel mesh axes
(``pod``, ``data``) plus the tensor-parallel ``model`` axis.  Dispatch modes:

* ``a2a``   — training / prefill: token selection per (destination rank,
  expert) with per-topology-level static capacities, then equal-split
  ``lax.all_to_all`` stages — intra-pod over ``data`` (capacity ``cap_near``),
  inter-pod over ``pod`` then ``data`` (capacity ``cap_far``).  With
  ``cap_near == cap_far`` this is exactly the DeepSpeed-MoE/FastMoE even
  dispatch baseline; with Eq. (7) capacities it is TA-MoE.
* ``a2a_pipelined`` — same routing and capacities as ``a2a``, but the
  per-level capacity buffers are split into ``num_chunks`` static chunks
  along the capacity axis and the three stages (dispatch exchange, expert
  GEMM, combine exchange) are software-pipelined: while chunk *k* is being
  exchanged, chunk *k-1* runs its expert FFN and chunk *k-2* runs its
  combine.  The chunks carry disjoint capacity slices, so the dependency
  graph lets XLA's async collective scheduler overlap the slow inter-pod
  exchange with expert compute (MoNTA / FasterMoE-style comm–compute
  overlap) while the output stays allclose to ``a2a`` at equal capacities.
* ``gather`` — decode: token counts are tiny, so experts stay put and tokens
  are (all-)gathered; each rank computes its local experts on all tokens,
  masked by the routing, and a ``psum`` over the EP axes combines.  This is
  the weights-stationary regime that is bandwidth-optimal for single-token
  steps (no all-to-all at all).

Everything is static-shaped; see DESIGN.md §2 for why Eq. (7)'s
level-constant solution makes that lossless.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gating
from repro.core.capacity import CapacityPlan


@dataclasses.dataclass(frozen=True)
class EPSpec:
    """How expert parallelism maps onto the mesh."""
    num_pods: int                 # pods over which experts span (1 = no pod span)
    ep_per_pod: int               # "data"-axis size
    pod_axis: Optional[str]       # mesh axis name, None when experts don't span pods
    data_axis: str
    model_axis: Optional[str]     # tensor-parallel axis for d_ff

    @property
    def ep_world(self) -> int:
        return self.num_pods * self.ep_per_pod

    def ep_axes(self):
        return ((self.pod_axis,) if self.pod_axis else ()) + (self.data_axis,)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                     # per-expert intermediate size
    num_experts: int              # routed experts N
    top_k: int
    capacity_factor: float = 1.25
    num_shared_experts: int = 0   # DeepSeek-style always-on experts
    activation: str = "swiglu"    # "swiglu" | "gelu"
    dtype: jnp.dtype = jnp.bfloat16
    use_kernel: bool = False      # Pallas grouped GEMM for expert FFN
    a2a_dtype: str = ""           # e.g. "float8_e4m3fn": quantize dispatch/
                                  # combine payloads on the wire (§Perf.2) —
                                  # halves collective bytes vs bf16


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_moe_params(key, cfg: MoEConfig, ep: EPSpec, gate_cfg: gating.GateConfig):
    """Global (unsharded-view) parameter pytree for one MoE layer.

    Expert tensors carry the full N on axis 0; the caller shards axis 0 over
    the EP axes and the d_ff axis over ``model``.
    """
    keys = jax.random.split(key, 8)
    d, f, n = cfg.d_model, cfg.d_ff, cfg.num_experts
    s1 = (1.0 / np.sqrt(d))
    s2 = (1.0 / np.sqrt(f))
    p = {
        "gate": gating.init_gate_params(keys[0], d, gate_cfg),
        "w_in": jax.random.normal(keys[1], (n, d, f), cfg.dtype) * s1,
        "w_out": jax.random.normal(keys[2], (n, f, d), cfg.dtype) * s2,
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = jax.random.normal(keys[3], (n, d, f), cfg.dtype) * s1
    if cfg.num_shared_experts:
        fs = cfg.d_ff * cfg.num_shared_experts
        p["shared_in"] = jax.random.normal(keys[4], (d, fs), cfg.dtype) * s1
        p["shared_out"] = jax.random.normal(keys[5], (fs, d), cfg.dtype) * s2
        if cfg.activation == "swiglu":
            p["shared_gate"] = jax.random.normal(keys[6], (d, fs), cfg.dtype) * s1
    return p


def moe_param_specs(cfg: MoEConfig, ep: EPSpec):
    """PartitionSpec pytree matching init_moe_params."""
    from jax.sharding import PartitionSpec as P
    expert_axes = (ep.ep_axes() if len(ep.ep_axes()) > 1 else ep.data_axis)
    if isinstance(expert_axes, tuple) and len(expert_axes) == 1:
        expert_axes = expert_axes[0]
    m = ep.model_axis
    specs = {
        "gate": {"w": P(None, None)},
        "w_in": P(expert_axes, None, m),
        "w_out": P(expert_axes, m, None),
    }
    if cfg.activation == "swiglu":
        specs["w_gate"] = P(expert_axes, None, m)
    if cfg.num_shared_experts:
        specs["shared_in"] = P(None, m)
        specs["shared_out"] = P(m, None)
        if cfg.activation == "swiglu":
            specs["shared_gate"] = P(None, m)
    return specs


# ---------------------------------------------------------------------------
# expert FFN (grouped)
# ---------------------------------------------------------------------------


def _act(cfg, xin, params):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xin, params["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xin, params["w_in"]))
    return h


def expert_ffn(params, xin, cfg: MoEConfig, ep: EPSpec, *,
               chunk_granular: bool = False):
    """Grouped expert FFN on [E_local, C, d] -> [E_local, C, d].

    d_ff is sharded over the model axis; the output psum happens here so the
    caller sees full activations.  ``chunk_granular`` routes through the
    row-padding kernel entry sized for pipelined-dispatch chunk slices.
    """
    if cfg.use_kernel:
        from repro.kernels.moe_gemm import ops as moe_gemm_ops
        ffn = (moe_gemm_ops.grouped_ffn_chunk if chunk_granular
               else moe_gemm_ops.grouped_ffn)
        y = ffn(
            xin, params["w_in"],
            params.get("w_gate"), params["w_out"],
            activation=cfg.activation)
    else:
        h = _act(cfg, xin, params)
        y = jnp.einsum("ecf,efd->ecd", h, params["w_out"])
    if ep.model_axis is not None:
        y = jax.lax.psum(y, ep.model_axis)
    return y


def shared_ffn(params, x, cfg: MoEConfig, ep: EPSpec):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_in"])
    else:
        h = jax.nn.gelu(x @ params["shared_in"])
    y = h @ params["shared_out"]
    if ep.model_axis is not None:
        y = jax.lax.psum(y, ep.model_axis)
    return y


# ---------------------------------------------------------------------------
# a2a dispatch path (train / prefill)
# ---------------------------------------------------------------------------


def _score_matrix(gate_out, num_experts: int):
    """[N, T] combine-weight matrix; -1 marks 'token did not pick expert'."""
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]
    T = topk_idx.shape[0]
    s = jnp.full((T, num_experts), -1.0, jnp.float32)
    s = s.at[jnp.arange(T)[:, None], topk_idx].set(topk_w.astype(jnp.float32))
    return s.T


def _a2a(x, axis_name, *, split_axis, concat_axis, wire_dtype: str = ""):
    """all_to_all with optional on-the-wire quantization.

    The cast happens immediately around the collective so only the wire
    payload is low-precision; compute stays in the model dtype.  f8e4m3's
    +-448 range comfortably covers post-norm activations.
    """
    if wire_dtype:
        orig = x.dtype
        x = x.astype(jnp.dtype(wire_dtype))
        x = jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                               concat_axis=concat_axis, tiled=True)
        return x.astype(orig)
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def _select(score_rows, x, cap: int):
    """Top-``cap`` tokens for each leading row of score_rows [..., T].

    Returns (weights [..., cap], token_idx [..., cap], buf [..., cap, d]).
    """
    cap = min(cap, score_rows.shape[-1])
    w, idx = jax.lax.top_k(score_rows, cap)
    valid = (w > 0).astype(x.dtype)
    buf = jnp.take(x, idx, axis=0) * valid[..., None]
    return w, idx, valid, buf


def _route(params, x, cfg: MoEConfig, ep: EPSpec, plan: CapacityPlan,
           gate_cfg: gating.GateConfig):
    """Gating + per-level token selection for the a2a paths.

    Returns ``(near, far, gate_out, aux, levels)`` where ``near``/``far`` are
    ``(w, idx, valid, buf)`` selection tuples with capacity axes 2 / 3
    respectively (``far`` is None on single-pod meshes).  Both the sync and
    the pipelined dispatch run this identical routing, which is what makes
    their outputs equivalent at matched capacities.
    """
    P1 = ep.ep_per_pod
    E_l = plan.experts_per_rank
    n_pods = ep.num_pods
    multipod = ep.pod_axis is not None and n_pods > 1

    my_data = jax.lax.axis_index(ep.data_axis)
    my_pod = jax.lax.axis_index(ep.pod_axis) if multipod else jnp.int32(0)

    levels = gating.expert_levels(cfg.num_experts, E_l, P1,
                                  n_pods, my_pod, my_data)
    gate_out = gating.gate_forward(params["gate"], x, gate_cfg, levels)
    aux = gating.aux_loss(gate_out, gate_cfg, levels)

    score = _score_matrix(gate_out, cfg.num_experts)  # [N, T]

    # near: experts of my own pod, delivered over the data axis
    near_rank = my_pod * P1 + jnp.arange(P1)                       # [P1]
    near_eids = near_rank[:, None] * E_l + jnp.arange(E_l)         # [P1, E_l]
    s_near = jnp.take(score, near_eids, axis=0)                    # [P1, E_l, T]
    near = _select(s_near, x, plan.cap_near)

    far = None
    if multipod and plan.cap_far > 0:
        all_rank = (jnp.arange(n_pods)[:, None] * P1
                    + jnp.arange(P1)[None, :])                      # [Q, P1]
        far_eids = all_rank[..., None] * E_l + jnp.arange(E_l)      # [Q, P1, E_l]
        s_far = jnp.take(score, far_eids, axis=0)                   # [Q, P1, E_l, T]
        own = (jnp.arange(n_pods) == my_pod)[:, None, None, None]
        s_far = jnp.where(own, -1.0, s_far)  # own pod handled by near stage
        far = _select(s_far, x, plan.cap_far)
    return near, far, gate_out, aux, levels


def _dispatch_near(buf, cfg: MoEConfig, ep: EPSpec):
    """[P1, E_l, C, d] local buffer -> [E_l, P1*C, d] expert rows."""
    P1, E_l, C, d = buf.shape
    recv = _a2a(buf, ep.data_axis, split_axis=0, concat_axis=0,
                wire_dtype=cfg.a2a_dtype)
    return recv.transpose(1, 0, 2, 3).reshape(E_l, P1 * C, d)


def _dispatch_far(buf, cfg: MoEConfig, ep: EPSpec):
    """[Q, P1, E_l, C, d] local buffer -> [E_l, Q*P1*C, d] expert rows."""
    Q, P1, E_l, C, d = buf.shape
    # pod exchange: slice [q] -> pod q (carries tokens for (q, *) ranks)
    t = _a2a(buf, ep.pod_axis, split_axis=0, concat_axis=0,
             wire_dtype=cfg.a2a_dtype)
    # deliver within pod: axis 1 is the destination data index
    t = _a2a(t, ep.data_axis, split_axis=1, concat_axis=1,
             wire_dtype=cfg.a2a_dtype)
    # t[q, s]: tokens from rank (q, s) for my experts
    return t.transpose(2, 0, 1, 3, 4).reshape(E_l, Q * P1 * C, d)


def _combine_near(y, P1: int, cfg: MoEConfig, ep: EPSpec):
    """[E_l, P1*C, d] expert outputs -> [P1, E_l, C, d] back at the source."""
    E_l, R, d = y.shape
    y = y.reshape(E_l, P1, R // P1, d).transpose(1, 0, 2, 3)
    return _a2a(y, ep.data_axis, split_axis=0, concat_axis=0,
                wire_dtype=cfg.a2a_dtype)


def _combine_far(y, n_pods: int, P1: int, cfg: MoEConfig, ep: EPSpec):
    """[E_l, Q*P1*C, d] expert outputs -> [Q, P1, E_l, C, d] at the source."""
    E_l, R, d = y.shape
    y = y.reshape(E_l, n_pods, P1, R // (n_pods * P1), d)
    y = y.transpose(1, 2, 0, 3, 4)                       # [Q, P1, E_l, C, d]
    y = _a2a(y, ep.data_axis, split_axis=1, concat_axis=1,
             wire_dtype=cfg.a2a_dtype)
    return _a2a(y, ep.pod_axis, split_axis=0, concat_axis=0,
                wire_dtype=cfg.a2a_dtype)


def _a2a_metrics(gate_out, aux, levels, v_near, T: int, cfg: MoEConfig,
                 gate_cfg: gating.GateConfig):
    """Per-level dispatched token counts (for Fig 6b / Fig 7)."""
    frac = gating.dispatch_fractions(gate_out["topk_idx"], cfg.num_experts)
    lvl1 = jnp.sum(jnp.where(levels <= 1, frac, 0.0))
    return {
        "aux_loss": aux,
        "frac_near": lvl1,
        "frac_far": 1.0 - lvl1,
        "dropped": 1.0 - jnp.minimum(
            v_near.sum() / (T * gate_cfg.top_k), 1.0),
    }


def moe_apply_a2a(params, x, cfg: MoEConfig, ep: EPSpec, plan: CapacityPlan,
                  gate_cfg: gating.GateConfig):
    """x: [T_local, d] inside shard_map. Returns (y, metrics)."""
    T, d = x.shape
    P1 = ep.ep_per_pod
    n_pods = ep.num_pods

    near, far, gate_out, aux, levels = _route(params, x, cfg, ep, plan,
                                              gate_cfg)
    w_near, i_near, v_near, buf_near = near
    Cn = buf_near.shape[2]
    xin = _dispatch_near(buf_near, cfg, ep)                # [E_l, P1*Cn, d]
    if far is not None:
        xin = jnp.concatenate([xin, _dispatch_far(far[3], cfg, ep)], axis=1)

    # ---- expert compute ----
    y_exp = expert_ffn(params, xin, cfg, ep)               # [E_l, R, d]

    # ---- reverse + combine ----
    back_near = _combine_near(y_exp[:, : P1 * Cn], P1, cfg, ep)
    out = jnp.zeros((T, d), y_exp.dtype)
    wgt = (w_near * v_near).astype(y_exp.dtype)
    out = out.at[i_near].add(back_near * wgt[..., None])

    if far is not None:
        w_far, i_far, v_far, _ = far
        back_far = _combine_far(y_exp[:, P1 * Cn:], n_pods, P1, cfg, ep)
        wf = (w_far * v_far).astype(y_exp.dtype)
        out = out.at[i_far].add(back_far * wf[..., None])

    if cfg.num_shared_experts:
        out = out + shared_ffn(params, x, cfg, ep).astype(out.dtype)

    metrics = _a2a_metrics(gate_out, aux, levels, v_near, T, cfg, gate_cfg)
    return out.astype(x.dtype), metrics


# ---------------------------------------------------------------------------
# pipelined a2a dispatch (comm–compute overlap)
# ---------------------------------------------------------------------------


def software_pipeline(num_chunks: int, dispatch, compute, combine, carry):
    """Unrolled 3-stage software pipeline over ``num_chunks`` chunks.

    At pipeline tick ``t`` this issues, in order: the dispatch of chunk
    ``t`` (first, so its exchange is in flight as early as possible), the
    compute of chunk ``t-1``, and the combine of chunk ``t-2``.  The three
    live chunks are mutually independent, so a backend with async
    collectives can run chunk ``t``'s exchange concurrently with chunk
    ``t-1``'s GEMM and chunk ``t-2``'s reverse exchange; the double-buffer
    working set (one in-flight dispatch + one in-flight compute) has
    non-overlapping lifetimes that XLA's buffer assignment reuses in place.

    This scheduling skeleton is deliberately generic — later async features
    (shadowed experts, quantized-a2a overlap, decode batching) can reuse it
    by swapping the stage callables.

    ``dispatch(j)`` produces chunk ``j``'s in-flight value, ``compute(j, v)``
    transforms it, and ``combine(carry, j, v)`` folds it into ``carry``.
    """
    in_dispatch = None            # (j, dispatched chunk j)
    in_compute = None             # (j, computed chunk j)
    for t in range(num_chunks + 2):
        nxt = (t, dispatch(t)) if t < num_chunks else None
        cmp = (in_dispatch[0], compute(*in_dispatch)) \
            if in_dispatch is not None else None
        if in_compute is not None:
            carry = combine(carry, *in_compute)
        in_dispatch, in_compute = nxt, cmp
    return carry


def _pad_selection(sel, axis: int, multiple: int):
    """Zero-pad a ``(w, idx, valid, buf)`` selection's capacity axis up to a
    multiple of ``multiple``.

    Padded slots carry ``valid == 0`` and ``idx == 0``: their FFN output is
    exactly zero (no biases anywhere in the expert FFN) and their combine
    weight is zero, so they contribute nothing — this keeps every chunk
    equal-split per level even when the plan capacity was clamped to the
    local token count.
    """
    w, idx, valid, buf = sel
    pad = (-w.shape[axis]) % multiple
    if pad == 0:
        return sel

    def _pad(a):
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)
    return _pad(w), _pad(idx), _pad(valid), _pad(buf)


def moe_apply_a2a_pipelined(params, x, cfg: MoEConfig, ep: EPSpec,
                            plan: CapacityPlan,
                            gate_cfg: gating.GateConfig,
                            num_chunks: int = 2):
    """Chunked, software-pipelined variant of :func:`moe_apply_a2a`.

    Routing, capacities and combine weights are identical to ``a2a``; only
    the execution schedule differs, so the output is allclose to the sync
    path (the per-token accumulation order over chunks may differ in the
    last ulp).  ``num_chunks == 1`` degenerates to the sync schedule.
    """
    T, d = x.shape
    P1 = ep.ep_per_pod
    n_pods = ep.num_pods

    near, far, gate_out, aux, levels = _route(params, x, cfg, ep, plan,
                                              gate_cfg)
    v_near_unpadded = near[2]
    num_chunks = max(1, int(num_chunks))
    near = _pad_selection(near, axis=2, multiple=num_chunks)
    w_near, i_near, v_near, buf_near = near
    cn = buf_near.shape[2] // num_chunks          # per-chunk near capacity
    cf = 0
    if far is not None:
        far = _pad_selection(far, axis=3, multiple=num_chunks)
        cf = far[3].shape[3] // num_chunks        # per-chunk far capacity

    def dispatch(j):
        xin = _dispatch_near(
            jax.lax.slice_in_dim(buf_near, j * cn, (j + 1) * cn, axis=2),
            cfg, ep)
        if far is not None:
            xin_far = _dispatch_far(
                jax.lax.slice_in_dim(far[3], j * cf, (j + 1) * cf, axis=3),
                cfg, ep)
            xin = jnp.concatenate([xin, xin_far], axis=1)
        return xin

    def compute(j, xin):
        # [E_l, P1*cn + Q*P1*cf, d]
        return expert_ffn(params, xin, cfg, ep, chunk_granular=True)

    def combine(out, j, y_exp):
        if out is None:
            out = jnp.zeros((T, d), y_exp.dtype)
        back = _combine_near(y_exp[:, : P1 * cn], P1, cfg, ep)
        sl = slice(j * cn, (j + 1) * cn)
        wgt = (w_near[:, :, sl] * v_near[:, :, sl]).astype(y_exp.dtype)
        out = out.at[i_near[:, :, sl]].add(back * wgt[..., None])
        if far is not None:
            w_far, i_far, v_far, _ = far
            back_far = _combine_far(y_exp[:, P1 * cn:], n_pods, P1, cfg, ep)
            slf = slice(j * cf, (j + 1) * cf)
            wf = (w_far[..., slf] * v_far[..., slf]).astype(y_exp.dtype)
            out = out.at[i_far[..., slf]].add(back_far * wf[..., None])
        return out

    out = software_pipeline(num_chunks, dispatch, compute, combine, None)

    if cfg.num_shared_experts:
        # independent of every chunk: another overlap opportunity for the
        # scheduler, issued after the pipeline drains.
        out = out + shared_ffn(params, x, cfg, ep).astype(out.dtype)

    metrics = _a2a_metrics(gate_out, aux, levels, v_near_unpadded, T, cfg,
                           gate_cfg)
    return out.astype(x.dtype), metrics


# ---------------------------------------------------------------------------
# gather path (decode)
# ---------------------------------------------------------------------------


def moe_apply_gather(params, x, cfg: MoEConfig, ep: EPSpec,
                     gate_cfg: gating.GateConfig,
                     tokens_replicated: bool = False):
    """Decode-time MoE: weights stationary, tokens gathered.

    x: [T_local, d].  When ``tokens_replicated`` the same tokens exist on
    every EP rank already (long_500k batch=1) and no gather/scatter is done.
    """
    P1, E_l = ep.ep_per_pod, max(1, -(-cfg.num_experts // ep.ep_world))
    multipod = ep.pod_axis is not None and ep.num_pods > 1
    my_data = jax.lax.axis_index(ep.data_axis)
    my_pod = jax.lax.axis_index(ep.pod_axis) if multipod else jnp.int32(0)

    if tokens_replicated:
        xg = x
    else:
        xg = jax.lax.all_gather(x, ep.data_axis, axis=0, tiled=True)
        if multipod:
            xg = jax.lax.all_gather(xg, ep.pod_axis, axis=0, tiled=True)

    gate_out = gating.gate_forward(params["gate"], xg, gate_cfg, None)

    my_rank = my_pod * P1 + my_data
    my_eids = my_rank * E_l + jnp.arange(E_l)                       # [E_l]
    # weight of each of my experts for each token (0 if not selected)
    sel = (gate_out["topk_idx"][:, :, None] == my_eids[None, None, :])
    w_mine = jnp.sum(jnp.where(
        sel, gate_out["topk_weight"][:, :, None], 0.0), axis=1)      # [Tg, E_l]

    xin = jnp.broadcast_to(xg, (E_l,) + xg.shape)                    # [E_l, Tg, d]
    y = expert_ffn(params, xin, cfg, ep)                             # [E_l, Tg, d]
    y = jnp.einsum("etd,te->td", y, w_mine.astype(y.dtype))          # [Tg, d]

    # combine across EP ranks
    y = jax.lax.psum(y, ep.data_axis)
    if multipod:
        y = jax.lax.psum(y, ep.pod_axis)
    if not tokens_replicated:
        T = x.shape[0]
        start = (my_pod * P1 + my_data) * T if multipod else my_data * T
        y = jax.lax.dynamic_slice_in_dim(y, start, T, axis=0)

    if cfg.num_shared_experts:
        y = y + shared_ffn(params, x, cfg, ep).astype(y.dtype)
    return y.astype(x.dtype), {"aux_loss": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# GShard/DeepSpeed-style einsum dispatch (baseline from the paper's §2)
# ---------------------------------------------------------------------------


def moe_apply_einsum(params, x, cfg: MoEConfig, ep: EPSpec,
                     gate_cfg: gating.GateConfig, capacity: int | None = None):
    """The classic einsum formulation: one-hot dispatch/combine tensors of
    shape [T, N, C] route tokens through a zero-padded [N, C, d] buffer.

    This is the DeepSpeed-MoE / GShard baseline the paper describes as
    introducing "redundant zero computation and extra memory consumption"
    (§2) — kept for comparison and as the equivalence oracle for the
    selection-based a2a path.  Runs shard-local (no collectives): suitable
    for pjit auto-sharding or single-rank tests.
    """
    T, d = x.shape
    N, K = cfg.num_experts, cfg.top_k
    if capacity is None:
        capacity = max(1, int(T * K * cfg.capacity_factor / N))

    gate_out = gating.gate_forward(params["gate"], x, gate_cfg, None)
    aux = gating.aux_loss(gate_out, gate_cfg, None)
    topk_idx, topk_w = gate_out["topk_idx"], gate_out["topk_weight"]

    # position of each (token, slot) within its expert's capacity buffer
    dispatch = jnp.zeros((T, N, capacity), jnp.float32)
    combine = jnp.zeros((T, N, capacity), jnp.float32)
    counts = jnp.zeros((N,), jnp.int32)
    for s in range(K):
        e = topk_idx[:, s]                       # [T]
        onehot = jax.nn.one_hot(e, N, dtype=jnp.int32)        # [T, N]
        pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot   # [T, N]
        pos = jnp.sum(pos_in_e, axis=1) + counts[e]            # [T]
        keep = pos < capacity
        slot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
        mask = (onehot.astype(jnp.float32) * keep[:, None].astype(jnp.float32))
        d_s = mask[:, :, None] * slot[:, None, :]              # [T, N, C]
        dispatch = dispatch + d_s
        combine = combine + d_s * topk_w[:, s][:, None, None]
        counts = counts + jnp.sum(onehot * keep[:, None], axis=0)

    xin = jnp.einsum("tnc,td->ncd", dispatch, x.astype(jnp.float32))
    y_exp = expert_ffn(params, xin.astype(x.dtype), cfg, ep)   # [N, C, d]
    y = jnp.einsum("tnc,ncd->td", combine, y_exp.astype(jnp.float32))
    if cfg.num_shared_experts:
        y = y + shared_ffn(params, x, cfg, ep).astype(y.dtype)
    metrics = {"aux_loss": aux,
               "dropped": 1.0 - dispatch.sum() / (T * K)}
    return y.astype(x.dtype), metrics
