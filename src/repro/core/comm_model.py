"""Link-contention alpha-beta simulator for global MoE exchanges.

Reproduces the paper's communication analysis (Table 1, Fig. 6a): given a
TreeTopology, per-level link bandwidths, and a dispatch matrix c[i, j]
(tokens device i sends to device j), estimate the global-exchange time.

Two estimates are produced:

* ``lower_bound`` — the paper's objective, Eq. (2):
      max_{i,j} (alpha_ij + beta_ij * bytes_ij)
* ``contention`` — a per-link serialization model: every delivery's bytes
  are charged to each link on its path; a link's busy time is its total
  bytes divided by its bandwidth; the exchange takes the busiest link's
  time plus the max latency.  This captures the inter-switch bottleneck
  that makes even dispatch slow (paper §3.3).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import CommModel


@dataclasses.dataclass(frozen=True)
class ExchangeTime:
    lower_bound: float
    contention: float
    per_level_bytes: dict  # level -> total bytes crossing that level


def simulate_exchange(model: CommModel, c_bytes: np.ndarray) -> ExchangeTime:
    """c_bytes[i, j]: bytes delivered from device i to device j."""
    topo = model.topo
    P = topo.num_devices
    assert c_bytes.shape == (P, P)
    lm = topo.level_matrix()
    alpha = np.asarray(model.alpha)[lm]
    beta = np.asarray(model.beta)[lm]

    lower = float((alpha + beta * c_bytes).max())

    # contention model: bytes at level l cross one level-l "uplink" on each
    # side; charge a device's send+recv traffic per level against the level's
    # bandwidth (beta_l).  The busiest (device, level) pair dominates.
    busiest = 0.0
    per_level = {}
    L = topo.num_levels
    for l in range(1, L):
        mask = lm == l
        per_level[l] = float(c_bytes[mask].sum())
        # per-device traffic that must cross its level-l uplink
        send = (c_bytes * mask).sum(axis=1)
        recv = (c_bytes * mask).sum(axis=0)
        t = (send + recv) * model.beta[l]
        busiest = max(busiest, float(t.max()))
    contention = busiest + float(np.asarray(model.alpha).max())
    return ExchangeTime(lower_bound=lower, contention=contention,
                        per_level_bytes=per_level)


def dispatch_matrix_from_ratios(model: CommModel, tokens_per_device: float,
                                d_bytes: float,
                                mode: str = "even",
                                c_hat: np.ndarray | None = None) -> np.ndarray:
    """Build c_bytes[i, j] for even dispatch or a supplied c_hat pattern."""
    P = model.topo.num_devices
    if mode == "even":
        c = np.full((P, P), tokens_per_device / P)
    else:
        assert c_hat is not None
        c = c_hat
    return c * d_bytes
