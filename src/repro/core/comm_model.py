"""Link-contention alpha-beta simulator for global MoE exchanges.

Reproduces the paper's communication analysis (Table 1, Fig. 6a): given a
TreeTopology, per-level link bandwidths, and a dispatch matrix c[i, j]
(tokens device i sends to device j), estimate the global-exchange time.

Two estimates are produced:

* ``lower_bound`` — the paper's objective, Eq. (2):
      max_{i,j} (alpha_ij + beta_ij * bytes_ij)
* ``contention`` — a per-link serialization model: every delivery's bytes
  are charged to each link on its path; a link's busy time is its total
  bytes divided by its bandwidth; the exchange takes the busiest link's
  time plus the max latency.  This captures the inter-switch bottleneck
  that makes even dispatch slow (paper §3.3).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import CommModel


@dataclasses.dataclass(frozen=True)
class ExchangeTime:
    lower_bound: float
    contention: float
    per_level_bytes: dict  # level -> total bytes crossing that level


def simulate_exchange(model: CommModel, c_bytes: np.ndarray) -> ExchangeTime:
    """c_bytes[i, j]: bytes delivered from device i to device j."""
    topo = model.topo
    P = topo.num_devices
    assert c_bytes.shape == (P, P)
    lm = topo.level_matrix()
    alpha = np.asarray(model.alpha)[lm]
    beta = np.asarray(model.beta)[lm]

    lower = float((alpha + beta * c_bytes).max())

    # contention model: bytes at level l cross one level-l "uplink" on each
    # side; charge a device's send+recv traffic per level against the level's
    # bandwidth (beta_l).  The busiest (device, level) pair dominates.
    busiest = 0.0
    per_level = {}
    L = topo.num_levels
    for l in range(1, L):
        mask = lm == l
        per_level[l] = float(c_bytes[mask].sum())
        # per-device traffic that must cross its level-l uplink
        send = (c_bytes * mask).sum(axis=1)
        recv = (c_bytes * mask).sum(axis=0)
        t = (send + recv) * model.beta[l]
        busiest = max(busiest, float(t.max()))
    contention = busiest + float(np.asarray(model.alpha).max())
    return ExchangeTime(lower_bound=lower, contention=contention,
                        per_level_bytes=per_level)


def dispatch_matrix_from_ratios(model: CommModel, tokens_per_device: float,
                                d_bytes: float,
                                mode: str = "even",
                                c_hat: np.ndarray | None = None) -> np.ndarray:
    """Build c_bytes[i, j] for even dispatch or a supplied c_hat pattern."""
    P = model.topo.num_devices
    if mode == "even":
        c = np.full((P, P), tokens_per_device / P)
    else:
        assert c_hat is not None
        c = c_hat
    return c * d_bytes


# ---------------------------------------------------------------------------
# pipelined-dispatch overlap model (comm–compute overlap, core/moe.py
# ``a2a_pipelined``)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OverlapEstimate:
    """Predicted step time of one MoE exchange+compute round."""

    num_chunks: int
    t_sync: float            # dispatch + GEMM + combine, fully serialized
    t_pipelined: float       # 3-stage software pipeline over num_chunks
    speedup: float

    @property
    def overlapped_fraction(self) -> float:
        """Share of the sync exchange hidden behind compute (or vice versa)."""
        return max(0.0, 1.0 - self.t_pipelined / max(self.t_sync, 1e-30))


def pipelined_time(t_dispatch: float, t_compute: float, t_combine: float,
                   num_chunks: int, alpha: float = 0.0) -> float:
    """Latency of the 3-stage pipeline with per-chunk stage times.

    Splitting a ``t``-second exchange into ``k`` chunks costs ``t/k + alpha``
    per chunk (the latency term alpha is paid per collective, which is what
    eventually stops chunking from helping); the pipeline fills in one pass
    of all three stages and then drains at the bottleneck-stage rate.
    """
    k = max(1, int(num_chunks))
    d = t_dispatch / k + alpha
    g = t_compute / k
    c = t_combine / k + alpha
    return d + g + c + (k - 1) * max(d, g, c)


def estimate_overlap(*, t_exchange: float, t_compute: float,
                     alpha: float = 0.0,
                     num_chunks: int) -> OverlapEstimate:
    """Sync vs pipelined step time for one chunk count.

    ``t_exchange`` is the one-way (dispatch) exchange time; combine moves
    the same bytes back, so it gets the same cost.
    """
    t_sync = 2.0 * (t_exchange + alpha) + t_compute
    t_pipe = pipelined_time(t_exchange, t_compute, t_exchange,
                            num_chunks, alpha=alpha)
    return OverlapEstimate(num_chunks=int(num_chunks), t_sync=t_sync,
                           t_pipelined=t_pipe,
                           speedup=t_sync / max(t_pipe, 1e-30))


def choose_num_chunks(*, t_exchange: float, t_compute: float,
                      alpha: float = 0.0,
                      candidates=(1, 2, 4, 8)) -> int:
    """Chunk count minimizing the predicted pipelined step time.

    With alpha = 0 more chunks always help (asymptotically hiding the
    smaller of exchange and compute entirely); a realistic per-collective
    alpha makes this a genuine optimum rather than max(candidates).
    """
    best = min(candidates,
               key=lambda k: pipelined_time(t_exchange, t_compute,
                                            t_exchange, k, alpha=alpha))
    return int(best)


def _stage_constants(plan, stage: int):
    """Fallback (alpha, beta) ladder for one dispatch stage: innermost ICI,
    outermost DCI, intermediate intra-pod DCN."""
    from repro.core import topology as topo_lib
    last = plan.num_stages - 1
    if stage == 0:
        return topo_lib.ICI_ALPHA, 1.0 / topo_lib.ICI_BW
    if stage == last:
        return topo_lib.DCI_ALPHA, 1.0 / topo_lib.DCI_BW
    return topo_lib.NODE_ALPHA, 1.0 / topo_lib.NODE_BW


def _stage_link(plan, stage: int, links: dict):
    """Measured LinkEstimate for one stage, if any.

    ``links`` may be keyed by mesh-axis name (:func:`measured_ep_links`) or
    by the legacy ``"near"`` / ``"far"`` pair; the stage's *outermost* hop
    is the bottleneck link it is charged against.
    """
    axis = plan.level_axes[stage][0] if stage < len(plan.level_axes) else None
    li = links.get(axis)
    if li is None:
        li = links.get("near" if stage == 0 else "far")
    return li


def stage_overlap_terms(plan, *, d_model: int, bytes_per_el: int,
                        links: dict | None = None, codec=None) -> list:
    """Per-dispatch-stage ``{stage, bytes, alpha, beta, t_exchange}`` rows.

    Each stage's send bytes are charged against its outermost hop's link
    (measured when available, ladder constants otherwise) — the
    level-indexed generalization of the old near/far split.  ``codec``
    (``repro.core.dispatch.wire``) rescales the payload bytes to the wire
    dtype and adds the scale sideband — see ``capacity.a2a_bytes``.
    """
    from repro.core.capacity import a2a_bytes

    links = links or {}
    b = a2a_bytes(plan, d_model, bytes_per_el, codec=codec)
    rows = []
    for s in range(plan.num_stages):
        if not plan.caps[s]:
            continue
        alpha_c, beta_c = _stage_constants(plan, s)
        li = _stage_link(plan, s, links)
        alpha = li.alpha if li else alpha_c
        beta = li.beta if li else beta_c
        rows.append({"stage": s, "bytes": b["by_level"][s],
                     "alpha": alpha, "beta": beta,
                     "t_exchange": b["by_level"][s] * beta})
    return rows


def moe_overlap_terms(plan, *, d_model: int, d_ff: int, bytes_per_el: int,
                      num_pods: int = 0, ep_per_pod: int = 0,
                      activation: str = "swiglu",
                      peak_flops: float = 197e12,
                      links: dict | None = None, codec=None) -> dict:
    """Alpha-beta inputs for the overlap model from a dispatch plan.

    Exchange time charges each stage's send bytes against its link
    bandwidth (all stages share the per-device NIC, so they are summed
    — the conservative serialization the contention model also assumes);
    compute time is the grouped expert FFN's FLOPs at peak; alpha is the
    slowest active stage's per-collective latency (what chunking pays).

    ``links`` optionally carries measured :class:`LinkEstimate` objects
    keyed by mesh-axis name (:func:`measured_ep_links`) or by the legacy
    ``"near"`` / ``"far"`` pair (:func:`measured_moe_links`); any stage
    without a measurement falls back to the ladder constants.
    ``num_pods`` / ``ep_per_pod`` are accepted for backward compatibility
    and ignored — the plan carries the mesh extents.  ``codec`` feeds the
    wire-codec byte accounting through to ``capacity.a2a_bytes`` so the
    chunk chooser sees quantized wire bytes.
    """
    stages = stage_overlap_terms(plan, d_model=d_model,
                                 bytes_per_el=bytes_per_el, links=links,
                                 codec=codec)
    t_exchange = sum(r["t_exchange"] for r in stages)
    # expert rows this rank computes per layer: every (src rank, expert,
    # capacity slot) lands exactly one row — including the masked
    # lower-stage padding block of each outer stage's buffer
    rows = sum(plan.caps[s] * plan.experts_per_rank * plan.stage_block(s)
               for s in range(plan.num_stages) if plan.caps[s])
    n_mats = 3 if activation == "swiglu" else 2
    flops = 2.0 * rows * d_model * d_ff * n_mats
    alpha = max((r["alpha"] for r in stages), default=0.0)
    return {"t_exchange": t_exchange, "t_compute": flops / peak_flops,
            "alpha": alpha}


# ---------------------------------------------------------------------------
# measured alpha/beta (micro-benchmarked links)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkEstimate:
    """Least-squares fit of ``t = alpha + beta * bytes`` for one mesh axis."""

    alpha: float                  # s (per-collective latency)
    beta: float                   # s/byte (inverse bandwidth)
    nbytes: tuple = ()            # sampled per-device exchange sizes
    times: tuple = ()             # matching measured times (s)

    def predict(self, n: float) -> float:
        return self.alpha + self.beta * n


_LINK_CACHE: dict = {}


def _mesh_key(mesh, axis_name: str, sizes_bytes, iters: int):
    plat = mesh.devices.flat[0].platform if mesh.devices.size else "none"
    return (plat, tuple(sorted(mesh.shape.items())), axis_name,
            tuple(int(s) for s in sizes_bytes), int(iters))


def measure_link(mesh, axis_name: str, *,
                 sizes_bytes=(1 << 13, 1 << 16, 1 << 19),
                 iters: int = 3) -> LinkEstimate:
    """Micro-benchmark ``lax.all_to_all`` over one mesh axis and fit
    ``t = alpha + beta * bytes_per_device``.

    This replaces the ICI/DCI topology *constants* with numbers measured on
    the mesh actually in use (ROADMAP open item: profiled alpha/beta for
    the overlap model).  On forced-host-device meshes the collectives are
    memcpys, so the fit reflects the host's true exchange cost — which is
    exactly what a chunk-count decision on that mesh should use.  Results
    are cached per (platform, mesh shape, axis).
    """
    key = _mesh_key(mesh, axis_name, sizes_bytes, iters)
    if key in _LINK_CACHE:
        return _LINK_CACHE[key]

    import time as _time

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    n = mesh.shape[axis_name]
    sizes, times = [], []
    for nbytes in sizes_bytes:
        w = max(1, int(nbytes) // (4 * n))

        def body(a):
            return jax.lax.all_to_all(a, axis_name, split_axis=0,
                                      concat_axis=0, tiled=True)
        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P(axis_name),
                               out_specs=P(axis_name), check_vma=False))
        xg = jnp.zeros((n * n, w), jnp.float32)
        with mesh:
            jax.block_until_ready(fn(xg))          # compile + warm
            t0 = _time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(fn(xg))
            times.append((_time.perf_counter() - t0) / iters)
        sizes.append(4 * n * w)                    # bytes each device sends
    beta, alpha = np.polyfit(np.asarray(sizes, np.float64),
                             np.asarray(times, np.float64), 1)
    est = LinkEstimate(alpha=float(max(alpha, 0.0)),
                       beta=float(max(beta, 1e-15)),
                       nbytes=tuple(sizes), times=tuple(times))
    _LINK_CACHE[key] = est
    return est


def measured_ep_links(mesh, axis_names) -> dict:
    """Measured per-axis links for one EP hierarchy: ``measure_link`` once
    per mesh axis, keyed by axis name.

    Axes of size 1 (or absent) are skipped — their entry is None and
    :func:`moe_overlap_terms` falls back to the ladder constants.
    """
    links = {}
    for ax in axis_names:
        links[ax] = (measure_link(mesh, ax)
                     if mesh.shape.get(ax, 1) > 1 else None)
    return links


def scale_links(links: dict, multipliers: dict) -> dict:
    """Apply per-axis beta multipliers to measured links.

    ``multipliers[axis] > 1`` models a degraded link (chaos injection or
    an out-of-band observation); entries absent from ``multipliers`` (and
    None links for size-1 axes) pass through unchanged.  Sampled times
    scale with beta so the fit stays self-consistent.
    """
    out = {}
    for ax, li in links.items():
        m = float(multipliers.get(ax, 1.0))
        if li is None or m == 1.0:
            out[ax] = li
        else:
            out[ax] = dataclasses.replace(
                li, beta=li.beta * m,
                times=tuple(t * m for t in li.times))
    return out


def link_slowdowns(links: dict, baseline: dict) -> dict:
    """Observed per-axis beta ratio vs a baseline observation (> 1 means
    the axis got slower).  Axes missing from either side are skipped —
    the degraded-topology fallback only acts on levels it can compare."""
    out = {}
    for ax, li in links.items():
        base = baseline.get(ax)
        if li is None or base is None:
            continue
        out[ax] = li.beta / max(base.beta, 1e-30)
    return out


def measured_moe_links(mesh, *, data_axis: str = "data",
                       pod_axis: str | None = None) -> dict:
    """Deprecated 2-level wrapper over :func:`measured_ep_links`: measured
    near (intra-pod) / far (inter-pod) links for one EP mesh."""
    axes = ((pod_axis,) if pod_axis is not None else ()) + (data_axis,)
    by_axis = measured_ep_links(mesh, axes)
    return {"near": by_axis.get(data_axis),
            "far": by_axis.get(pod_axis) if pod_axis is not None else None}
