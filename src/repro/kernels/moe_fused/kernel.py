"""Fused dispatch→GEMM→combine Pallas megakernel (permute-free local path).

The kernel-on engine previously made three HBM round trips over the same
rows per layer: ``permute`` row-DMAs tokens into a sorted [S, d] capacity
buffer, the ragged grouped GEMM reads it back, and ``unpermute`` scatters
expert outputs into token order.  For *local* traffic (the stage-0 self
level, and every stage of a unit mesh) nothing ever leaves the device, so
the sorted buffer is pure staging — this kernel deletes it.

Grid: ``(row-block, f-block)`` — the same static block decomposition the
ragged GEMM uses (``moe_gemm.ops.plan_blocks``) — with **five**
scalar-prefetch SMEM vectors: the permute's ``slot_to_token`` map and
per-slot combine weights feed the GEMM's ``block_row`` / ``block_eid`` /
``block_nvalid`` vectors directly:

    slot_to_token[s]  source token of capacity slot ``s`` (sentinel = T)
    slot_w[s]         combine weight of slot ``s`` (0 for empty slots)
    block_row[b]      row-block index of block ``b`` in slot space
    block_eid[b]      expert whose weights block ``b`` multiplies
    block_nvalid[b]   runtime valid-row count of block ``b`` (0..bc)

Each grid step's *gather prologue* (first f block of a row block) pulls
its ``bc`` input rows straight from the resident [T + 1, d] token buffer
via ``slot_to_token`` — the sorted [S, d] buffer never exists in HBM.
``pl.when(block_nvalid > 0)`` gates the whole body exactly as in the
ragged GEMM, so slack blocks still issue zero matmuls.  The *combine
epilogue* (last f block) mirrors ``unpermute``: the f32 down-projection
accumulator is scatter-accumulated into the resident [T, d] output with
the gate-weight multiply fused in, walking only the block's ``nvalid``
live slots (valid slots are a segment prefix, so none is the sentinel).

Both residents (token input, combined output) use constant-index-map
whole-array blocks, which bounds the fused path to layouts whose
[T, d] + [S] vectors fit VMEM alongside the weight blocks — exactly the
local-stage shapes the engine routes here (remote stages keep the
permute → a2a → ragged GEMM chain; see ``engine._staged_a2a``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.moe_gemm.kernel import _ffn_body


# BlockSpec index maps, named so the analyzer layout (bottom of file)
# evaluates the exact functions the pallas_call uses.

def _resident_map(b, j, tok, w, row, eid, nv):
    # whole-array block, resident across the entire grid
    return (0, 0)


def _fused_win_map(b, j, tok, w, row, eid, nv):
    return (eid[b], 0, j)


def _fused_wout_map(b, j, tok, w, row, eid, nv):
    return (eid[b], j, 0)


def _fused_kernel(tok_ref, w_ref, row_ref, eid_ref, nvalid_ref,
                  x_ref, win_ref, wgate_ref, wout_ref, o_ref,
                  acc_ref, xblk_ref, *, activation: str, block_c: int):
    b = pl.program_id(0)               # row-block index (scalar-prefetched)
    j = pl.program_id(1)               # f-block index (sequential)
    nf = pl.num_programs(1)
    nv = nvalid_ref[b]                 # runtime valid rows of this block
    base = row_ref[b] * block_c        # first slot of this block

    @pl.when((b == 0) & (j == 0))
    def _zero_out():
        # the combined output accumulates across row blocks; zero it once
        o_ref[...] = jnp.zeros_like(o_ref)

    # the same occupancy predicate as the ragged GEMM: row blocks past a
    # segment's realized count do zero gathers, zero MXU work, zero stores
    @pl.when(nv > 0)
    def _compute():
        @pl.when(j == 0)
        def _gather():
            # dispatch fused in: pull the block's rows straight from the
            # token buffer (sentinel slots read the trailing zero row)
            def body(i, _):
                t = tok_ref[base + i]
                xblk_ref[pl.ds(i, 1), :] = x_ref[pl.ds(t, 1), :]
                return 0
            jax.lax.fori_loop(0, block_c, body, 0)

        part = _ffn_body(xblk_ref[...], win_ref, wgate_ref, wout_ref,
                         activation=activation)
        rows = jax.lax.broadcasted_iota(jnp.int32, part.shape, 0)
        part = jnp.where(rows < nv, part, 0.0)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = part

        @pl.when(j > 0)
        def _acc():
            acc_ref[...] += part

        @pl.when(j == nf - 1)
        def _scatter():
            # combine fused in: scatter-accumulate the finished rows into
            # token order with the gate-weight multiply applied — only the
            # nv live slots, none of which is the sentinel
            def body(i, _):
                t = tok_ref[base + i]
                w = w_ref[base + i]
                o_ref[pl.ds(t, 1), :] += w * acc_ref[pl.ds(i, 1), :]
                return 0
            jax.lax.fori_loop(0, nv, body, 0)


def local_moe_pallas(x_padded, slot_to_token, slot_w, block_row, block_eid,
                     block_nvalid, w_in, w_gate, w_out, *,
                     activation: str = "swiglu", block_c: int,
                     block_f: int = 256, interpret: bool = False):
    """x_padded: [T + 1, d] tokens (last row zeros); slot_to_token: [S]
    int32 in [0, T]; slot_w: [S] float32; block vectors as in
    ``moe_gemm.kernel.grouped_ffn_ragged_pallas``.  Returns the [T, d]
    float32 combined output (cast at the caller)."""
    T = x_padded.shape[0] - 1
    d = x_padded.shape[-1]
    f = w_in.shape[-1]
    bc = block_c
    bf = min(block_f, f)
    nb = block_row.shape[0]
    nf = pl.cdiv(f, bf)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(nb, nf),
        in_specs=[
            # whole token buffer resident across the grid
            pl.BlockSpec((T + 1, d), _resident_map),
            pl.BlockSpec((1, d, bf), _fused_win_map),
            pl.BlockSpec((1, d, bf), _fused_win_map),
            pl.BlockSpec((1, bf, d), _fused_wout_map),
        ],
        # whole combined output resident: row blocks of the same token
        # accumulate into it across the sequential grid
        out_specs=pl.BlockSpec((T, d), _resident_map),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32),
                        pltpu.VMEM((bc, d), x_padded.dtype)],
    )
    kernel = functools.partial(_fused_kernel, activation=activation,
                               block_c=bc)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=interpret,
    )(slot_to_token, slot_w.astype(jnp.float32), block_row, block_eid,
      block_nvalid, x_padded, w_in, w_gate, w_out)


# ---------------------------------------------------------------------------
# analyzer layout (repro.analysis.pallas_check)
# ---------------------------------------------------------------------------


@backend.register_kernel("moe_fused.local_moe")
def _fused_layouts():
    """Canonical fused-megakernel layout.  The [T, d] output block is
    revisited by *every* row block (its index map is constant while the
    non-trailing grid dimension b varies) — the exact scatter-revisit
    pattern the analyzer requires ``acc_guarded`` for; the kernel earns
    the flag with its ``(b == 0) & (j == 0)`` zero-init plus ``+=``
    scatter epilogue."""
    from repro.kernels.moe_gemm import ops

    E, T, d, f = 4, 128, 128, 512
    bf = 256
    seg_offsets = np.asarray([0, 128, 192, 320, 384], np.int32)
    seg_experts = np.arange(E, dtype=np.int32)
    bc, brow, beid, bseg, bloc = ops.plan_blocks(seg_offsets, seg_experts,
                                                 block_c=128)
    S = int(seg_offsets[-1])
    tok = np.arange(S, dtype=np.int32) % (T + 1)   # values in [0, T]
    slot_w = np.ones(S, np.float32)
    nv = np.full(brow.shape, bc, np.int32)
    grid = (brow.shape[0], f // bf)
    return [backend.KernelLayout(
        kernel="moe_fused.local_moe",
        grid=grid,
        prefetch=(tok, slot_w, brow, beid, nv),
        blocks=(
            backend.BlockDecl("x_padded", "in", 4, (T + 1, d), (T + 1, d),
                              _resident_map),
            backend.BlockDecl("w_in", "in", 4, (1, d, bf), (E, d, f),
                              _fused_win_map),
            backend.BlockDecl("w_gate", "in", 4, (1, d, bf), (E, d, f),
                              _fused_win_map),
            backend.BlockDecl("w_out", "in", 4, (1, bf, d), (E, f, d),
                              _fused_wout_map),
            backend.BlockDecl("o", "out", 4, (T, d), (T, d), _resident_map,
                              acc_guarded=True),
            backend.BlockDecl("acc", "scratch", 4, (bc, d)),
            backend.BlockDecl("xblk", "scratch", 4, (bc, d)),
        ),
        meta={"block_c": int(bc), "seg_offsets": seg_offsets,
              "seg_experts": seg_experts, "block_seg": bseg,
              "block_loc": bloc},
    )]
