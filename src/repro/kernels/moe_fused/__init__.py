"""Fused dispatch→GEMM→combine megakernel for local MoE traffic.

``local_moe`` folds the permute gather and the unpermute/gate-weight
combine into the ragged grouped GEMM's scalar-prefetch grid, so local
(self-level) dispatch never materializes a sorted [S, d] capacity buffer
in HBM.  Pallas TPU kernel in kernel.py, pure-jnp oracle in ref.py,
backend/autodiff policy in ops.py — same layout and shared
``repro.kernels.backend`` policy as ``moe_permute`` / ``moe_gemm``.
"""

from repro.kernels.moe_fused.ops import (    # noqa: F401
    local_moe,
    use_fused,
)
from repro.kernels.moe_fused.ref import local_moe_ref    # noqa: F401
