"""Pure-jnp oracle for the fused dispatch→GEMM→combine megakernel.

By construction this IS the three-kernel path — permute gather, ragged
grouped FFN, weighted scatter-add combine — composed out of the existing
references, so "fused allclose to (permute → grouped GEMM → unpermute)"
is the defining property, not an approximation.  It is differentiable
(the ragged reference masks invalid-row gradients) and doubles as the
``custom_vjp`` backward of the Pallas forward in ops.py.

Sentinel convention (shared with moe_permute): ``slot_to_token == T``
addresses an implicit zero row on the way in and is dropped by the
scatter on the way out; slots at or past a segment's ``rows_valid`` count
produce exact-zero FFN rows, so garbage tokens/weights parked there can
never leak into the combined output.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.moe_gemm.ref import grouped_ffn_ragged_ref
from repro.kernels.moe_permute.ref import permute_ref


def local_moe_ref(x, slot_to_token, slot_w, seg_offsets, seg_experts,
                  rows_valid, w_in, w_gate, w_out, *,
                  activation: str = "swiglu"):
    """Fused local MoE: token buffer in, combined token buffer out.

    x: [T, d] tokens; slot_to_token: [S] int32 in [0, T] (T = sentinel);
    slot_w: [S] combine weight per slot (0 for empty slots);
    seg_offsets/seg_experts/rows_valid: the static segment layout +
    runtime occupancy the ragged grouped FFN consumes.  Returns the
    [T, d] float32 combined output
    ``out[t] = sum_{s: slot_to_token[s]==t} slot_w[s] * FFN(x[t])[s]``.
    """
    T = x.shape[0]
    buf = permute_ref(x, slot_to_token)                         # [S, d]
    ys = grouped_ffn_ragged_ref(buf, seg_offsets, seg_experts, rows_valid,
                                w_in, w_gate, w_out, activation=activation)
    out = jnp.zeros((T, x.shape[1]), jnp.float32)
    return out.at[slot_to_token].add(
        ys.astype(jnp.float32) * slot_w[:, None].astype(jnp.float32),
        mode="drop")
