"""Public fused local-MoE entry with backend + autodiff policy.

:func:`local_moe` is the permute-free hot path for *local* dispatch
traffic: one kernel call takes the raw [T, d] token buffer plus the
flattened sort indices (``DispatchIndices.slot_to_token`` / ``slot_w``
and the static segment layout with its runtime ``rows_per_expert``
occupancy) and returns the [T, d] combined output — no sorted [S, d]
capacity buffer in HBM, no separate permute / unpermute round trips.

Backend selection is the shared ``repro.kernels.backend`` policy (the
same ``kernels_active`` decision moe_permute and moe_gemm resolve
through, so one engine call can never mix fused and unfused layers
across backends).  The kernel-off path and the ``custom_vjp`` backward
both run :func:`ref.local_moe_ref` — plain differentiable jnp — so
training and CPU CI work unchanged, and gate-weight gradients flow
through the fused combine multiply exactly as they do through
``unpermute``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import (float0 as _float0,
                                   interpret_mode as _interpret,
                                   kernels_active as _kernels_active)
from repro.kernels.moe_fused import kernel
from repro.kernels.moe_fused.ref import local_moe_ref
from repro.kernels.moe_gemm import ops as gemm_ops
from repro.kernels.moe_permute.ref import _with_zero_row


def use_fused(use_pallas=None) -> bool:
    """Whether the fused megakernel is active for this flag — the shared
    ``kernels_active`` decision, so it can never disagree with
    ``moe_gemm.ops.use_ragged`` / the moe_permute entries."""
    return _kernels_active(use_pallas)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_pallas(static, x, slot_to_token, slot_w, rows_valid, w_in,
                  w_gate, w_out):
    seg_offsets, seg_experts, activation, block_c, block_f, interpret = static
    bc, brow, beid, bseg, bloc = gemm_ops.plan_blocks(seg_offsets,
                                                      seg_experts, block_c)
    nvalid = jnp.clip(jnp.take(jnp.asarray(rows_valid, jnp.int32),
                               jnp.asarray(bseg)) - jnp.asarray(bloc),
                      0, bc).astype(jnp.int32)
    return kernel.local_moe_pallas(
        _with_zero_row(x), slot_to_token, slot_w, jnp.asarray(brow),
        jnp.asarray(beid), nvalid, w_in, w_gate, w_out,
        activation=activation, block_c=bc, block_f=block_f,
        interpret=interpret)


def _fused_fwd(static, x, slot_to_token, slot_w, rows_valid, w_in, w_gate,
               w_out):
    y = _fused_pallas(static, x, slot_to_token, slot_w, rows_valid, w_in,
                      w_gate, w_out)
    return y, (x, slot_to_token, slot_w, rows_valid, w_in, w_gate, w_out)


def _fused_bwd(static, res, g):
    seg_offsets, seg_experts, activation, *_ = static
    x, slot_to_token, slot_w, rows_valid, w_in, w_gate, w_out = res

    def f(x_, sw_, wi_, wg_, wo_):
        return local_moe_ref(
            x_, slot_to_token, sw_, seg_offsets, seg_experts, rows_valid,
            wi_, wg_ if activation == "swiglu" else None, wo_,
            activation=activation)

    _, vjp = jax.vjp(f, x, slot_w, w_in, w_gate, w_out)
    gx, gsw, gwi, gwg, gwo = vjp(g.astype(jnp.float32))
    return (gx, _float0(slot_to_token), gsw, _float0(rows_valid), gwi, gwg,
            gwo)


_fused_pallas.defvjp(_fused_fwd, _fused_bwd)


def local_moe(x, slot_to_token, slot_w, seg_offsets, seg_experts, rows_valid,
              w_in, w_gate, w_out, *, activation: str = "swiglu",
              block_c: int = 128, block_f: int = 256, use_pallas=None):
    """Fused dispatch→GEMM→combine over local traffic.

    x: [T, d] raw tokens; ``slot_to_token`` [S] / ``slot_w`` [S] are the
    flat sort-order maps ``routing.build_indices`` emits (sentinel ``T``
    marks empty slots, whose weight is 0); ``seg_offsets`` (static
    [n + 1]) / ``seg_experts`` (static [n]) describe the contiguous
    capacity segments of slot space and ``rows_valid`` (runtime [n]
    int32, or None = fully occupied) each segment's realized rows —
    identical contracts to ``moe_gemm.ops.grouped_ffn_ragged``.  Returns
    the [T, d] float32 combined output; on the kernel path the sorted
    [S, d] buffer is never materialized.
    """
    offs = tuple(int(o) for o in seg_offsets)
    exps = tuple(int(e) for e in seg_experts)
    S = slot_to_token.shape[0]
    assert len(offs) == len(exps) + 1 and offs[0] == 0 and offs[-1] == S, \
        (offs, len(exps), S)
    swiglu = activation == "swiglu" and w_gate is not None
    if rows_valid is None:
        rows_valid = jnp.asarray(
            [offs[s + 1] - offs[s] for s in range(len(exps))], jnp.int32)
    if not use_fused(use_pallas) or S == 0:
        return local_moe_ref(x, slot_to_token, slot_w, offs, exps,
                             rows_valid, w_in, w_gate if swiglu else None,
                             w_out, activation=activation)
    wg = w_gate if swiglu else w_in   # placeholder, un-grad-ed by gelu
    static = (offs, exps, "swiglu" if swiglu else "gelu",
              int(block_c), int(block_f), _interpret())
    return _fused_pallas(static, x, slot_to_token.astype(jnp.int32),
                         slot_w.astype(jnp.float32), rows_valid, w_in, wg,
                         w_out)
