"""Pallas decode attention: one query token per request against a long KV
cache (the decode_32k / long_500k hot loop).

Grid: (B, L/bl) — the cache-length axis is sequential, so the per-request
accumulator [H, hd], running max m [H] and normalizer l [H] live in the
revisited output blocks (flash-decoding style online softmax).  The kernel
is HBM-bandwidth-bound: each KV block is streamed through VMEM exactly
once, which is the roofline-optimal access pattern for decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, *,
                scale: float, bl: int, G: int, window: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])
        m_ref[0] = jnp.full_like(m_ref[0], NEG_INF)
        l_ref[0] = jnp.zeros_like(l_ref[0])

    q = q_ref[0].astype(jnp.float32) * scale         # [H, hd]
    k = k_ref[0].astype(jnp.float32)                 # [bl, K, hd]
    v = v_ref[0].astype(jnp.float32)
    H, hd = q.shape
    K = k.shape[1]
    qg = q.reshape(K, G, hd)
    s = jnp.einsum("kgh,lkh->kgl", qg, k)            # [K, G, bl]
    s = s.reshape(H, bl)

    n_valid = len_ref[0]                             # current length (scalar)
    kpos = j * bl + jax.lax.broadcasted_iota(jnp.int32, (H, bl), 1)
    mask = kpos < n_valid
    if window:
        mask &= kpos >= (n_valid - window)
    s = jnp.where(mask, s, NEG_INF)
    # rows past the cache end may be block-padding garbage (NaN): zero them
    # so 0-weight x garbage cannot poison the p@v product below
    lvalid = (mask[0])[:, None, None]                # [bl, 1, 1]
    v = jnp.where(lvalid, v, 0.0)
    s = jnp.where(jnp.isnan(s), NEG_INF, s)

    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)   # [H, bl]
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = alpha * l_prev + jnp.sum(p, axis=1)
    pg = p.reshape(K, G, bl)
    o_new = jnp.einsum("kgl,lkh->kgh", pg, v).reshape(H, hd)
    o_ref[0] = o_ref[0] * alpha[:, None] + o_new
    m_ref[0] = m_new


def decode_attention_pallas(q, k, v, lengths, *, sliding_window: int = 0,
                            block_l: int = 512, interpret: bool = False):
    """q: [B, H, hd]; k/v: [B, L, K, hd]; lengths: [B] valid entries.

    Returns [B, H, hd].
    """
    B, H, hd = q.shape
    L, K = k.shape[1], k.shape[2]
    G = H // K
    bl = min(block_l, L)
    nl = pl.cdiv(L, bl)
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_dec_kernel, scale=scale, bl=bl, G=G,
                               window=sliding_window)
    out, m, l = pl.pallas_call(
        kernel,
        grid=(B, nl),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bl, K, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bl, K, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j: (b, 0)),
            pl.BlockSpec((1, H), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
    l = jnp.where(l == 0.0, 1.0, l)
    return (out / l[..., None]).astype(q.dtype)
