"""Jitted public wrapper for decode attention."""

import functools
import os

import jax

from repro.kernels.decode_attn.kernel import decode_attention_pallas
from repro.kernels.decode_attn.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("sliding_window",))
def _ref_jit(q, k, v, lengths, sliding_window=0):
    return decode_attention_ref(q, k, v, lengths,
                                sliding_window=sliding_window)


def decode_attention(q, k, v, lengths, *, sliding_window: int = 0):
    if jax.default_backend() == "tpu":
        return decode_attention_pallas(q, k, v, lengths,
                                       sliding_window=sliding_window)
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return decode_attention_pallas(q, k, v, lengths,
                                       sliding_window=sliding_window,
                                       interpret=True)
    return _ref_jit(q, k, v, lengths, sliding_window)
