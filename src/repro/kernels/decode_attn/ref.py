"""Pure-jnp oracle for decode attention."""

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, lengths, *, sliding_window: int = 0):
    B, H, hd = q.shape
    L, K = k.shape[1], k.shape[2]
    G = H // K
    qg = (q.astype(jnp.float32) / np.sqrt(hd)).reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,blkh->bkgl", qg, k.astype(jnp.float32))
    kpos = jnp.arange(L)
    mask = kpos[None, :] < lengths[:, None]              # [B, L]
    if sliding_window:
        mask &= kpos[None, :] >= (lengths[:, None] - sliding_window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgl,blkh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
