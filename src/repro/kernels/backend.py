"""Shared Pallas backend policy for the kernel packages.

One policy, three consumers (``moe_permute``, ``moe_gemm``, ``moe_fused``)
— keeping it in a single module means the permute, GEMM, and fused layers
of the same engine call can never drift onto different backends:

* ``want_pallas(None)`` (auto) resolves to the Pallas kernels on
  accelerators (TPU/GPU) and the jnp references elsewhere;
  ``REPRO_KERNEL_INTERPRET=1`` additionally flips the auto default on, so
  CPU-only CI executes the kernel bodies under the interpreter.
* ``pallas_viable()``: TPU compiles through Mosaic; CPU runs
  ``interpret=True``; GPU has no Mosaic/Triton lowering for the
  scalar-prefetch grids these kernels use, so the reference path is used
  even when the flag is on.
* ``kernels_active(flag)`` — the one decision every public kernel entry
  keys on: ``want_pallas(flag) and pallas_viable()``.
* ``interpret_mode()``: everything that is not a real TPU interprets.

The module also hosts the **kernel registry** consumed by the static
analyzer (``repro.analysis.pallas_check``): each kernel package registers
a builder that re-states its grid / BlockSpec layout as ``KernelLayout``
declarations over canonical shapes, sharing the *same* index-map
functions the real ``pallas_call`` uses so the declaration cannot drift
from the kernel.
"""

from __future__ import annotations

import dataclasses
import os
from collections.abc import Callable, Sequence

import jax
import numpy as np

_ENV_TRUE = ("1", "true")
_ENV_FALSE = ("0", "false")


def use_pallas_default() -> bool:
    """The engine's auto policy: Pallas on accelerators, ref elsewhere."""
    return jax.default_backend() in ("tpu", "gpu")


def env_interpret() -> bool:
    """Strictly-parsed ``REPRO_KERNEL_INTERPRET``: 1/true -> on, 0/false
    (or unset) -> off, anything else raises.  A typo'd value used to be
    silently ignored, leaving CI on the jnp reference path while claiming
    to exercise the kernel bodies."""
    raw = os.environ.get("REPRO_KERNEL_INTERPRET")
    if raw is None:
        return False
    val = raw.strip().lower()
    if val in _ENV_TRUE:
        return True
    if val in _ENV_FALSE:
        return False
    raise ValueError(
        f"REPRO_KERNEL_INTERPRET={raw!r} is not a recognized value; "
        f"use one of {_ENV_TRUE + _ENV_FALSE}")


def want_pallas(use_pallas=None) -> bool:
    if use_pallas is None:
        return use_pallas_default() or env_interpret()
    return bool(use_pallas)


def pallas_viable() -> bool:
    return jax.default_backend() in ("tpu", "cpu")


def kernels_active(use_pallas=None) -> bool:
    """Whether the Pallas entries actually run for this ``use_pallas`` flag
    (vs the jnp references).  The dispatch engine keys the occupancy
    machinery (valid-count exchange, ragged/fused compute) off this."""
    return want_pallas(use_pallas) and pallas_viable()


def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


def float0(a):
    """Symbolic-zero cotangent for integer operands of a custom_vjp."""
    return np.zeros(a.shape, jax.dtypes.float0)


# ---------------------------------------------------------------------------
# kernel registry (consumed by repro.analysis.pallas_check)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockDecl:
    """One operand of a ``pallas_call``, as the analyzer sees it.

    ``index_map`` is the *same function object* the kernel's BlockSpec
    uses, called with ``(*grid_ids, *prefetch)`` where the prefetch
    vectors are numpy arrays — the analyzer evaluates it over the whole
    grid to bound-check the block indices it produces.  ``kind`` is one
    of ``"in"`` / ``"out"`` / ``"scratch"`` (scratch has no array shape
    or index map).  ``acc_guarded`` declares that revisits of the same
    output block across a non-trailing grid dimension are protected by a
    zero-init + read-modify-write accumulation (the fused megakernel's
    scatter pattern); the analyzer rejects unguarded revisits.
    """

    name: str
    kind: str
    dtype_bytes: float
    block_shape: tuple[int, ...]
    array_shape: tuple[int, ...] | None = None
    index_map: Callable[..., tuple[int, ...]] | None = None
    acc_guarded: bool = False


@dataclasses.dataclass(frozen=True)
class KernelLayout:
    """A concrete grid/BlockSpec instantiation of one kernel.

    ``prefetch`` holds the scalar-prefetch vectors (numpy) fed to every
    block's ``index_map``; ``meta`` carries kernel-specific invariants
    the analyzer cross-checks (e.g. the ``plan_blocks`` segment table
    behind a ragged layout's block vectors).
    """

    kernel: str
    grid: tuple[int, ...]
    blocks: tuple[BlockDecl, ...]
    prefetch: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


KERNEL_REGISTRY: dict[str, Callable[[], Sequence[KernelLayout]]] = {}


def register_kernel(name: str):
    """Register a layout builder under ``name``.  Builders take no
    arguments and return the kernel's canonical ``KernelLayout``s (one
    per representative shape family)."""

    def deco(fn):
        KERNEL_REGISTRY[name] = fn
        return fn

    return deco


def registered_layouts() -> dict[str, Sequence[KernelLayout]]:
    """Materialize every registered builder (importing the kernel
    packages is the caller's job — registration happens on import)."""
    return {name: tuple(build()) for name, build in
            sorted(KERNEL_REGISTRY.items())}
