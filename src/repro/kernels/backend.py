"""Shared Pallas backend policy for the kernel packages.

One policy, three consumers (``moe_permute``, ``moe_gemm``, ``moe_fused``)
— keeping it in a single module means the permute, GEMM, and fused layers
of the same engine call can never drift onto different backends:

* ``want_pallas(None)`` (auto) resolves to the Pallas kernels on
  accelerators (TPU/GPU) and the jnp references elsewhere;
  ``REPRO_KERNEL_INTERPRET=1`` additionally flips the auto default on, so
  CPU-only CI executes the kernel bodies under the interpreter.
* ``pallas_viable()``: TPU compiles through Mosaic; CPU runs
  ``interpret=True``; GPU has no Mosaic/Triton lowering for the
  scalar-prefetch grids these kernels use, so the reference path is used
  even when the flag is on.
* ``kernels_active(flag)`` — the one decision every public kernel entry
  keys on: ``want_pallas(flag) and pallas_viable()``.
* ``interpret_mode()``: everything that is not a real TPU interprets.
"""

from __future__ import annotations

import os

import jax
import numpy as np


def use_pallas_default() -> bool:
    """The engine's auto policy: Pallas on accelerators, ref elsewhere."""
    return jax.default_backend() in ("tpu", "gpu")


def want_pallas(use_pallas=None) -> bool:
    if use_pallas is None:
        return (use_pallas_default()
                or os.environ.get("REPRO_KERNEL_INTERPRET") == "1")
    return bool(use_pallas)


def pallas_viable() -> bool:
    return jax.default_backend() in ("tpu", "cpu")


def kernels_active(use_pallas=None) -> bool:
    """Whether the Pallas entries actually run for this ``use_pallas`` flag
    (vs the jnp references).  The dispatch engine keys the occupancy
    machinery (valid-count exchange, ragged/fused compute) off this."""
    return want_pallas(use_pallas) and pallas_viable()


def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


def float0(a):
    """Symbolic-zero cotangent for integer operands of a custom_vjp."""
    return np.zeros(a.shape, jax.dtypes.float0)
