"""Pallas grouped expert-FFN GEMMs: dense (equal-capacity) and ragged
(occupancy-aware).

Dense entry — computes, per expert e:

    y[e] = act(x[e] @ w_in[e] [, x[e] @ w_gate[e]]) @ w_out[e]

TPU mapping: grid (E, C/bc, F/bf); the f axis is the last (sequential) grid
dimension so the f32 accumulator block [bc, d] stays resident in a VMEM
scratch across f blocks and is cast back to the model dtype once, in the
epilogue of the last f block.  Block shapes keep the working set
(x: bc*d, w_in/w_gate: d*bf, w_out: bf*d, acc: bc*d f32) inside ~16 MB VMEM
with MXU-aligned (multiple-of-128) matmul dims.

Ragged entry — the occupancy-aware variant behind TA-MoE's skewed Eq. (7)
capacity plans: the flat [R, d] row buffer is pre-sorted into contiguous
per-(expert) segments whose *capacity* is static but whose *realized* row
count is a runtime value (delivered tokens vs planned slack).  The grid is
(row-block, f-block) over a static block decomposition of the segments;
three scalar-prefetch vectors in SMEM drive it MegaBlocks-style:

    block_row[b]     row-block index of block ``b`` in the flat buffer
                     (BlockSpec index map: the DMA source/dest address)
    block_eid[b]     expert whose weights block ``b`` multiplies
    block_nvalid[b]  runtime valid-row count of block ``b`` (0..bc)

``pl.when(block_nvalid[b] > 0)`` gates the whole MXU body, so row blocks
past a segment's realized rows issue **zero matmuls** and emit exact zero
rows; partially-filled blocks compute and mask rows past the count.  The
shapes (grid, buffers) stay fully static for jit — only the FLOPs are
data-dependent, at row-block granularity.

Both entries carry a ``custom_vjp`` with a pure-jnp backward (mirroring
``kernels/moe_permute``) so training runs the Pallas forward without
falling into Pallas autodiff; the dense backward lives here, the ragged
backward in ops.py next to the segment structure it needs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.moe_gemm.ref import grouped_ffn_ref


# BlockSpec index maps, named so the analyzer's registered layouts (see
# ``_registry_layouts`` below) evaluate the *same* functions the
# pallas_calls use — the declaration cannot drift from the kernel.

def _dense_x_map(e, i, j):
    return (e, i, 0)


def _dense_win_map(e, i, j):
    return (e, 0, j)


def _dense_wout_map(e, i, j):
    return (e, j, 0)


def _ragged_row_map(b, j, row, eid, nv):
    return (row[b], 0)


def _ragged_win_map(b, j, row, eid, nv):
    return (eid[b], 0, j)


def _ragged_wout_map(b, j, row, eid, nv):
    return (eid[b], j, 0)


# quant variant: two extra f32 scale vectors (per-block dequant factors)
# lead the prefetch tuple so the trailing three stay (row, eid, nvalid) —
# the convention ``analysis.pallas_check.check_plan_blocks`` keys on.

def _ragged_quant_row_map(b, j, s1, sg, row, eid, nv):
    return (row[b], 0)


def _ragged_quant_win_map(b, j, s1, sg, row, eid, nv):
    return (eid[b], 0, j)


def _ragged_quant_wout_map(b, j, s1, sg, row, eid, nv):
    return (eid[b], j, 0)


def _ffn_body(x, win_ref, wgate_ref, wout_ref, *, activation: str):
    """One (row-block, f-block) partial product, f32 [bc, d]."""
    win = win_ref[0]                   # [d, bf]
    wout = wout_ref[0]                 # [bf, d]
    h = jnp.dot(x, win, preferred_element_type=jnp.float32)
    if activation == "swiglu":
        g = jnp.dot(x, wgate_ref[0], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.dot(h.astype(x.dtype), wout, preferred_element_type=jnp.float32)


def _ffn_kernel(x_ref, win_ref, wgate_ref, wout_ref, y_ref, acc_ref, *,
                activation: str):
    j = pl.program_id(2)               # f-block index (sequential)
    nf = pl.num_programs(2)
    part = _ffn_body(x_ref[0], win_ref, wgate_ref, wout_ref,
                     activation=activation)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        acc_ref[...] += part

    @pl.when(j == nf - 1)
    def _epilogue():
        # cast the resident f32 accumulator back once, inside the kernel —
        # no whole-array astype over [E, C, d] on the outside
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


def _grouped_ffn_call(x, w_in, w_gate, w_out, activation, block_c, block_f,
                      interpret):
    E, C, d = x.shape
    f = w_in.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, f)
    nc = pl.cdiv(C, bc)
    nf = pl.cdiv(f, bf)
    kernel = functools.partial(_ffn_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, d), _dense_x_map),
            pl.BlockSpec((1, d, bf), _dense_win_map),
            pl.BlockSpec((1, d, bf), _dense_win_map),
            pl.BlockSpec((1, bf, d), _dense_wout_map),
        ],
        out_specs=pl.BlockSpec((1, bc, d), _dense_x_map),
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, w_in, w_gate, w_out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _grouped_ffn_pallas(x, w_in, w_gate, w_out, activation, block_c, block_f,
                        interpret):
    return _grouped_ffn_call(x, w_in, w_gate, w_out, activation, block_c,
                             block_f, interpret)


def _grouped_ffn_fwd(x, w_in, w_gate, w_out, activation, block_c, block_f,
                     interpret):
    y = _grouped_ffn_pallas(x, w_in, w_gate, w_out, activation, block_c,
                            block_f, interpret)
    return y, (x, w_in, w_gate, w_out)


def _grouped_ffn_bwd(activation, block_c, block_f, interpret, res, g):
    x, w_in, w_gate, w_out = res

    def f(x_, wi_, wg_, wo_):
        return grouped_ffn_ref(x_, wi_, wg_ if activation == "swiglu"
                               else None, wo_, activation=activation)

    _, vjp = jax.vjp(f, x, w_in, w_gate, w_out)
    return vjp(g.astype(x.dtype))


_grouped_ffn_pallas.defvjp(_grouped_ffn_fwd, _grouped_ffn_bwd)


def grouped_ffn_pallas(x, w_in, w_gate, w_out, *, activation: str = "swiglu",
                       block_c: int = 128, block_f: int = 256,
                       interpret: bool = False):
    """x: [E, C, d]; w_in/w_gate: [E, d, f]; w_out: [E, f, d] -> [E, C, d]."""
    swiglu = activation == "swiglu" and w_gate is not None
    if not swiglu:
        w_gate = w_in  # placeholder operand, unused (and un-grad-ed) by gelu
    return _grouped_ffn_pallas(x, w_in, w_gate, w_out,
                               "swiglu" if swiglu else "gelu",
                               block_c, block_f, interpret)


# ---------------------------------------------------------------------------
# occupancy-aware ragged entry
# ---------------------------------------------------------------------------


def _ragged_ffn_kernel(row_ref, eid_ref, nvalid_ref, x_ref, win_ref,
                       wgate_ref, wout_ref, y_ref, acc_ref, *,
                       activation: str):
    b = pl.program_id(0)               # row-block index (scalar-prefetched)
    j = pl.program_id(1)               # f-block index (sequential)
    nf = pl.num_programs(1)
    nv = nvalid_ref[b]                 # runtime valid rows of this block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the occupancy predicate: a row block past its segment's realized row
    # count does zero MXU work — the whole FFN body is skipped
    @pl.when(nv > 0)
    def _compute():
        part = _ffn_body(x_ref[...], win_ref, wgate_ref, wout_ref,
                         activation=activation)
        rows = jax.lax.broadcasted_iota(jnp.int32, part.shape, 0)
        acc_ref[...] += jnp.where(rows < nv, part, 0.0)

    @pl.when(j == nf - 1)
    def _epilogue():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def grouped_ffn_ragged_pallas(x, block_row, block_eid, block_nvalid, w_in,
                              w_gate, w_out, *, activation: str = "swiglu",
                              block_c: int, block_f: int = 256,
                              interpret: bool = False):
    """Occupancy-aware grouped FFN over a flat, segment-sorted row buffer.

    x: [R, d] flat rows; ``block_row``/``block_eid``/``block_nvalid`` are the
    [NB] scalar-prefetch vectors of a static block decomposition (see
    ``ops.plan_blocks``): block ``b`` covers rows
    ``block_row[b]*block_c : +block_c`` of ``x``, multiplies expert
    ``block_eid[b]``'s weights, and holds ``block_nvalid[b]`` (runtime)
    valid rows.  Rows past the valid count come back as exact zeros.
    ``block_c`` must divide every segment width (ops picks it that way), so
    no block straddles two experts.
    """
    R, d = x.shape
    f = w_in.shape[-1]
    bc = block_c
    bf = min(block_f, f)
    nb = block_row.shape[0]
    nf = pl.cdiv(f, bf)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nb, nf),
        in_specs=[
            pl.BlockSpec((bc, d), _ragged_row_map),
            pl.BlockSpec((1, d, bf), _ragged_win_map),
            pl.BlockSpec((1, d, bf), _ragged_win_map),
            pl.BlockSpec((1, bf, d), _ragged_wout_map),
        ],
        out_specs=pl.BlockSpec((bc, d), _ragged_row_map),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
    )
    kernel = functools.partial(_ragged_ffn_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(block_row, block_eid, block_nvalid, x, w_in, w_gate, w_out)


# ---------------------------------------------------------------------------
# quantized ragged entry (AQT-style int8 up-projections, i32 accumulate)
# ---------------------------------------------------------------------------


def _ragged_quant_kernel(s1_ref, sg_ref, row_ref, eid_ref, nvalid_ref,
                         x_ref, win_ref, wgate_ref, wout_ref, y_ref,
                         acc_ref, *, activation: str):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nf = pl.num_programs(1)
    nv = nvalid_ref[b]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(nv > 0)
    def _compute():
        xq = x_ref[...]                              # [bc, d] int8
        # int8 x int8 -> i32 accumulate on the MXU; one f32 dequant factor
        # per row block (= per segment x per expert), prefetched in SMEM
        h = jnp.dot(xq, win_ref[0],
                    preferred_element_type=jnp.int32)
        h = h.astype(jnp.float32) * s1_ref[b]
        if activation == "swiglu":
            g = jnp.dot(xq, wgate_ref[0],
                        preferred_element_type=jnp.int32)
            h = jax.nn.silu(g.astype(jnp.float32) * sg_ref[b]) * h
        else:
            h = jax.nn.gelu(h)
        # down-projection stays in the model dtype, f32 accumulate
        part = jnp.dot(h.astype(wout_ref.dtype), wout_ref[0],
                       preferred_element_type=jnp.float32)
        rows = jax.lax.broadcasted_iota(jnp.int32, part.shape, 0)
        acc_ref[...] += jnp.where(rows < nv, part, 0.0)

    @pl.when(j == nf - 1)
    def _epilogue():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def grouped_ffn_ragged_quant_pallas(xq, s1, sg, block_row, block_eid,
                                    block_nvalid, qw_in, qw_gate, w_out, *,
                                    out_dtype, activation: str = "swiglu",
                                    block_c: int, block_f: int = 256,
                                    interpret: bool = False):
    """Quantized occupancy-aware grouped FFN.

    Same grid / block decomposition / zero-slot contract as
    :func:`grouped_ffn_ragged_pallas`, but ``xq`` and ``qw_in``/``qw_gate``
    are int8 and the up-projection dots accumulate in i32.  ``s1``/``sg``
    are [NB] f32 per-block dequant factors (segment activation scale x
    expert weight scale), scalar-prefetched ahead of the block vectors so
    the trailing three prefetch operands keep the (row, eid, nvalid)
    convention.  The down-projection runs against the unquantized ``w_out``
    with f32 accumulation — "accumulate in i32/f32".
    """
    R, d = xq.shape
    f = qw_in.shape[-1]
    bc = block_c
    bf = min(block_f, f)
    nb = block_row.shape[0]
    nf = pl.cdiv(f, bf)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(nb, nf),
        in_specs=[
            pl.BlockSpec((bc, d), _ragged_quant_row_map),
            pl.BlockSpec((1, d, bf), _ragged_quant_win_map),
            pl.BlockSpec((1, d, bf), _ragged_quant_win_map),
            pl.BlockSpec((1, bf, d), _ragged_quant_wout_map),
        ],
        out_specs=pl.BlockSpec((bc, d), _ragged_quant_row_map),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
    )
    kernel = functools.partial(_ragged_quant_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, d), out_dtype),
        interpret=interpret,
    )(s1, sg, block_row, block_eid, block_nvalid, xq, qw_in, qw_gate, w_out)


# ---------------------------------------------------------------------------
# analyzer layouts (repro.analysis.pallas_check)
# ---------------------------------------------------------------------------


@backend.register_kernel("moe_gemm.grouped_ffn")
def _dense_layouts():
    """Canonical dense grouped-FFN layout: grid (E, C/bc, F/bf), resident
    f32 accumulator, f the trailing (sequential) dimension."""
    E, C, d, f = 4, 256, 128, 512
    bc, bf = 128, 256
    grid = (E, C // bc, f // bf)
    return [backend.KernelLayout(
        kernel="moe_gemm.grouped_ffn",
        grid=grid,
        blocks=(
            backend.BlockDecl("x", "in", 4, (1, bc, d), (E, C, d),
                              _dense_x_map),
            backend.BlockDecl("w_in", "in", 4, (1, d, bf), (E, d, f),
                              _dense_win_map),
            backend.BlockDecl("w_gate", "in", 4, (1, d, bf), (E, d, f),
                              _dense_win_map),
            backend.BlockDecl("w_out", "in", 4, (1, bf, d), (E, f, d),
                              _dense_wout_map),
            backend.BlockDecl("y", "out", 4, (1, bc, d), (E, C, d),
                              _dense_x_map),
            backend.BlockDecl("acc", "scratch", 4, (bc, d)),
        ),
    )]


@backend.register_kernel("moe_gemm.grouped_ffn_ragged")
def _ragged_layouts():
    """Canonical ragged layout: the block vectors come from the real
    ``ops.plan_blocks`` over a skewed segment table, so the analyzer
    checks the very divisor invariants the kernel relies on."""
    from repro.kernels.moe_gemm import ops  # circular at module scope

    E, d, f = 4, 128, 512
    bf = 256
    seg_offsets = np.asarray([0, 256, 384, 640, 768], np.int32)
    seg_experts = np.arange(E, dtype=np.int32)
    bc, brow, beid, bseg, bloc = ops.plan_blocks(seg_offsets, seg_experts,
                                                 block_c=128)
    R = int(seg_offsets[-1])
    nv = np.full(brow.shape, bc, np.int32)  # static stand-in (runtime value)
    grid = (brow.shape[0], f // bf)
    return [backend.KernelLayout(
        kernel="moe_gemm.grouped_ffn_ragged",
        grid=grid,
        prefetch=(brow, beid, nv),
        blocks=(
            backend.BlockDecl("x", "in", 4, (bc, d), (R, d),
                              _ragged_row_map),
            backend.BlockDecl("w_in", "in", 4, (1, d, bf), (E, d, f),
                              _ragged_win_map),
            backend.BlockDecl("w_gate", "in", 4, (1, d, bf), (E, d, f),
                              _ragged_win_map),
            backend.BlockDecl("w_out", "in", 4, (1, bf, d), (E, f, d),
                              _ragged_wout_map),
            backend.BlockDecl("y", "out", 4, (bc, d), (R, d),
                              _ragged_row_map),
            backend.BlockDecl("acc", "scratch", 4, (bc, d)),
        ),
        meta={"block_c": int(bc), "seg_offsets": seg_offsets,
              "seg_experts": seg_experts, "block_seg": bseg,
              "block_loc": bloc},
    )]


@backend.register_kernel("moe_gemm.grouped_ffn_ragged_quant")
def _ragged_quant_layouts():
    """Quantized ragged layout: int8 x / w_in / w_gate blocks (1 byte), f32
    per-block scale vectors leading the prefetch tuple, trailing three
    prefetch operands keep the (row, eid, nvalid) plan-blocks convention."""
    from repro.kernels.moe_gemm import ops  # circular at module scope

    E, d, f = 4, 128, 512
    bf = 256
    seg_offsets = np.asarray([0, 256, 384, 640, 768], np.int32)
    seg_experts = np.arange(E, dtype=np.int32)
    bc, brow, beid, bseg, bloc = ops.plan_blocks(seg_offsets, seg_experts,
                                                 block_c=128)
    R = int(seg_offsets[-1])
    nv = np.full(brow.shape, bc, np.int32)  # static stand-in (runtime value)
    s1 = np.ones(brow.shape, np.float32)    # per-block dequant factors
    grid = (brow.shape[0], f // bf)
    return [backend.KernelLayout(
        kernel="moe_gemm.grouped_ffn_ragged_quant",
        grid=grid,
        prefetch=(s1, s1, brow, beid, nv),
        blocks=(
            backend.BlockDecl("x", "in", 1, (bc, d), (R, d),
                              _ragged_quant_row_map),
            backend.BlockDecl("w_in", "in", 1, (1, d, bf), (E, d, f),
                              _ragged_quant_win_map),
            backend.BlockDecl("w_gate", "in", 1, (1, d, bf), (E, d, f),
                              _ragged_quant_win_map),
            backend.BlockDecl("w_out", "in", 4, (1, bf, d), (E, f, d),
                              _ragged_quant_wout_map),
            backend.BlockDecl("y", "out", 4, (bc, d), (R, d),
                              _ragged_quant_row_map),
            backend.BlockDecl("acc", "scratch", 4, (bc, d)),
        ),
        meta={"block_c": int(bc), "seg_offsets": seg_offsets,
              "seg_experts": seg_experts, "block_seg": bseg,
              "block_loc": bloc},
    )]
