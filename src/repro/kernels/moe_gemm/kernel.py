"""Pallas grouped expert-FFN GEMM.

Computes, per expert e:   y[e] = act(x[e] @ w_in[e] [, x[e] @ w_gate[e]]) @ w_out[e]

TPU mapping: grid (E, C/bc, F/bf); the f axis is the last (sequential) grid
dimension so the output block [bc, d] stays resident in VMEM and accumulates
partial products across f blocks.  Block shapes keep the working set
(x: bc*d, w_in/w_gate: d*bf, w_out: bf*d, acc: bc*d f32) inside ~16 MB VMEM
with MXU-aligned (multiple-of-128) matmul dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, win_ref, wgate_ref, wout_ref, y_ref, *,
                activation: str, nf: int):
    j = pl.program_id(2)  # f-block index (sequential)

    x = x_ref[0]                       # [bc, d]
    win = win_ref[0]                   # [d, bf]
    wout = wout_ref[0]                 # [bf, d]
    h = jnp.dot(x, win, preferred_element_type=jnp.float32)
    if activation == "swiglu":
        g = jnp.dot(x, wgate_ref[0], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    part = jnp.dot(h.astype(x.dtype), wout,
                   preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        y_ref[0] = part

    @pl.when(j > 0)
    def _acc():
        y_ref[0] += part


def grouped_ffn_pallas(x, w_in, w_gate, w_out, *, activation: str = "swiglu",
                       block_c: int = 128, block_f: int = 256,
                       interpret: bool = False):
    """x: [E, C, d]; w_in/w_gate: [E, d, f]; w_out: [E, f, d] -> [E, C, d]."""
    E, C, d = x.shape
    f = w_in.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, f)
    nc = pl.cdiv(C, bc)
    nf = pl.cdiv(f, bf)

    swiglu = activation == "swiglu" and w_gate is not None
    if not swiglu:
        w_gate = w_in  # placeholder operand, unused by the gelu path

    kernel = functools.partial(_ffn_kernel,
                               activation="swiglu" if swiglu else "gelu",
                               nf=nf)
    out = pl.pallas_call(
        kernel,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, d, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, bf, d), lambda e, i, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), jnp.float32),
        interpret=interpret,
    )(x, w_in, w_gate, w_out)
    return out.astype(x.dtype)
