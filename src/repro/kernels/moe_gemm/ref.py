"""Pure-jnp oracles for the grouped expert FFN (dense and ragged)."""

import jax
import jax.numpy as jnp
import numpy as np


def grouped_ffn_ref(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w_in.astype(jnp.float32))
    if activation == "swiglu" and w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                       w_gate.astype(jnp.float32))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype).astype(jnp.float32),
                   w_out.astype(jnp.float32))
    return y.astype(x.dtype)


def segment_relayout_maps(src_offsets, dst_offsets):
    """Static index maps for re-laying flat segment rows into a padded
    segment layout (all numpy, built at trace time).

    ``src_offsets`` / ``dst_offsets`` are [S + 1] offset vectors of the
    same S segments in the source and destination (padded) flat buffers;
    every destination width must be >= its source width.  Returns
    ``(gather, carve)``: ``gather[p]`` is the source row of destination
    row ``p`` — the sentinel ``R`` (= one-past-the-end, callers append a
    zero row) for pad rows — and ``carve[r]`` is the destination position
    of source row ``r``.  This is the one place the sentinel-gather /
    searchsorted carve-back arithmetic lives; both the ragged reference
    and the kernel path's ``row_align`` padding resolve through it.
    """
    src = np.asarray(src_offsets, np.int64)
    dst = np.asarray(dst_offsets, np.int64)
    R, Rp = int(src[-1]), int(dst[-1])
    widths = src[1:] - src[:-1]
    p = np.arange(Rp)
    seg_p = np.searchsorted(dst[1:], p, side="right")
    local = p - dst[seg_p]
    gather = np.where(local < widths[seg_p], src[seg_p] + local, R)
    r = np.arange(R)
    seg_r = np.searchsorted(src[1:], r, side="right")
    carve = dst[seg_r] + (r - src[seg_r])
    return gather, carve


def grouped_ffn_ragged_ref(x, seg_offsets, seg_experts, rows_valid, w_in,
                           w_gate, w_out, *, activation: str = "swiglu"):
    """Oracle for the occupancy-aware ragged entry.

    ``x`` is a flat [R, d] buffer of static, contiguous segments: segment
    ``s`` owns rows ``seg_offsets[s]:seg_offsets[s + 1]`` and multiplies
    expert ``seg_experts[s]``'s weights.  ``rows_valid`` (runtime [S] int32,
    or None for fully occupied) caps each segment's realized rows: rows at
    or past the count are masked on input and forced to exact zero on
    output — the zero-slot convention the kernel shares.

    Implementation: one batched gather lifts the flat buffer onto a
    [S, cmax, d] equal-width view (row-index matrix built in numpy at trace
    time — no per-segment Python ops in the graph), the dense einsums run
    with per-segment gathered weights, and a second gather carves the flat
    layout back out.  Differentiable (the masks zero invalid-row
    gradients), so this is also the ``custom_vjp`` backward of the Pallas
    forward.
    """
    offs = np.asarray([int(o) for o in seg_offsets], np.int64)
    exps = tuple(int(e) for e in seg_experts)
    S = len(exps)
    R = x.shape[0]
    assert offs.shape[0] == S + 1 and offs[0] == 0 and offs[-1] == R, \
        (offs, S, x.shape)
    widths = offs[1:] - offs[:-1]
    if not S or R == 0:
        return jnp.zeros_like(x)
    cmax = int(widths.max())

    row = np.arange(cmax)[None, :]                          # [1, cmax]
    in_seg = row < widths[:, None]                          # [S, cmax] static
    equal = bool((widths == cmax).all())
    if equal:
        # the engine's common case: equal segments view for free
        xs = x.reshape(S, cmax, -1)
    else:
        gather, carve = segment_relayout_maps(
            offs, np.arange(S + 1) * cmax)
        xz = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
        xs = jnp.take(xz, jnp.asarray(gather.reshape(S, cmax)),
                      axis=0)                               # [S, cmax, d]

    if rows_valid is None:
        mask = jnp.asarray(in_seg)
    else:
        mask = jnp.asarray(in_seg) & \
            (jnp.asarray(row) < jnp.asarray(rows_valid, jnp.int32)[:, None])
    xs = xs * mask[..., None].astype(xs.dtype)

    eid = jnp.asarray(exps, jnp.int32)
    wg = None if w_gate is None else jnp.take(w_gate, eid, axis=0)
    ys = grouped_ffn_ref(xs, jnp.take(w_in, eid, axis=0), wg,
                         jnp.take(w_out, eid, axis=0), activation=activation)
    ys = ys * mask[..., None].astype(ys.dtype)
    if equal:
        return ys.reshape(R, -1)
    # carve the flat layout back out: flat row offs[s] + l lives at [s, l]
    return jnp.take(ys.reshape(S * cmax, -1), jnp.asarray(carve), axis=0)
