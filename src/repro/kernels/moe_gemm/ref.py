"""Pure-jnp oracle for the grouped expert FFN."""

import jax
import jax.numpy as jnp


def grouped_ffn_ref(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w_in.astype(jnp.float32))
    if activation == "swiglu" and w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                       w_gate.astype(jnp.float32))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype).astype(jnp.float32),
                   w_out.astype(jnp.float32))
    return y.astype(x.dtype)
