"""Pure-jnp oracles for the grouped expert FFN (dense and ragged)."""

import jax
import jax.numpy as jnp
import numpy as np


def grouped_ffn_ref(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w_in.astype(jnp.float32))
    if activation == "swiglu" and w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                       w_gate.astype(jnp.float32))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype).astype(jnp.float32),
                   w_out.astype(jnp.float32))
    return y.astype(x.dtype)


def segment_relayout_maps(src_offsets, dst_offsets):
    """Static index maps for re-laying flat segment rows into a padded
    segment layout (all numpy, built at trace time).

    ``src_offsets`` / ``dst_offsets`` are [S + 1] offset vectors of the
    same S segments in the source and destination (padded) flat buffers;
    every destination width must be >= its source width.  Returns
    ``(gather, carve)``: ``gather[p]`` is the source row of destination
    row ``p`` — the sentinel ``R`` (= one-past-the-end, callers append a
    zero row) for pad rows — and ``carve[r]`` is the destination position
    of source row ``r``.  This is the one place the sentinel-gather /
    searchsorted carve-back arithmetic lives; both the ragged reference
    and the kernel path's ``row_align`` padding resolve through it.
    """
    src = np.asarray(src_offsets, np.int64)
    dst = np.asarray(dst_offsets, np.int64)
    R, Rp = int(src[-1]), int(dst[-1])
    widths = src[1:] - src[:-1]
    p = np.arange(Rp)
    seg_p = np.searchsorted(dst[1:], p, side="right")
    local = p - dst[seg_p]
    gather = np.where(local < widths[seg_p], src[seg_p] + local, R)
    r = np.arange(R)
    seg_r = np.searchsorted(src[1:], r, side="right")
    carve = dst[seg_r] + (r - src[seg_r])
    return gather, carve


def quantize_segments(x, seg_offsets, *, qmax: float = 127.0):
    """Per-segment symmetric quantization of a flat [R, d] row buffer.

    One f32 scale per contiguous segment (absmax over the segment's rows /
    ``qmax``); all-zero or empty segments get scale 1 so the round trip is
    exact on zero-filled slack rows.  Returns ``(q_int8 [R, d], scale [S])``.
    """
    offs = np.asarray([int(o) for o in seg_offsets], np.int64)
    S = len(offs) - 1
    seg_ids = jnp.asarray(
        np.searchsorted(offs[1:], np.arange(int(offs[-1])), side="right"),
        jnp.int32)
    xf = x.astype(jnp.float32)
    row_max = jnp.max(jnp.abs(xf), axis=-1)
    absmax = jax.ops.segment_max(row_max, seg_ids, num_segments=S)
    scale = jnp.where(absmax > 0, absmax, qmax) / qmax
    q = jnp.clip(jnp.round(xf / jnp.take(scale, seg_ids)[:, None]),
                 -qmax, qmax).astype(jnp.int8)
    return q, scale


def quantize_experts(w, *, qmax: float = 127.0):
    """Per-expert symmetric quantization of [E, d, f] weights ->
    ``(q_int8, scale [E])``."""
    wf = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wf), axis=(1, 2))
    scale = jnp.where(absmax > 0, absmax, qmax) / qmax
    q = jnp.clip(jnp.round(wf / scale[:, None, None]),
                 -qmax, qmax).astype(jnp.int8)
    return q, scale


def grouped_ffn_ragged_quant_ref(x, seg_offsets, seg_experts, rows_valid,
                                 w_in, w_gate, w_out, *,
                                 activation: str = "swiglu"):
    """Oracle for the AQT-style quantized ragged entry.

    Same segment layout / masking contract as :func:`grouped_ffn_ragged_ref`
    but the two up-projections run in int8 with i32 accumulation: per-segment
    activation scales x per-expert ``w_in``/``w_gate`` scales, dequantized
    into f32 before the activation; the down-projection (``w_out``) stays in
    the model dtype with f32 accumulation.  Integer arithmetic is exact, so
    this reference and the Pallas kernel agree to f32-summation-order
    tolerance.
    """
    offs = np.asarray([int(o) for o in seg_offsets], np.int64)
    exps = tuple(int(e) for e in seg_experts)
    S = len(exps)
    R = x.shape[0]
    assert offs.shape[0] == S + 1 and offs[0] == 0 and offs[-1] == R, \
        (offs, S, x.shape)
    widths = offs[1:] - offs[:-1]
    if not S or R == 0:
        return jnp.zeros_like(x)
    cmax = int(widths.max())

    row = np.arange(cmax)[None, :]
    in_seg = row < widths[:, None]
    equal = bool((widths == cmax).all())
    if equal:
        xs = x.reshape(S, cmax, -1)
    else:
        gather, carve = segment_relayout_maps(offs, np.arange(S + 1) * cmax)
        xz = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
        xs = jnp.take(xz, jnp.asarray(gather.reshape(S, cmax)), axis=0)

    if rows_valid is None:
        mask = jnp.asarray(in_seg)
    else:
        mask = jnp.asarray(in_seg) & \
            (jnp.asarray(row) < jnp.asarray(rows_valid, jnp.int32)[:, None])
    xs = xs * mask[..., None].astype(xs.dtype)

    # per-segment activation quantization on the equal-width view (the
    # masked view matches the flat-buffer quantization under the zero-slot
    # convention) and per-expert weight quantization, gathered per segment
    qmax = 127.0
    xf = xs.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=(1, 2))
    sx = jnp.where(absmax > 0, absmax, qmax) / qmax            # [S]
    xq = jnp.clip(jnp.round(xf / sx[:, None, None]),
                  -qmax, qmax).astype(jnp.int8)

    eid = jnp.asarray(exps, jnp.int32)
    q_in, s_in = quantize_experts(w_in, qmax=qmax)
    h = jnp.einsum("scd,sdf->scf", xq.astype(jnp.int32),
                   jnp.take(q_in, eid, axis=0).astype(jnp.int32))
    h = h.astype(jnp.float32) * (sx * jnp.take(s_in, eid))[:, None, None]
    if activation == "swiglu" and w_gate is not None:
        q_g, s_g = quantize_experts(w_gate, qmax=qmax)
        g = jnp.einsum("scd,sdf->scf", xq.astype(jnp.int32),
                       jnp.take(q_g, eid, axis=0).astype(jnp.int32))
        g = g.astype(jnp.float32) * (sx * jnp.take(s_g, eid))[:, None, None]
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    ys = jnp.einsum("scf,sfd->scd", h.astype(w_out.dtype).astype(jnp.float32),
                    jnp.take(w_out, eid, axis=0).astype(jnp.float32))
    ys = (ys * mask[..., None].astype(ys.dtype)).astype(x.dtype)
    if equal:
        return ys.reshape(R, -1)
    return jnp.take(ys.reshape(S * cmax, -1), jnp.asarray(carve), axis=0)


def grouped_ffn_ragged_ref(x, seg_offsets, seg_experts, rows_valid, w_in,
                           w_gate, w_out, *, activation: str = "swiglu"):
    """Oracle for the occupancy-aware ragged entry.

    ``x`` is a flat [R, d] buffer of static, contiguous segments: segment
    ``s`` owns rows ``seg_offsets[s]:seg_offsets[s + 1]`` and multiplies
    expert ``seg_experts[s]``'s weights.  ``rows_valid`` (runtime [S] int32,
    or None for fully occupied) caps each segment's realized rows: rows at
    or past the count are masked on input and forced to exact zero on
    output — the zero-slot convention the kernel shares.

    Implementation: one batched gather lifts the flat buffer onto a
    [S, cmax, d] equal-width view (row-index matrix built in numpy at trace
    time — no per-segment Python ops in the graph), the dense einsums run
    with per-segment gathered weights, and a second gather carves the flat
    layout back out.  Differentiable (the masks zero invalid-row
    gradients), so this is also the ``custom_vjp`` backward of the Pallas
    forward.
    """
    offs = np.asarray([int(o) for o in seg_offsets], np.int64)
    exps = tuple(int(e) for e in seg_experts)
    S = len(exps)
    R = x.shape[0]
    assert offs.shape[0] == S + 1 and offs[0] == 0 and offs[-1] == R, \
        (offs, S, x.shape)
    widths = offs[1:] - offs[:-1]
    if not S or R == 0:
        return jnp.zeros_like(x)
    cmax = int(widths.max())

    row = np.arange(cmax)[None, :]                          # [1, cmax]
    in_seg = row < widths[:, None]                          # [S, cmax] static
    equal = bool((widths == cmax).all())
    if equal:
        # the engine's common case: equal segments view for free
        xs = x.reshape(S, cmax, -1)
    else:
        gather, carve = segment_relayout_maps(
            offs, np.arange(S + 1) * cmax)
        xz = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
        xs = jnp.take(xz, jnp.asarray(gather.reshape(S, cmax)),
                      axis=0)                               # [S, cmax, d]

    if rows_valid is None:
        mask = jnp.asarray(in_seg)
    else:
        mask = jnp.asarray(in_seg) & \
            (jnp.asarray(row) < jnp.asarray(rows_valid, jnp.int32)[:, None])
    xs = xs * mask[..., None].astype(xs.dtype)

    eid = jnp.asarray(exps, jnp.int32)
    wg = None if w_gate is None else jnp.take(w_gate, eid, axis=0)
    ys = grouped_ffn_ref(xs, jnp.take(w_in, eid, axis=0), wg,
                         jnp.take(w_out, eid, axis=0), activation=activation)
    ys = ys * mask[..., None].astype(ys.dtype)
    if equal:
        return ys.reshape(R, -1)
    # carve the flat layout back out: flat row offs[s] + l lives at [s, l]
    return jnp.take(ys.reshape(S * cmax, -1), jnp.asarray(carve), axis=0)
