"""Jitted public wrapper: picks the Pallas kernel on TPU, interpret-mode
Pallas under REPRO_KERNEL_INTERPRET=1 (CPU validation), jnp oracle otherwise."""

import functools
import os

import jax

from repro.kernels.moe_gemm.kernel import grouped_ffn_pallas
from repro.kernels.moe_gemm.ref import grouped_ffn_ref


def _backend() -> str:
    return jax.default_backend()


@functools.partial(jax.jit, static_argnames=("activation",))
def _ref_jit(x, w_in, w_gate, w_out, activation="swiglu"):
    return grouped_ffn_ref(x, w_in, w_gate, w_out, activation=activation)


def grouped_ffn(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    if _backend() == "tpu":
        return grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                  activation=activation)
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                  activation=activation, interpret=True)
    return _ref_jit(x, w_in, w_gate, w_out, activation)


def grouped_ffn_chunk(x, w_in, w_gate, w_out, *, activation: str = "swiglu",
                      row_align: int = 128):
    """Chunk-granular grouped FFN for the pipelined dispatch path.

    The pipelined a2a splits the capacity axis into chunks, so per-call row
    counts are ``cap/num_chunks`` slices that are usually *not* multiples of
    the MXU tile.  This entry pads the row axis up to ``row_align`` (the MXU
    systolic width; zero rows produce zero outputs in a bias-free FFN)
    before hitting the Pallas kernel and slices the result back, keeping
    every chunk GEMM on the fast aligned path instead of falling into a
    ragged tail block per chunk.
    """
    import jax.numpy as jnp

    E, C, d = x.shape
    pad = (-C) % row_align
    if pad:
        # zero rows produce zero outputs in the bias-free FFN on every
        # backend, so the pad path runs (and is tested) everywhere
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        return grouped_ffn(xp, w_in, w_gate, w_out,
                           activation=activation)[:, :C]
    return grouped_ffn(x, w_in, w_gate, w_out, activation=activation)
