"""Jitted public wrapper: picks the Pallas kernel on TPU, interpret-mode
Pallas under REPRO_KERNEL_INTERPRET=1 (CPU validation), jnp oracle otherwise."""

import functools
import os

import jax

from repro.kernels.moe_gemm.kernel import grouped_ffn_pallas
from repro.kernels.moe_gemm.ref import grouped_ffn_ref


def _backend() -> str:
    return jax.default_backend()


@functools.partial(jax.jit, static_argnames=("activation",))
def _ref_jit(x, w_in, w_gate, w_out, activation="swiglu"):
    return grouped_ffn_ref(x, w_in, w_gate, w_out, activation=activation)


def grouped_ffn(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    if _backend() == "tpu":
        return grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                  activation=activation)
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                  activation=activation, interpret=True)
    return _ref_jit(x, w_in, w_gate, w_out, activation)
