"""Jitted public wrapper: picks the Pallas kernel on TPU, interpret-mode
Pallas under REPRO_KERNEL_INTERPRET=1 (CPU validation), jnp oracle otherwise."""

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.kernel import grouped_ffn_pallas
from repro.kernels.moe_gemm.ref import grouped_ffn_ref


def _backend() -> str:
    return jax.default_backend()


@functools.partial(jax.jit, static_argnames=("activation",))
def _ref_jit(x, w_in, w_gate, w_out, activation="swiglu"):
    return grouped_ffn_ref(x, w_in, w_gate, w_out, activation=activation)


def grouped_ffn(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    if _backend() == "tpu":
        return grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                  activation=activation)
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                  activation=activation, interpret=True)
    return _ref_jit(x, w_in, w_gate, w_out, activation)


def grouped_ffn_segments(x, seg_offsets, w_in, w_gate, w_out, *,
                         activation: str = "swiglu", row_align: int = 1):
    """Segment-offset grouped FFN over a flat [R, d] row buffer.

    ``seg_offsets`` is a static, monotone [E + 1] offset vector: expert
    ``e`` owns rows ``seg_offsets[e]:seg_offsets[e + 1]``.  This is the
    layout the moe_permute dispatch emits — contiguous expert spans, in
    (stage, destination, expert) sort order per expert — so the equal-width
    case (every static capacity plan) reshapes straight onto the blocked
    ``grouped_ffn`` with zero data movement; ragged offsets fall back to
    per-segment calls.  ``row_align > 1`` routes equal segments through the
    row-padding chunk entry (pipelined dispatch slices are usually not
    MXU-tile multiples).
    """
    offs = tuple(int(o) for o in seg_offsets)
    E = w_in.shape[0]
    assert len(offs) == E + 1 and offs[0] == 0 and offs[-1] == x.shape[0], \
        (offs, E, x.shape)
    widths = [offs[e + 1] - offs[e] for e in range(E)]
    d = x.shape[-1]
    if len(set(widths)) == 1:
        xg = x.reshape(E, widths[0], d)
        if row_align > 1:
            y = grouped_ffn_chunk(xg, w_in, w_gate, w_out,
                                  activation=activation, row_align=row_align)
        else:
            y = grouped_ffn(xg, w_in, w_gate, w_out, activation=activation)
        return y.reshape(-1, d)
    parts = []
    for e in range(E):
        if offs[e + 1] == offs[e]:
            continue
        xe = x[offs[e]:offs[e + 1]][None]
        wg = w_gate[e:e + 1] if w_gate is not None else None
        parts.append(grouped_ffn(xe, w_in[e:e + 1], wg, w_out[e:e + 1],
                                 activation=activation)[0])
    return jnp.concatenate(parts, axis=0)


def grouped_ffn_chunk(x, w_in, w_gate, w_out, *, activation: str = "swiglu",
                      row_align: int = 128):
    """Chunk-granular grouped FFN for the pipelined dispatch path.

    The pipelined a2a splits the capacity axis into chunks, so per-call row
    counts are ``cap/num_chunks`` slices that are usually *not* multiples of
    the MXU tile.  This entry pads the row axis up to ``row_align`` (the MXU
    systolic width; zero rows produce zero outputs in a bias-free FFN)
    before hitting the Pallas kernel and slices the result back, keeping
    every chunk GEMM on the fast aligned path instead of falling into a
    ragged tail block per chunk.
    """
    E, C, d = x.shape
    pad = (-C) % row_align
    if pad:
        # zero rows produce zero outputs in the bias-free FFN on every
        # backend, so the pad path runs (and is tested) everywhere
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        return grouped_ffn(xp, w_in, w_gate, w_out,
                           activation=activation)[:, :C]
    return grouped_ffn(x, w_in, w_gate, w_out, activation=activation)
