"""Public grouped-FFN entry points with backend + autodiff policy.

Implementation selection is the shared ``repro.kernels.backend`` policy
(same module ``kernels/moe_permute`` resolves through, so the permute and
GEMM layers of one engine call can never drift apart): ``None`` / auto
resolves to the Pallas kernels on TPU, the jnp references elsewhere;
``REPRO_KERNEL_INTERPRET=1`` flips the auto default onto interpreted
kernels so CPU-only CI executes the kernel bodies; ``True``/``False``
force it (``True`` on CPU interprets, GPU always takes the reference —
no Triton lowering for scalar-prefetch grids).

Entries:

* :func:`grouped_ffn` — dense [E, C, d] equal-capacity grouped FFN.
* :func:`grouped_ffn_chunk` — dense with row padding to an MXU multiple
  (pipelined-dispatch chunk slices).
* :func:`grouped_ffn_ragged` — the occupancy-aware entry: a flat [R, d]
  buffer of static contiguous segments with *runtime* per-segment
  valid-row counts; row blocks past a segment's realized rows do zero MXU
  work and emit zero rows (see ``plan_blocks`` for the static block
  decomposition the scalar-prefetch grid consumes).
* :func:`grouped_ffn_ragged_quant` — the AQT-style quantized ragged entry
  (int8 up-projections, i32 accumulate, per-segment activation scales x
  per-expert weight scales, full-precision straight-through backward);
  ``grouped_ffn_segments(quantized=True)`` is how the dispatch engine
  reaches it when the wire codec opts delivered rows into low-precision
  compute.
* :func:`grouped_ffn_segments` — the segment-offset compat surface the
  dispatch engine historically called: equal spans reshape onto the dense
  entry when the kernels are off; any ragged layout (and every kernel-on
  call) routes through :func:`grouped_ffn_ragged` — the old per-segment
  Python-loop fallback is gone.

Both Pallas forwards carry a ``custom_vjp`` with a jnp backward (the
ragged one lives here, next to the segment structure it closes over), so
training never falls into Pallas autodiff for the GEMM.
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import (float0 as _float0,
                                   interpret_mode as _interpret,
                                   kernels_active as _kernels_active)
from repro.kernels.moe_gemm import kernel
from repro.kernels.moe_gemm.ref import (grouped_ffn_ragged_quant_ref,
                                        grouped_ffn_ragged_ref,
                                        grouped_ffn_ref,
                                        quantize_experts,
                                        quantize_segments,
                                        segment_relayout_maps)


def use_ragged(use_pallas=None) -> bool:
    """Whether the occupancy-aware Pallas entry is active for this flag.

    The dispatch engine keys the whole occupancy machinery (valid-count
    exchange, ragged compute) off this: when False the engine runs the
    legacy dense path untouched — no extra collectives on backends where
    the kernel would not run anyway.  This is the shared
    ``repro.kernels.backend.kernels_active`` decision, re-exported under
    the historical name.
    """
    return _kernels_active(use_pallas)


@functools.partial(jax.jit, static_argnames=("activation",))
def _ref_jit(x, w_in, w_gate, w_out, activation="swiglu"):
    return grouped_ffn_ref(x, w_in, w_gate, w_out, activation=activation)


def grouped_ffn(x, w_in, w_gate, w_out, *, activation: str = "swiglu"):
    if _kernels_active(None):
        return kernel.grouped_ffn_pallas(x, w_in, w_gate, w_out,
                                         activation=activation,
                                         interpret=_interpret())
    return _ref_jit(x, w_in, w_gate, w_out, activation)


# ---------------------------------------------------------------------------
# occupancy-aware ragged entry
# ---------------------------------------------------------------------------


def plan_blocks(seg_offsets, seg_experts, block_c: int = 128):
    """Static block decomposition of a segment layout.

    Picks the largest row-block size ``bc <= block_c`` that divides every
    non-empty segment width — so no block ever straddles two segments and
    no padding/repacking of the flat buffer is needed (static capacity
    plans are MXU-aligned by construction; tiny test plans just get small
    blocks).  Returns ``(bc, block_row, block_eid, block_seg, block_loc)``
    numpy vectors: block ``b`` covers flat rows ``block_row[b]*bc : +bc``,
    multiplies expert ``block_eid[b]``, and starts ``block_loc[b]`` rows
    into segment ``block_seg[b]``.
    """
    offs = tuple(int(o) for o in seg_offsets)
    widths = [offs[s + 1] - offs[s] for s in range(len(offs) - 1)]
    g = 0
    for w in widths:
        g = math.gcd(g, w)
    bc = 1
    for cand in range(min(g, int(block_c)), 0, -1):
        if g % cand == 0:
            bc = cand
            break
    rows, eids, segs, locs = [], [], [], []
    for s, (e, w) in enumerate(zip(seg_experts, widths)):
        for i in range(w // bc):
            rows.append(offs[s] // bc + i)
            eids.append(int(e))
            segs.append(s)
            locs.append(i * bc)
    return (bc, np.asarray(rows, np.int32), np.asarray(eids, np.int32),
            np.asarray(segs, np.int32), np.asarray(locs, np.int32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ragged_pallas(static, x, rows_valid, w_in, w_gate, w_out):
    seg_offsets, seg_experts, activation, block_c, block_f, interpret = static
    bc, brow, beid, bseg, bloc = plan_blocks(seg_offsets, seg_experts,
                                             block_c)
    nvalid = jnp.clip(jnp.take(jnp.asarray(rows_valid, jnp.int32),
                               jnp.asarray(bseg)) - jnp.asarray(bloc),
                      0, bc).astype(jnp.int32)
    return kernel.grouped_ffn_ragged_pallas(
        x, jnp.asarray(brow), jnp.asarray(beid), nvalid, w_in, w_gate,
        w_out, activation=activation, block_c=bc, block_f=block_f,
        interpret=interpret)


def _ragged_fwd(static, x, rows_valid, w_in, w_gate, w_out):
    y = _ragged_pallas(static, x, rows_valid, w_in, w_gate, w_out)
    return y, (x, rows_valid, w_in, w_gate, w_out)


def _ragged_bwd(static, res, g):
    seg_offsets, seg_experts, activation, *_ = static
    x, rows_valid, w_in, w_gate, w_out = res

    def f(x_, wi_, wg_, wo_):
        return grouped_ffn_ragged_ref(
            x_, seg_offsets, seg_experts, rows_valid, wi_,
            wg_ if activation == "swiglu" else None, wo_,
            activation=activation)

    _, vjp = jax.vjp(f, x, w_in, w_gate, w_out)
    gx, gwi, gwg, gwo = vjp(g.astype(x.dtype))
    return gx, _float0(rows_valid), gwi, gwg, gwo


_ragged_pallas.defvjp(_ragged_fwd, _ragged_bwd)


def grouped_ffn_ragged(x, seg_offsets, seg_experts, rows_valid, w_in, w_gate,
                       w_out, *, activation: str = "swiglu",
                       block_c: int = 128, block_f: int = 256,
                       row_align: int = 1, use_pallas=None):
    """Occupancy-aware grouped FFN over a flat [R, d] segment-sorted buffer.

    ``seg_offsets`` (static [S + 1]) and ``seg_experts`` (static [S]) give
    each contiguous segment's rows and expert; ``rows_valid`` (runtime [S]
    int32, or None = fully occupied) its realized row count.  The contract
    is the zero-slot convention shared with ``moe_permute``: callers keep
    rows at or past the valid count zero-filled (the permute sentinel does
    this for free), and the entry returns exact zeros there — on the kernel
    path whole row blocks past the count are skipped, so FLOPs track
    delivered tokens instead of planned capacity.

    ``row_align > 1`` (the pipelined dispatch passes the MXU systolic
    width) keeps the kernel path on MXU-friendly row blocks even when the
    segment widths are chunk slices with no nice divisor: segments are
    padded up to a multiple of ``min(row_align, block_c)`` through a
    batched gather before the kernel and carved back after — the padded
    rows sit past ``rows_valid``, so they are skipped/masked slack, exactly
    like capacity slack (this replaces what ``grouped_ffn_chunk`` did for
    the dense path).
    """
    offs = tuple(int(o) for o in seg_offsets)
    exps = tuple(int(e) for e in seg_experts)
    R = x.shape[0]
    assert len(offs) == len(exps) + 1 and offs[0] == 0 \
        and offs[-1] == R, (offs, len(exps), x.shape)
    if R == 0:
        return x
    swiglu = activation == "swiglu" and w_gate is not None
    widths = [offs[s + 1] - offs[s] for s in range(len(exps))]
    if rows_valid is None:
        rows_valid = jnp.asarray(widths, jnp.int32)
    if not use_ragged(use_pallas):
        return grouped_ffn_ragged_ref(x, offs, exps, rows_valid, w_in,
                                      w_gate if swiglu else None, w_out,
                                      activation=activation)

    wg = w_gate if swiglu else w_in   # placeholder, un-grad-ed by gelu
    align = max(1, min(int(row_align), int(block_c)))
    unaligned = align > 1 and any(w % align for w in widths)
    if unaligned:
        pw = np.asarray([-(-w // align) * align for w in widths], np.int64)
        poffs = np.concatenate([[0], np.cumsum(pw)])
        gather, carve = segment_relayout_maps(offs, poffs)
        xz = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
        xp = jnp.take(xz, jnp.asarray(gather), axis=0)   # sentinel -> zeros
        offs = tuple(int(o) for o in poffs)
    else:
        xp = x
    static = (offs, exps, "swiglu" if swiglu else "gelu",
              int(block_c), int(block_f), _interpret())
    y = _ragged_pallas(static, xp, rows_valid, w_in, wg, w_out)
    if unaligned:
        y = jnp.take(y, jnp.asarray(carve), axis=0)
    return y


# ---------------------------------------------------------------------------
# quantized ragged entry (AQT-style: int8 forward, straight-through backward)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ragged_quant(static, x, rows_valid, w_in, w_gate, w_out):
    (seg_offsets, seg_experts, activation, block_c, block_f, interpret,
     use_kernel) = static
    if not use_kernel:
        # quant reference fallback: same int8/i32 arithmetic, pure jnp —
        # numerics match the kernel on every backend
        return grouped_ffn_ragged_quant_ref(
            x, seg_offsets, seg_experts, rows_valid, w_in,
            w_gate if activation == "swiglu" else None, w_out,
            activation=activation)
    xq, sx = quantize_segments(x, seg_offsets)
    q_in, s_in = quantize_experts(w_in)
    q_g, s_g = quantize_experts(w_gate)
    bc, brow, beid, bseg, bloc = plan_blocks(seg_offsets, seg_experts,
                                             block_c)
    nvalid = jnp.clip(jnp.take(jnp.asarray(rows_valid, jnp.int32),
                               jnp.asarray(bseg)) - jnp.asarray(bloc),
                      0, bc).astype(jnp.int32)
    # per-block dequant factors: segment activation scale x expert weight
    # scale, resolved here so the kernel never chains SMEM lookups
    sx_b = jnp.take(sx, jnp.asarray(bseg))
    s1 = (sx_b * jnp.take(s_in, jnp.asarray(beid))).astype(jnp.float32)
    sg = (sx_b * jnp.take(s_g, jnp.asarray(beid))).astype(jnp.float32)
    return kernel.grouped_ffn_ragged_quant_pallas(
        xq, s1, sg, jnp.asarray(brow), jnp.asarray(beid), nvalid,
        q_in, q_g, w_out, out_dtype=x.dtype, activation=activation,
        block_c=bc, block_f=block_f, interpret=interpret)


def _ragged_quant_fwd(static, x, rows_valid, w_in, w_gate, w_out):
    y = _ragged_quant(static, x, rows_valid, w_in, w_gate, w_out)
    return y, (x, rows_valid, w_in, w_gate, w_out)


def _ragged_quant_bwd(static, res, g):
    # straight-through estimator: gradients flow through the full-precision
    # ragged reference, ignoring round/clip — the AQT training convention
    seg_offsets, seg_experts, activation, *_ = static
    x, rows_valid, w_in, w_gate, w_out = res

    def f(x_, wi_, wg_, wo_):
        return grouped_ffn_ragged_ref(
            x_, seg_offsets, seg_experts, rows_valid, wi_,
            wg_ if activation == "swiglu" else None, wo_,
            activation=activation)

    _, vjp = jax.vjp(f, x, w_in, w_gate, w_out)
    gx, gwi, gwg, gwo = vjp(g.astype(x.dtype))
    return gx, _float0(rows_valid), gwi, gwg, gwo


_ragged_quant.defvjp(_ragged_quant_fwd, _ragged_quant_bwd)


def grouped_ffn_ragged_quant(x, seg_offsets, seg_experts, rows_valid, w_in,
                             w_gate, w_out, *, activation: str = "swiglu",
                             block_c: int = 128, block_f: int = 256,
                             row_align: int = 1, use_pallas=None):
    """Quantized occupancy-aware grouped FFN (same surface as
    :func:`grouped_ffn_ragged`).

    The two up-projections run AQT-style — per-segment int8 activations x
    per-expert int8 weights with i32 accumulation, dequantized before the
    nonlinearity — while the down-projection stays in the model dtype with
    f32 accumulation.  Backward is the full-precision straight-through
    reference, so training gradients ignore the round/clip.  With the Pallas
    kernels off the forward falls back to the *quantized* jnp reference, so
    the arithmetic (and its error) is identical on every backend.
    """
    offs = tuple(int(o) for o in seg_offsets)
    exps = tuple(int(e) for e in seg_experts)
    R = x.shape[0]
    assert len(offs) == len(exps) + 1 and offs[0] == 0 \
        and offs[-1] == R, (offs, len(exps), x.shape)
    if R == 0:
        return x
    swiglu = activation == "swiglu" and w_gate is not None
    widths = [offs[s + 1] - offs[s] for s in range(len(exps))]
    if rows_valid is None:
        rows_valid = jnp.asarray(widths, jnp.int32)
    use_kernel = use_ragged(use_pallas)

    wg = w_gate if swiglu else w_in   # placeholder, un-grad-ed by gelu
    align = max(1, min(int(row_align), int(block_c)))
    unaligned = use_kernel and align > 1 and any(w % align for w in widths)
    if unaligned:
        pw = np.asarray([-(-w // align) * align for w in widths], np.int64)
        poffs = np.concatenate([[0], np.cumsum(pw)])
        gather, carve = segment_relayout_maps(offs, poffs)
        xz = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
        xp = jnp.take(xz, jnp.asarray(gather), axis=0)   # sentinel -> zeros
        offs = tuple(int(o) for o in poffs)
    else:
        xp = x
    static = (offs, exps, "swiglu" if swiglu else "gelu",
              int(block_c), int(block_f), _interpret(), use_kernel)
    y = _ragged_quant(static, xp, rows_valid, w_in, wg, w_out)
    if unaligned:
        y = jnp.take(y, jnp.asarray(carve), axis=0)
    return y


def grouped_ffn_segments(x, seg_offsets, w_in, w_gate, w_out, *,
                         activation: str = "swiglu", row_align: int = 1,
                         seg_experts=None, rows_valid=None, use_pallas=None,
                         quantized: bool = False):
    """Segment-offset grouped FFN over a flat [R, d] row buffer.

    ``seg_offsets`` is a static, monotone offset vector: segment ``s`` owns
    rows ``seg_offsets[s]:seg_offsets[s + 1]`` and multiplies expert
    ``seg_experts[s]`` (default: one segment per expert, in order).  This
    is the layout the moe_permute dispatch emits — contiguous sorted spans
    — so when the kernels are off and every span is equal and fully
    occupied, the buffer reshapes straight onto the dense ``grouped_ffn``
    with zero data movement (``row_align > 1`` routes through the
    row-padding chunk entry for pipelined slices).  Everything else —
    ragged static widths, runtime ``rows_valid`` occupancy, or the kernels
    on — goes through the occupancy-aware :func:`grouped_ffn_ragged`
    entry; there is no per-segment loop fallback any more.
    """
    offs = tuple(int(o) for o in seg_offsets)
    E = w_in.shape[0]
    if seg_experts is None:
        assert len(offs) == E + 1, (offs, E)
        seg_experts = tuple(range(E))
    assert offs[0] == 0 and offs[-1] == x.shape[0], (offs, x.shape)
    widths = [offs[s + 1] - offs[s] for s in range(len(seg_experts))]
    d = x.shape[-1]
    if quantized:
        # wire codec opted delivered rows into low-precision compute:
        # always the quantized ragged entry, never the dense fast path
        return grouped_ffn_ragged_quant(
            x, offs, seg_experts, rows_valid, w_in, w_gate, w_out,
            activation=activation, row_align=row_align,
            use_pallas=use_pallas)
    dense = (rows_valid is None and len(set(widths)) == 1
             and len(widths) == E
             and tuple(seg_experts) == tuple(range(E))
             and not use_ragged(use_pallas))
    if dense:
        xg = x.reshape(E, widths[0], d)
        if row_align > 1:
            y = grouped_ffn_chunk(xg, w_in, w_gate, w_out,
                                  activation=activation, row_align=row_align)
        else:
            y = grouped_ffn(xg, w_in, w_gate, w_out, activation=activation)
        return y.reshape(-1, d)
    return grouped_ffn_ragged(x, offs, seg_experts, rows_valid, w_in, w_gate,
                              w_out, activation=activation,
                              row_align=row_align, use_pallas=use_pallas)


def grouped_ffn_chunk(x, w_in, w_gate, w_out, *, activation: str = "swiglu",
                      row_align: int = 128):
    """Chunk-granular grouped FFN for the pipelined dispatch path.

    The pipelined a2a splits the capacity axis into chunks, so per-call row
    counts are ``cap/num_chunks`` slices that are usually *not* multiples of
    the MXU tile.  This entry pads the row axis up to ``row_align`` (the MXU
    systolic width; zero rows produce zero outputs in a bias-free FFN)
    before hitting the Pallas kernel and slices the result back, keeping
    every chunk GEMM on the fast aligned path instead of falling into a
    ragged tail block per chunk.
    """
    E, C, d = x.shape
    pad = (-C) % row_align
    if pad:
        # zero rows produce zero outputs in the bias-free FFN on every
        # backend, so the pad path runs (and is tested) everywhere
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        return grouped_ffn(xp, w_in, w_gate, w_out,
                           activation=activation)[:, :C]
    return grouped_ffn(x, w_in, w_gate, w_out, activation=activation)
