"""Sort-based token permutation kernels for the MoE dispatch hot path.

``permute`` gathers token rows into the (stage, destination, expert)-sorted
capacity buffers the staged all-to-all transports; ``unpermute`` inverts the
permutation on combine with the gate-weight multiply fused in.  Both have a
Pallas TPU kernel (kernel.py) and a pure-jnp reference (ref.py) selected by
ops.py per backend / ``use_pallas`` flag.
"""

from repro.kernels.moe_permute.ops import (    # noqa: F401
    permute,
    unpermute,
    use_pallas_default,
)
from repro.kernels.moe_permute.ref import (    # noqa: F401
    permute_ref,
    unpermute_ref,
)
