"""Public permute/unpermute entry points with backend + autodiff policy.

Implementation selection, given the engine-level ``use_pallas`` flag
(``None`` = auto):

* auto resolves to Pallas on accelerators (TPU/GPU) and the jnp reference
  elsewhere; ``REPRO_KERNEL_INTERPRET=1`` forces the Pallas bodies through
  the interpreter so CPU-only CI still executes them.
* On TPU the kernels compile through Mosaic.  On GPU the scalar-prefetch
  grid spec has no Triton lowering, so the reference path (whose XLA
  gather is already a fused kernel on GPU) is used even when the flag is
  on; on CPU a Pallas request runs ``interpret=True``.

Both Pallas entries carry a ``custom_vjp`` whose backward pass is plain
jnp scatter/gather — the permutation is its own (weighted) inverse — so
training works identically whichever implementation the forward picked,
and gate-weight gradients flow through the fused combine multiply.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import (float0 as _float0,
                                   interpret_mode as _interpret,
                                   kernels_active as _kernels_active,
                                   use_pallas_default)     # noqa: F401
from repro.kernels.moe_permute import kernel
from repro.kernels.moe_permute.ref import (_with_zero_row, permute_ref,
                                           unpermute_ref)


# --- permute ---------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _permute_pallas(x, slot_to_token, interpret):
    return kernel.permute_pallas(_with_zero_row(x), slot_to_token,
                                 interpret=interpret)


def _permute_fwd(x, slot_to_token, interpret):
    return _permute_pallas(x, slot_to_token, interpret), \
        (x.shape[0], slot_to_token)


def _permute_bwd(interpret, res, g):
    T, slot_to_token = res
    # inverse of a gather is a scatter-add; sentinel slots (index == T) are
    # out of bounds and dropped
    gx = jnp.zeros((T, g.shape[-1]), g.dtype)
    gx = gx.at[slot_to_token].add(g, mode="drop")
    return gx, _float0(slot_to_token)


_permute_pallas.defvjp(_permute_fwd, _permute_bwd)


def permute(x, slot_to_token, *, use_pallas=None):
    """[T, d] tokens -> [S, d] sorted capacity-slot rows (see ref.py for
    the sentinel convention)."""
    if _kernels_active(use_pallas):
        return _permute_pallas(x, slot_to_token, _interpret())
    return permute_ref(x, slot_to_token)


# --- unpermute -------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _unpermute_pallas(y, inv_idx, inv_w, interpret):
    return kernel.unpermute_pallas(_with_zero_row(y), inv_idx, inv_w,
                                   interpret=interpret)


def _unpermute_fwd(y, inv_idx, inv_w, interpret):
    return _unpermute_pallas(y, inv_idx, inv_w, interpret), (y, inv_idx,
                                                             inv_w)


def _unpermute_bwd(interpret, res, g):
    y, inv_idx, inv_w = res
    S, d = y.shape
    K = inv_idx.shape[1]
    g = g.astype(jnp.float32)                                   # [T, d]
    # K chunked scatter-adds / gathers: peak extra memory is one [T, d]
    # temporary per pick instead of a materialized [T, K, d] contrib tensor
    y_z = _with_zero_row(y)
    gy = jnp.zeros((S, d), jnp.float32)
    gw_cols = []
    for k in range(K):
        wk = inv_w[:, k].astype(jnp.float32)[:, None]           # [T, 1]
        # gy[s] = sum over picks mapping to slot s of w * g[token]
        gy = gy.at[inv_idx[:, k]].add(g * wk, mode="drop")
        # gw[t, k] = <g[t], y[inv_idx[t, k]]>
        picked = jnp.take(y_z, inv_idx[:, k], axis=0).astype(jnp.float32)
        gw_cols.append(jnp.sum(g * picked, axis=-1))
    gw = jnp.stack(gw_cols, axis=1).astype(inv_w.dtype)
    return gy.astype(y.dtype), _float0(inv_idx), gw


_unpermute_pallas.defvjp(_unpermute_fwd, _unpermute_bwd)


def unpermute(y, inv_idx, inv_w, *, use_pallas=None):
    """[S, d] slot rows -> [T, d] float32 combined tokens, gate-weight
    multiply fused (see ref.py for the sentinel convention)."""
    if _kernels_active(use_pallas):
        return _unpermute_pallas(y, inv_idx, inv_w, _interpret())
    return unpermute_ref(y, inv_idx, inv_w)
