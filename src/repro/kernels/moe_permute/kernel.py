"""Pallas token-permutation kernels (TPU scalar-prefetch row movers).

Both kernels are pure data movement: the slot/pick index vectors are
scalar-prefetched into SMEM so every grid step's BlockSpec index map can
address its source row *before* the body runs, turning the gather into a
pipelined chain of single-row DMAs — no [T, N, C] one-hot einsum, no
per-slot ``jnp.take`` scatter/gather HLOs in the dispatch hot path.

``permute``   grid (S,):     out[s] = x[slot_to_token[s]]
``unpermute`` grid (T, K):   out[t] = sum_k inv_w[t, k] * y[inv_idx[t, k]]
              (K is the last, sequential grid axis, so the [1, d] output
              block stays resident in VMEM and accumulates across picks —
              the gate-weight multiply is fused into the accumulation)

Sentinel convention (shared with ref.py): inputs arrive with one trailing
all-zero row; index == row-count selects it.  ops.py appends that row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend


# BlockSpec index maps, named so the analyzer layouts (bottom of file)
# evaluate the exact functions the pallas_calls use.

def _permute_src_map(s, idx_ref):
    return (idx_ref[s], 0)


def _permute_dst_map(s, idx_ref):
    return (s, 0)


def _unpermute_src_map(t, k, idx_ref, w_ref):
    return (idx_ref[t, k], 0)


def _unpermute_dst_map(t, k, idx_ref, w_ref):
    return (t, 0)


def _permute_kernel(idx_ref, x_ref, o_ref):
    del idx_ref  # consumed by the BlockSpec index map
    o_ref[0] = x_ref[0]


def permute_pallas(x_padded, slot_to_token, *, interpret: bool = False):
    """x_padded: [T + 1, d] (last row zeros); slot_to_token: [S] int32 in
    [0, T].  Returns [S, d] rows in sorted capacity-slot order."""
    S = slot_to_token.shape[0]
    d = x_padded.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, d), _permute_src_map)],
        out_specs=pl.BlockSpec((1, d), _permute_dst_map),
    )
    return pl.pallas_call(
        _permute_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, d), x_padded.dtype),
        interpret=interpret,
    )(slot_to_token, x_padded)


def _unpermute_kernel(idx_ref, w_ref, y_ref, o_ref):
    del idx_ref  # consumed by the BlockSpec index map
    t = pl.program_id(0)
    k = pl.program_id(1)
    part = y_ref[0].astype(jnp.float32) * w_ref[t, k]

    @pl.when(k == 0)
    def _init():
        o_ref[0] = part

    @pl.when(k > 0)
    def _acc():
        o_ref[0] += part


def unpermute_pallas(y_padded, inv_idx, inv_w, *, interpret: bool = False):
    """y_padded: [S + 1, d] (last row zeros); inv_idx: [T, K] int32 in
    [0, S]; inv_w: [T, K] float32.  Returns [T, d] float32 combined
    outputs (cast at the caller)."""
    T, K = inv_idx.shape
    d = y_padded.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # inv_idx, inv_w live in SMEM
        grid=(T, K),                 # K last => sequential accumulation
        in_specs=[pl.BlockSpec((1, d), _unpermute_src_map)],
        out_specs=pl.BlockSpec((1, d), _unpermute_dst_map),
    )
    return pl.pallas_call(
        _unpermute_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=interpret,
    )(inv_idx, inv_w.astype(jnp.float32), y_padded)


# ---------------------------------------------------------------------------
# analyzer layouts (repro.analysis.pallas_check)
# ---------------------------------------------------------------------------


@backend.register_kernel("moe_permute.permute")
def _permute_layouts():
    T, S, d = 96, 128, 128
    idx = np.arange(S, dtype=np.int32) % (T + 1)   # values in [0, T]
    return [backend.KernelLayout(
        kernel="moe_permute.permute",
        grid=(S,),
        prefetch=(idx,),
        blocks=(
            backend.BlockDecl("x_padded", "in", 4, (1, d), (T + 1, d),
                              _permute_src_map),
            backend.BlockDecl("o", "out", 4, (1, d), (S, d),
                              _permute_dst_map),
        ),
    )]


@backend.register_kernel("moe_permute.unpermute")
def _unpermute_layouts():
    T, S, K, d = 96, 128, 2, 128
    idx = (np.arange(T * K, dtype=np.int32) % (S + 1)).reshape(T, K)
    w = np.ones((T, K), np.float32)
    return [backend.KernelLayout(
        kernel="moe_permute.unpermute",
        grid=(T, K),
        prefetch=(idx, w),
        blocks=(
            backend.BlockDecl("y_padded", "in", 4, (1, d), (S + 1, d),
                              _unpermute_src_map),
            # revisited across the trailing (sequential) K axis only —
            # the resident accumulation the analyzer treats as safe
            backend.BlockDecl("o", "out", 4, (1, d), (T, d),
                              _unpermute_dst_map, acc_guarded=True),
        ),
    )]
