"""Pure-jnp oracle for the token permutation pair.

Sentinel convention (shared with kernel.py): an index equal to the source
row count addresses an implicit all-zero row — dropped / padded capacity
slots point at it on the way in, dropped gate picks point at it on the way
out — so neither direction needs a separate validity mask.
"""

import jax.numpy as jnp


def _with_zero_row(x):
    """Append the sentinel zero row: [R, d] -> [R + 1, d]."""
    return jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)


def permute_ref(x, slot_to_token):
    """Gather tokens into sorted capacity-slot order.

    x: [T, d] local tokens; slot_to_token: [S] int32 in [0, T] where T is
    the sentinel for empty slots.  Returns [S, d]: row ``s`` holds
    ``x[slot_to_token[s]]`` (zeros for sentinel slots).
    """
    return jnp.take(_with_zero_row(x), slot_to_token, axis=0)


def unpermute_ref(y, inv_idx, inv_w):
    """Invert the permutation with the combine-weight multiply fused in.

    y: [S, d] expert outputs in slot order; inv_idx: [T, K] int32 in
    [0, S] (S = sentinel for dropped picks); inv_w: [T, K] combine weights
    (0 for dropped picks).  Returns [T, d]:
    ``out[t] = sum_k inv_w[t, k] * y[inv_idx[t, k]]`` in float32.
    """
    g = jnp.take(_with_zero_row(y), inv_idx, axis=0).astype(jnp.float32)
    return jnp.sum(g * inv_w[..., None].astype(jnp.float32), axis=1)
