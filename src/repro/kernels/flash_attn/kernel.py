"""Pallas flash attention (prefill/train) with causal + sliding-window
masks and GQA head mapping.

Grid: (B, H, Sq/bq, Sk/bk) — the k axis is last (sequential), so the
output block, running max m and normalizer l stay VMEM-resident across k
blocks (online softmax).  Block shapes are MXU-aligned: bq, bk multiples of
128 where the sequence allows, head_dim is the contraction dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               sk: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        o_ref[0, 0] = jnp.zeros_like(o_ref[0, 0])
        m_ref[0, 0] = jnp.full_like(m_ref[0, 0], NEG_INF)
        l_ref[0, 0] = jnp.zeros_like(l_ref[0, 0])

    q = q_ref[0, 0].astype(jnp.float32) * scale      # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)              # [bk, hd]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk                      # ragged final block bound
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    s = jnp.where(jnp.isnan(s), NEG_INF, s)   # padded K rows may be garbage
    v = jnp.where((kpos[0] < sk)[:, None], v, 0.0)

    m_prev = m_ref[0, 0]                             # [bq]
    l_prev = l_ref[0, 0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: exp(NEG_INF - NEG_INF) = 1 — zero them explicitly
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    o_ref[0, 0] = o_ref[0, 0] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           sliding_window: int = 0, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, K, hd] -> [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Sk, bk)
    scale = 1.0 / np.sqrt(hd)

    qt = q.transpose(0, 2, 1, 3)      # [B, H, Sq, hd]
    kt = k.transpose(0, 2, 1, 3)      # [B, K, Sk, hd]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=sliding_window, bq=bq, bk=bk, sk=Sk)
    out, m, l = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    l = jnp.where(l == 0.0, 1.0, l)   # fully-masked query rows
    out = out / l[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)
