"""Jitted public wrapper for flash attention."""

import functools
import os

import jax

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window"))
def _ref_jit(q, k, v, causal=True, sliding_window=0):
    return flash_attention_ref(q, k, v, causal=causal,
                               sliding_window=sliding_window)


def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0):
    if jax.default_backend() == "tpu":
        return flash_attention_pallas(q, k, v, causal=causal,
                                      sliding_window=sliding_window)
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return flash_attention_pallas(q, k, v, causal=causal,
                                      sliding_window=sliding_window,
                                      interpret=True)
    return _ref_jit(q, k, v, causal, sliding_window)
