"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""

import jax.numpy as jnp

from repro.models.layers import _sdpa


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window: int = 0):
    Sq, Sk = q.shape[1], k.shape[1]
    return _sdpa(q, k, v, causal=causal, sliding_window=sliding_window,
                 q_positions=jnp.arange(Sq), k_positions=jnp.arange(Sk))
