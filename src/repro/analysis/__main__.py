"""``python -m repro.analysis`` — run the static contract checkers.

Default: all three checkers (HLO collective verifier, Pallas kernel
analyzer, repo-rule lint) against HEAD; a JSON report goes to
``--json PATH`` (and a human summary to stderr); exit 1 on violations.
``--fixture NAME`` runs a planted-violation fixture instead and *also*
exits 1 when the planted violation is (correctly) reported — CI asserts
nonzero there to prove each check fires.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import Report

CHECKERS = ("hlo", "pallas", "lint")


def _run_checker(name: str):
    if name == "hlo":
        from repro.analysis import hlo_check
        return hlo_check.run()
    if name == "pallas":
        from repro.analysis import pallas_check
        return pallas_check.run()
    from repro.analysis import lint
    return lint.run()


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("--only", choices=CHECKERS, action="append",
                        help="run a subset of checkers (repeatable)")
    parser.add_argument("--json", metavar="PATH",
                        help="write the JSON report here (default: stdout)")
    parser.add_argument("--fixture", metavar="NAME",
                        help="run a planted-violation fixture instead of "
                             "HEAD; exits nonzero when the check fires")
    parser.add_argument("--list-fixtures", action="store_true",
                        help="list fixture names and exit")
    args = parser.parse_args(argv)

    report = Report()
    if args.list_fixtures:
        from repro.analysis import fixtures
        print("\n".join(sorted(fixtures.FIXTURES)))
        return 0
    if args.fixture:
        from repro.analysis import fixtures
        report.extend("fixture", fixtures.run_fixture(args.fixture),
                      [args.fixture])
    else:
        for name in args.only or CHECKERS:
            violations, covered = _run_checker(name)
            report.extend(name, violations, covered)

    payload = json.dumps(report.to_dict(), indent=2)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(payload + "\n")
    else:
        print(payload)

    checked = sum(len(v) for v in report.checked.values())
    if report.ok:
        print(f"analysis OK: {checked} targets checked, no violations",
              file=sys.stderr)
        return 0
    print(f"analysis FAILED: {len(report.violations)} violation(s) across "
          f"{checked} checked targets", file=sys.stderr)
    for v in report.violations:
        print(f"  [{v.checker}/{v.rule}] {v.where}: {v.message}",
              file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
