"""Static Pallas kernel analyzer.

Walks the kernel registry (``repro.kernels.backend.KERNEL_REGISTRY`` —
each kernel package registers ``KernelLayout`` declarations built from
the *same* index-map functions its ``pallas_call`` uses) and checks, per
layout, without executing anything:

* **vmem-budget** — the per-grid-step working set (every in/out block,
  double-buffered unless its index map is constant over the grid i.e.
  the block is resident, plus scratch) must fit the VMEM budget.
* **index-bounds** — every block index the index maps produce over the
  *entire* grid (scalar-prefetch vectors included) must address a block
  inside the declared array shape.
* **plan-blocks** — layouts built over a ``plan_blocks`` decomposition
  (``meta`` carries the segment table) must satisfy its invariants: the
  block size divides every non-empty segment width, no row block
  straddles two segments, and each block's expert id matches its
  segment's.
* **scatter-race** — an output block revisited across a **non-trailing**
  grid dimension leaves the VMEM-resident window between visits; unless
  the kernel declares ``acc_guarded`` (zero-init + read-modify-write,
  the fused megakernel's scatter epilogue), the revisit silently
  clobbers earlier writes.  Revisits that only vary the trailing
  (sequential) dimension stay resident and are safe.
"""

from __future__ import annotations

import itertools
import math

from repro.analysis import Violation

# Per-core VMEM on current TPUs is ~16 MiB; kernels budget their working
# sets against it (see the moe_gemm docstring).
VMEM_BUDGET_BYTES = 16 * 2 ** 20


def _iter_grid(grid):
    return itertools.product(*(range(n) for n in grid))


def _block_indices(block, grid, prefetch):
    """Evaluate ``block.index_map`` over the whole grid; yields
    ``(grid_point, block_index_tuple)``."""
    for pt in _iter_grid(grid):
        idx = block.index_map(*pt, *prefetch)
        yield pt, tuple(int(i) for i in idx)


def _is_resident(block, grid, prefetch):
    """A block whose index map is constant over the grid is fetched once
    and stays resident (no double buffering)."""
    seen = {idx for _, idx in _block_indices(block, grid, prefetch)}
    return len(seen) == 1


def check_vmem(layout) -> list[Violation]:
    total = 0
    for b in layout.blocks:
        nbytes = math.prod(b.block_shape) * b.dtype_bytes
        if b.kind == "scratch":
            total += nbytes
        else:
            resident = _is_resident(b, layout.grid, layout.prefetch)
            total += nbytes * (1 if resident else 2)  # double-buffered DMA
    if total > VMEM_BUDGET_BYTES:
        return [Violation(
            "pallas", "vmem-budget", layout.kernel,
            f"per-grid-step working set {int(total)} B exceeds the "
            f"{VMEM_BUDGET_BYTES} B VMEM budget")]
    return []


def check_index_bounds(layout) -> list[Violation]:
    out = []
    for b in layout.blocks:
        if b.kind == "scratch":
            continue
        nblocks = tuple(-(-a // s) for a, s in zip(b.array_shape,
                                                   b.block_shape))
        bad = None
        for pt, idx in _block_indices(b, layout.grid, layout.prefetch):
            if len(idx) != len(nblocks):
                bad = (pt, idx, "rank mismatch")
                break
            if any(i < 0 or i >= n for i, n in zip(idx, nblocks)):
                bad = (pt, idx, f"outside block bounds {nblocks}")
                break
        if bad is not None:
            pt, idx, why = bad
            out.append(Violation(
                "pallas", "index-bounds", f"{layout.kernel}:{b.name}",
                f"index map at grid point {pt} produced block index "
                f"{idx}: {why} (array {b.array_shape}, block "
                f"{b.block_shape})"))
    return out


def check_plan_blocks(layout) -> list[Violation]:
    meta = layout.meta
    if "seg_offsets" not in meta:
        return []
    out = []
    offs = [int(o) for o in meta["seg_offsets"]]
    experts = [int(e) for e in meta["seg_experts"]]
    bc = int(meta["block_c"])
    widths = [offs[s + 1] - offs[s] for s in range(len(offs) - 1)]
    for s, w in enumerate(widths):
        if w and w % bc:
            out.append(Violation(
                "pallas", "plan-blocks", layout.kernel,
                f"block size {bc} does not divide segment {s} width {w}"))
    # prefetch layout convention: the last three vectors are
    # (block_row, block_eid, block_nvalid) — see plan_blocks
    brow, beid = layout.prefetch[-3], layout.prefetch[-2]
    for b in range(len(brow)):
        start = int(brow[b]) * bc
        seg = None
        for s in range(len(widths)):
            if offs[s] <= start < offs[s + 1]:
                seg = s
                break
        if seg is None or start + bc > offs[seg + 1]:
            out.append(Violation(
                "pallas", "plan-blocks", layout.kernel,
                f"row block {b} (rows {start}:{start + bc}) straddles a "
                f"segment boundary"))
        elif int(beid[b]) != experts[seg]:
            out.append(Violation(
                "pallas", "plan-blocks", layout.kernel,
                f"row block {b} multiplies expert {int(beid[b])} but lies "
                f"in segment {seg} of expert {experts[seg]}"))
    return out


def check_scatter_race(layout) -> list[Violation]:
    out = []
    for b in layout.blocks:
        if b.kind != "out":
            continue
        visits = {}
        for pt, idx in _block_indices(b, layout.grid, layout.prefetch):
            visits.setdefault(idx, []).append(pt)
        for idx, pts in visits.items():
            nontrailing = {pt[:-1] for pt in pts}
            if len(nontrailing) > 1 and not b.acc_guarded:
                out.append(Violation(
                    "pallas", "scatter-race", f"{layout.kernel}:{b.name}",
                    f"output block {idx} is revisited across a "
                    f"non-trailing grid dimension (e.g. grid points "
                    f"{pts[0]} and {pts[-1]}) without an accumulation "
                    f"guard — earlier writes would be clobbered"))
                break
    return out


def check_layout(layout) -> list[Violation]:
    return (check_vmem(layout) + check_index_bounds(layout)
            + check_plan_blocks(layout) + check_scatter_race(layout))


def run(layouts=None) -> tuple[list[Violation], list[str]]:
    """Check every registered layout (or an explicit list, for fixtures).
    Returns ``(violations, covered_layout_names)``."""
    if layouts is None:
        # registration happens on import
        from repro.kernels import backend
        from repro.kernels.moe_fused import kernel as _f   # noqa: F401
        from repro.kernels.moe_gemm import kernel as _g    # noqa: F401
        from repro.kernels.moe_permute import kernel as _p # noqa: F401
        layouts = [lay for lays in backend.registered_layouts().values()
                   for lay in lays]
    violations, covered = [], []
    for lay in layouts:
        covered.append(lay.kernel)
        violations.extend(check_layout(lay))
    return violations, covered
