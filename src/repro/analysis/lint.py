"""Repo-rule lint: an AST pass over ``src/``.

Three rules, each encoding a convention the repo already documents but
until now only enforced by review:

* **raw-shard-map** — ``jax.shard_map`` / ``jax.make_mesh`` / the
  ``jax.experimental.shard_map`` module may only be touched by
  ``repro/compat.py`` (the version-portability shim every other module
  must import from — see ROADMAP "Version portability").
* **np-in-traced** — a ``np.*`` *call* inside a jit/custom_vjp-traced
  function executes at trace time and bakes its result into the jaxpr as
  a constant: silent recompiles, no grad, wrong under vmap.  Traced code
  should use ``jnp``; trace-time *constants* belong outside the
  function.
* **mutable-config-closure** — a jitted function that closes over a
  module-level mutable literal (dict/list/set) reads it at trace time;
  later mutation silently does nothing until an unrelated retrace picks
  it up.  Hoist the value to an argument or freeze it (tuple /
  dataclass).
"""

from __future__ import annotations

import ast
import pathlib

from repro.analysis import Violation

# the one module allowed to touch the raw entry points it wraps
COMPAT_SUFFIX = ("repro", "compat.py")

_TRACED_DECORATOR_TAILS = ("jit", "custom_vjp", "custom_jvp")


def _attr_chain(node):
    """Dotted-name string for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_traced_decorator(dec) -> bool:
    """jax.jit / jit / custom_vjp, possibly via functools.partial(...)."""
    if isinstance(dec, ast.Call):
        chain = _attr_chain(dec.func)
        if chain and chain.split(".")[-1] == "partial":
            return any(_is_traced_decorator(a) for a in dec.args)
        dec = dec.func
    chain = _attr_chain(dec)
    return bool(chain) and chain.split(".")[-1] in _TRACED_DECORATOR_TAILS


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, relpath: str):
        self.relpath = relpath
        self.is_compat = pathlib.PurePath(path).parts[-2:] == COMPAT_SUFFIX
        self.violations: list[Violation] = []
        self.mutable_globals: set[str] = set()
        self._depth = 0

    def _flag(self, rule: str, node, message: str) -> None:
        self.violations.append(Violation(
            "lint", rule, f"{self.relpath}:{node.lineno}", message))

    # -- rule: raw-shard-map ------------------------------------------------

    def visit_Import(self, node):
        if not self.is_compat:
            for alias in node.names:
                if alias.name.startswith("jax.experimental.shard_map"):
                    self._flag("raw-shard-map", node,
                               f"import of {alias.name}: go through "
                               f"repro.compat instead")
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        if not self.is_compat and node.module:
            if node.module.startswith("jax.experimental.shard_map"):
                self._flag("raw-shard-map", node,
                           f"from {node.module} import ...: go through "
                           f"repro.compat instead")
            elif node.module == "jax":
                for alias in node.names:
                    if alias.name in ("shard_map", "make_mesh"):
                        self._flag("raw-shard-map", node,
                                   f"from jax import {alias.name}: go "
                                   f"through repro.compat instead")
        self.generic_visit(node)

    def visit_Attribute(self, node):
        if not self.is_compat and node.attr in ("shard_map", "make_mesh"):
            chain = _attr_chain(node)
            if chain and chain.split(".")[0] == "jax":
                self._flag("raw-shard-map", node,
                           f"{chain}: go through repro.compat instead")
        self.generic_visit(node)

    # -- rules: np-in-traced, mutable-config-closure ------------------------

    def visit_Assign(self, node):
        if self._depth == 0:
            mutable = isinstance(node.value, (ast.Dict, ast.List, ast.Set))
            if (isinstance(node.value, ast.Call)
                    and _attr_chain(node.value.func) in ("dict", "list",
                                                         "set")):
                mutable = True
            if mutable:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.mutable_globals.add(tgt.id)
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        traced = any(_is_traced_decorator(d) for d in node.decorator_list)
        if traced:
            self._check_traced_body(node)
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check_traced_body(self, fn):
        locals_ = {a.arg for a in (fn.args.args + fn.args.posonlyargs
                                   + fn.args.kwonlyargs)}
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
                locals_.add(sub.id)
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                chain = _attr_chain(sub.func)
                if chain and chain.split(".")[0] in ("np", "numpy"):
                    self._flag(
                        "np-in-traced", sub,
                        f"{chain}() inside traced function "
                        f"'{fn.name}' runs at trace time (constant-folded "
                        f"into the jaxpr) — use jnp, or hoist it out")
            elif (isinstance(sub, ast.Name)
                    and isinstance(sub.ctx, ast.Load)
                    and sub.id in self.mutable_globals
                    and sub.id not in locals_):
                self._flag(
                    "mutable-config-closure", sub,
                    f"traced function '{fn.name}' closes over mutable "
                    f"module-level '{sub.id}' — mutations after trace are "
                    f"silently ignored; pass it as an argument or freeze "
                    f"it")


def lint_source(source: str, path: str, relpath: str | None = None
                ) -> list[Violation]:
    tree = ast.parse(source, filename=path)
    linter = _Linter(path, relpath or path)
    # module-level mutable bindings must be known before function bodies
    # are checked, so collect them in a first pass
    for node in tree.body:
        if isinstance(node, ast.Assign):
            linter.visit_Assign(node)
    linter.mutable_globals -= {"__all__"}
    linter.visit(tree)
    return linter.violations


def run(root=None) -> tuple[list[Violation], list[str]]:
    """Lint every .py file under ``src/`` (fixtures excluded — they exist
    to violate the rules).  Returns ``(violations, covered_files)``."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[2]  # src/
    root = pathlib.Path(root)
    violations, covered = [], []
    for path in sorted(root.rglob("*.py")):
        if "fixtures" in path.parts:
            continue
        rel = str(path.relative_to(root))
        covered.append(rel)
        violations.extend(lint_source(path.read_text(), str(path), rel))
    return violations, covered
