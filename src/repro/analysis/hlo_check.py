"""HLO collective verifier.

AOT-lowers the MoE step (``jax.jit(...).lower()`` over an abstract mesh —
no devices needed, so this runs on single-CPU CI) for every registered
dispatch path × topology, parses the StableHLO for collective ops, and
asserts the inventory matches what the Eq. (7) ``DispatchPlan`` promises:

* one all_to_all **chain** per active remote stage — stage ``s`` hops
  over its ``s+1`` delivery axes, each hop's ``replica_groups`` exactly
  the device groups of that mesh axis;
* per-hop payloads of ``num_dests × E_l × cap_chunk × d`` elements in
  the **wire dtype** (the resolved ``MoEConfig.wire_codec``), i.e. wire
  bytes scale with the plan's caps — and with the chunk count on the
  pipelined path;
* the valid-count exchange riding the same chain (int32, no wire cast)
  exactly when the occupancy-aware ragged GEMM is active;
* for **scaled** wire codecs (int8 / fp8e4m3), the per-segment f32
  scale sideband riding the same chain — one scale exchange per payload
  exchange, ``num_dests × E_l`` f32 elements each, dispatch and combine;
* **no** unaccounted collective anywhere in the step — stray
  all-gathers / reshards in the hot path are inventory violations, and
  the fused unit-mesh path must lower to **zero** collectives
  (generalizing the old ``test_moe_fused`` jaxpr pin);
* the gather path's per-axis all_gather + psum pairs, and the einsum
  oracle's empty inventory.

The expected inventory is *computed*, not hard-coded: it replicates the
engine's stage split (``plan_stages``, the fused local-stage shortcut,
``use_ragged``) and capacity arithmetic (cap clamp, chunk alignment)
from the same modules the engine uses, so a plan change moves both sides
together while a mapping bug moves only the lowering.
"""

from __future__ import annotations

import ast
import dataclasses
import math
import re

from repro.analysis import Violation

# innermost axis last, matching EPSpec's outermost-first hierarchy order
_AXIS_NAMES = {1: ("data",), 2: ("pod", "data"), 3: ("pod", "node", "data")}

# jnp dtype name -> StableHLO element type
_HLO_DTYPE = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
              "int32": "i32", "int8": "i8", "float8_e4m3fn": "f8E4M3FN",
              "float8_e5m2": "f8E5M2"}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One lowering under verification: dispatch path × topology × kernel
    flag (× wire dtype / chunk count)."""

    name: str
    axis_sizes: tuple
    path: str
    use_pallas: bool
    num_chunks: int = 1
    a2a_dtype: str = ""           # deprecated cast-only wire (kept for the
                                  # alias coverage); prefer wire_codec
    wire_codec: str = ""          # registered codec name in dispatch.wire
    tokens: int = 32
    num_experts: int = 16
    d_model: int = 16
    d_ff: int = 32
    top_k: int = 2
    capacity_factor: float = 2.0

    @property
    def axis_names(self) -> tuple:
        return _AXIS_NAMES[len(self.axis_sizes)]


def default_scenarios() -> tuple:
    """All four dispatch paths on the 2-level (2×2) and 3-level (2×2×2)
    meshes, kernels on and off, plus the pipelined chunking, the fused
    unit-mesh zero-collective pin, a cast-wire variant, and the scaled
    (int8 / fp8e4m3) wire-codec variants with their scale sidebands."""
    return (
        Scenario("a2a-2x2-ref", (2, 2), "a2a", False),
        Scenario("a2a-2x2-kernels", (2, 2), "a2a", True),
        Scenario("a2a_pipelined-2x2-kernels", (2, 2), "a2a_pipelined", True,
                 num_chunks=2),
        Scenario("gather-2x2-ref", (2, 2), "gather", False),
        Scenario("gather-2x2-kernels", (2, 2), "gather", True),
        Scenario("einsum-2x2", (2, 2), "einsum", False),
        Scenario("a2a-2x2x2-ref", (2, 2, 2), "a2a", False),
        Scenario("a2a-2x2x2-kernels", (2, 2, 2), "a2a", True),
        Scenario("a2a_pipelined-2x2x2-kernels", (2, 2, 2), "a2a_pipelined",
                 True, num_chunks=2),
        Scenario("gather-2x2x2-ref", (2, 2, 2), "gather", False),
        Scenario("einsum-2x2x2", (2, 2, 2), "einsum", False),
        Scenario("a2a-unit-mesh-fused", (1,), "a2a", True),
        Scenario("a2a-2x2-wire-bf16", (2, 2), "a2a", True,
                 a2a_dtype="bfloat16"),
        Scenario("a2a-2x2-wire-int8", (2, 2), "a2a", True,
                 wire_codec="int8"),
        Scenario("a2a-2x2x2-wire-fp8e4m3", (2, 2, 2), "a2a", True,
                 wire_codec="fp8e4m3"),
    )


@dataclasses.dataclass(frozen=True)
class Collective:
    """A collective op signature.  On *expected* entries, ``None`` fields
    are wildcards; parsed entries carry ``None`` only where the textual
    form omits the information (e.g. region ops' operand type)."""

    kind: str
    dtype: str | None = None
    elements: int | None = None
    groups: tuple | None = None

    def describe(self) -> str:
        parts = [self.kind]
        if self.dtype is not None:
            parts.append(f"dtype={self.dtype}")
        if self.elements is not None:
            parts.append(f"elements={self.elements}")
        if self.groups is not None:
            parts.append(f"groups={list(map(list, self.groups))}")
        return " ".join(parts)


def axis_groups(names, sizes, axis) -> tuple:
    """Replica groups of mesh axis ``axis``: device ids laid out
    row-major over the mesh, grouped by fixing every other axis."""
    import numpy as np

    ids = np.arange(math.prod(sizes)).reshape(sizes)
    k = names.index(axis)
    rows = np.moveaxis(ids, k, -1).reshape(-1, sizes[k])
    return tuple(sorted(tuple(int(x) for x in row) for row in rows))


# ---------------------------------------------------------------------------
# expected inventory (computed from the same modules the engine uses)
# ---------------------------------------------------------------------------


def _scenario_codec(sc: Scenario):
    """Resolve the scenario's wire codec the way MoEConfig does: the
    first-class ``wire_codec`` name wins, the deprecated ``a2a_dtype``
    falls back to a cast-only codec (no warning here — the analysis lane
    exercises the alias deliberately)."""
    from repro.core.dispatch import wire as wire_lib

    if sc.wire_codec:
        return wire_lib.get_codec(sc.wire_codec)
    if sc.a2a_dtype:
        return wire_lib.cast_codec(sc.a2a_dtype)
    return None


def expected_inventory(sc: Scenario) -> list:
    from repro.core import dispatch as dispatch_lib
    from repro.core.capacity import make_dispatch_plan
    from repro.core.dispatch import transport
    from repro.kernels.moe_fused import ops as fused_ops
    from repro.kernels.moe_gemm import ops as gemm_ops

    names = sc.axis_names
    T, d, N = sc.tokens, sc.d_model, sc.num_experts
    ep_world = math.prod(sc.axis_sizes)
    E_l = N // ep_world
    groups_of = {a: axis_groups(names, sc.axis_sizes, a) for a in names}

    if sc.path == "einsum":
        return []

    if sc.path == "gather":
        exp = []
        for a, size in zip(names, sc.axis_sizes):
            if size == 1:
                continue
            exp.append(Collective("all_gather", groups=groups_of[a]))
            exp.append(Collective("all_reduce", groups=groups_of[a]))
        return exp

    # staged a2a paths
    plan = make_dispatch_plan(
        tokens_per_device=T, num_experts=N, top_k=sc.top_k,
        capacity_factor=sc.capacity_factor, axis_sizes=sc.axis_sizes,
        mode="ta")
    ep = dispatch_lib.EPSpec.from_axes(names, sc.axis_sizes, model_axis=None)
    stages = transport.plan_stages(plan, ep)
    fused_on = fused_ops.use_fused(sc.use_pallas)
    ragged = gemm_ops.use_ragged(sc.use_pallas)
    codec = _scenario_codec(sc)
    wire = _HLO_DTYPE[str(codec.wire_dtype) if codec else "float32"]
    scaled = codec is not None and codec.scaled
    nc = max(1, sc.num_chunks)

    exp = []
    for stage in stages:
        if stage.cap <= 0:
            continue
        if fused_on and stage.num_dests == 1:
            continue  # fused local path: zero collectives for this stage
        cap_eff = min(int(stage.cap), T)       # routing.select's clamp
        aligned = -(-cap_eff // nc) * nc       # routing.pad_selection
        cpc = aligned // nc
        payload = stage.num_dests * E_l * cpc * d
        counts = stage.num_dests * E_l
        for ax, size in zip(stage.axis_names, stage.axis_sizes):
            if size == 1:
                continue  # trivial hop: jax lowers it away
            for _ in range(nc):
                # dispatch hop + combine hop, both in the wire dtype
                exp.append(Collective("all_to_all", wire, payload,
                                      groups_of[ax]))
                exp.append(Collective("all_to_all", wire, payload,
                                      groups_of[ax]))
                if scaled:
                    # per-segment f32 scale sideband: one exchange per
                    # payload exchange, shaped like the count tensor
                    exp.append(Collective("all_to_all", "f32", counts,
                                          groups_of[ax]))
                    exp.append(Collective("all_to_all", "f32", counts,
                                          groups_of[ax]))
                if ragged:
                    # valid-count exchange rides the same chain, exact i32
                    exp.append(Collective("all_to_all", "i32", counts,
                                          groups_of[ax]))
    return exp


# ---------------------------------------------------------------------------
# lowering + StableHLO parsing
# ---------------------------------------------------------------------------


def lower_scenario(sc: Scenario) -> str:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    try:
        from jax.sharding import AbstractMesh
    except ImportError:  # jax 0.4.x
        from jax._src.mesh import AbstractMesh

    from repro.compat import shard_map
    from repro.core import dispatch as dispatch_lib, gating
    from repro.core.capacity import make_dispatch_plan
    from repro.core.dispatch.base import moe_param_specs

    names = sc.axis_names
    T, d, N = sc.tokens, sc.d_model, sc.num_experts
    ep_world = math.prod(sc.axis_sizes)
    cfg = dispatch_lib.MoEConfig(d_model=d, d_ff=sc.d_ff, num_experts=N,
                                 top_k=sc.top_k, dtype=jnp.float32,
                                 wire_codec=_scenario_codec(sc))
    ep = dispatch_lib.EPSpec.from_axes(names, sc.axis_sizes, model_axis=None)
    gate_cfg = gating.GateConfig(num_experts=N, top_k=sc.top_k,
                                 aux_mode="lb")
    params = dispatch_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                          gate_cfg)
    kwargs = {}
    if sc.path in ("a2a", "a2a_pipelined"):
        kwargs["plan"] = make_dispatch_plan(
            tokens_per_device=T, num_experts=N, top_k=sc.top_k,
            capacity_factor=sc.capacity_factor, axis_sizes=sc.axis_sizes,
            mode="ta")
    if sc.path == "einsum":
        kwargs["capacity"] = T
    eng = dispatch_lib.make_engine(sc.path, cfg=cfg, ep=ep,
                                   gate_cfg=gate_cfg,
                                   num_chunks=sc.num_chunks,
                                   use_pallas=sc.use_pallas, **kwargs)

    mesh = AbstractMesh(tuple(zip(names, sc.axis_sizes)))
    if sc.path == "einsum":
        # the shard-local oracle: everything replicated, no mesh traffic
        pspecs, xspec = jax.tree.map(lambda _: P(), params), P()
    else:
        pspecs, xspec = moe_param_specs(cfg, ep), P(names)
    xg = jnp.zeros((T * ep_world, d), jnp.float32)
    fn = shard_map(lambda p, xx: eng(p, xx), mesh=mesh,
                   in_specs=(pspecs, xspec), out_specs=(xspec, P()),
                   check_vma=False)
    return jax.jit(fn).lower(params, xg).as_text()


_OP_RE = re.compile(r'"stablehlo\.(all_to_all|all_gather|all_reduce'
                    r'|reduce_scatter|collective_permute|collective_broadcast'
                    r')"')
_GROUPS_RE = re.compile(r"replica_groups = dense<(\[\[.*?\]\])>")
_TYPE_RE = re.compile(r"\}>\s*:\s*\(tensor<([^>]*)>")


def parse_collectives(text: str) -> list:
    """Collective signatures from a StableHLO dump.  Ops print one per
    line; region ops (all_reduce) keep their attributes on the first line
    but their type signature after the region, so dtype/elements stay
    ``None`` for them."""
    out = []
    for line in text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        groups = None
        gm = _GROUPS_RE.search(line)
        if gm:
            raw = ast.literal_eval(gm.group(1))
            groups = tuple(sorted(tuple(int(x) for x in g) for g in raw))
        dtype = elements = None
        tm = _TYPE_RE.search(line)
        if tm:
            parts = tm.group(1).split("x")
            dtype = parts[-1]
            elements = math.prod(int(p) for p in parts[:-1])
        out.append(Collective(m.group(1), dtype, elements, groups))
    return out


# ---------------------------------------------------------------------------
# matching
# ---------------------------------------------------------------------------


def _matches(exp: Collective, act: Collective) -> bool:
    if exp.kind != act.kind:
        return False
    return all(getattr(exp, f) is None or getattr(exp, f) == getattr(act, f)
               for f in ("dtype", "elements", "groups"))


def match_inventory(where: str, expected, actual) -> list:
    """Greedy multiset match; every miss in either direction is a
    violation (so stray collectives fail even when all expected ones are
    present)."""
    violations = []
    remaining = list(actual)
    for exp in expected:
        hit = next((a for a in remaining if _matches(exp, a)), None)
        if hit is None:
            violations.append(Violation(
                "hlo", "collective-inventory", where,
                f"missing expected collective: {exp.describe()}"))
        else:
            remaining.remove(hit)
    for act in remaining:
        violations.append(Violation(
            "hlo", "collective-inventory", where,
            f"unexpected collective in the lowering: {act.describe()}"))
    return violations


def verify(sc: Scenario, expected=None) -> list:
    """Lower one scenario and diff its collective inventory against the
    plan-derived expectation (``expected`` overrides it — fixtures use
    this to prove the check fires)."""
    if expected is None:
        expected = expected_inventory(sc)
    actual = parse_collectives(lower_scenario(sc))
    return match_inventory(sc.name, expected, actual)


def run(scenarios=None) -> tuple:
    if scenarios is None:
        scenarios = default_scenarios()
    violations, covered = [], []
    for sc in scenarios:
        covered.append(sc.name)
        violations.extend(verify(sc))
    return violations, covered
