"""Static contract checkers for the TA-MoE reproduction.

TA-MoE's premise is that the dispatch pattern must match the topology —
in this repo that means the *lowered* program must contain exactly the
collective chain the Eq. (7) ``DispatchPlan`` promises, the Pallas
kernels must honor the block-decomposition invariants their grids assume,
and the source must go through the blessed entry points.  Three checkers
enforce those contracts statically (no execution — CI runs them on a
single CPU):

* ``hlo_check``   — AOT-lowers the MoE step for every registered
  dispatch path × topology and verifies the collective inventory
  (op kinds, replica groups, payload shapes/dtypes) against the plan.
* ``pallas_check``— walks the kernel registry
  (``repro.kernels.backend.KERNEL_REGISTRY``) and checks VMEM
  footprints, index-map bounds, ``plan_blocks`` divisor invariants, and
  scatter-accumulation guards.
* ``lint``        — an AST pass over ``src/`` for repo rules (raw
  ``jax.shard_map``/``make_mesh`` outside ``repro/compat.py``, ``np.``
  calls inside traced functions, jitted closures over mutable config).

``python -m repro.analysis`` runs all three, emits a JSON report, and
exits nonzero on violations; ``--fixture NAME`` runs a planted-violation
fixture instead, proving the corresponding check fires (see
``repro.analysis.fixtures``).  Contract details in ``docs/analysis.md``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract breach: which checker, which rule, where, and what."""

    checker: str          # "hlo" | "pallas" | "lint"
    rule: str             # stable rule id, e.g. "collective-inventory"
    where: str            # scenario / kernel layout / file:line
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    """Aggregated checker results, serialized as the CI artifact."""

    violations: list[Violation] = dataclasses.field(default_factory=list)
    checked: dict[str, list[str]] = dataclasses.field(default_factory=dict)

    def extend(self, checker: str, items: list[Violation],
               covered: list[str]) -> None:
        self.violations.extend(items)
        self.checked.setdefault(checker, []).extend(covered)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "violations": [v.to_dict() for v in self.violations],
            "checked": self.checked,
        }
