"""Planted-violation fixtures: each proves one checker actually fires.

``python -m repro.analysis --fixture NAME`` runs one of these and exits
nonzero when the corresponding check reports the planted violation (the
CI lane and ``tests/test_analysis_checkers.py`` assert it does).  The
fixtures live in their own package that the HEAD-lint scan and the
kernel registry skip — they exist to be wrong.

* ``collective_mismatch`` — verifies a real (2×2, kernels-on) a2a
  lowering against an expectation with the counts chain dropped: the
  inventory diff must flag the count exchange as unexpected traffic.
* ``missing_scale_exchange`` — verifies a real int8-wire (2×2,
  kernels-on) lowering against an expectation with the f32 scale
  sideband dropped: the diff must flag the scale exchanges the scaled
  codec actually put on the wire.
* ``vmem_over_budget``    — a kernel layout whose blocks blow the VMEM
  budget.
* ``unguarded_scatter``   — the fused megakernel's scatter-revisit
  pattern (constant output index map, non-trailing grid dimension)
  *without* the accumulation guard.
* ``raw_shard_map``       — a source file calling ``jax.shard_map`` /
  ``jax.make_mesh`` outside ``repro/compat.py`` (plus the other two lint
  rules' patterns).
"""

from __future__ import annotations

import pathlib


def collective_mismatch():
    from repro.analysis import hlo_check

    sc = hlo_check.Scenario("fixture-collective-mismatch", (2, 2), "a2a",
                            True)
    tampered = [c for c in hlo_check.expected_inventory(sc)
                if c.dtype != "i32"]
    return hlo_check.verify(sc, expected=tampered)


def missing_scale_exchange():
    from repro.analysis import hlo_check

    sc = hlo_check.Scenario("fixture-missing-scale-exchange", (2, 2), "a2a",
                            True, wire_codec="int8")
    tampered = [c for c in hlo_check.expected_inventory(sc)
                if c.dtype != "f32"]
    return hlo_check.verify(sc, expected=tampered)


def vmem_over_budget():
    from repro.analysis import pallas_check
    from repro.kernels import backend

    def _x_map(i, j):
        return (i, 0)

    # a [4096, 4096] f32 block is 64 MiB before double buffering
    layout = backend.KernelLayout(
        kernel="fixture.vmem_over_budget",
        grid=(4, 2),
        blocks=(
            backend.BlockDecl("x", "in", 4, (4096, 4096), (16384, 4096),
                              _x_map),
            backend.BlockDecl("y", "out", 4, (4096, 4096), (16384, 4096),
                              _x_map),
        ),
    )
    violations, _ = pallas_check.run(layouts=[layout])
    return violations


def unguarded_scatter():
    from repro.analysis import pallas_check
    from repro.kernels import backend

    def _in_map(b, j):
        return (b, 0)

    def _out_map(b, j):
        # constant over the non-trailing b dimension — the fused
        # megakernel's scatter pattern, minus its accumulation guard
        return (0, 0)

    layout = backend.KernelLayout(
        kernel="fixture.unguarded_scatter",
        grid=(4, 2),
        blocks=(
            backend.BlockDecl("x", "in", 4, (8, 16), (32, 16), _in_map),
            backend.BlockDecl("o", "out", 4, (8, 16), (8, 16), _out_map,
                              acc_guarded=False),
        ),
    )
    violations, _ = pallas_check.run(layouts=[layout])
    return violations


def raw_shard_map():
    from repro.analysis import lint

    path = pathlib.Path(__file__).with_name("raw_shard_map_fixture.py")
    return lint.lint_source(path.read_text(), str(path),
                            "repro/analysis/fixtures/raw_shard_map_fixture.py")


FIXTURES = {
    "collective_mismatch": collective_mismatch,
    "missing_scale_exchange": missing_scale_exchange,
    "vmem_over_budget": vmem_over_budget,
    "unguarded_scatter": unguarded_scatter,
    "raw_shard_map": raw_shard_map,
}


def run_fixture(name: str):
    try:
        fn = FIXTURES[name]
    except KeyError:
        raise ValueError(f"unknown fixture {name!r}; "
                         f"available: {sorted(FIXTURES)}") from None
    return fn()
