"""Planted lint violations — every rule in ``repro.analysis.lint`` has a
specimen here.  This file is never imported; the fixture runner feeds its
*source* to the linter (and the HEAD scan skips the fixtures package)."""

from __future__ import annotations

import functools

import jax
import numpy as np

MUTABLE_CFG = {"num_layers": 4}


def bad_mesh_setup(devices):
    # raw-shard-map: both entry points must go through repro.compat
    mesh = jax.make_mesh((len(devices),), ("data",))
    return jax.shard_map(lambda x: x, mesh=mesh)


@functools.partial(jax.jit, static_argnums=0)
def bad_traced_fn(n, x):
    # np-in-traced: constant-folded at trace time
    scale = np.sqrt(n)
    # mutable-config-closure: retraces won't see later mutation
    return x * scale * MUTABLE_CFG["num_layers"]
