"""Deterministic synthetic LM data pipeline.

Produces sharded token batches without any external dataset (the container
is offline).  The stream is a reproducible mixture of Zipf-distributed
"vocabulary" draws with short Markov motifs so the LM loss is learnable
(structure exists) but not trivially memorizable.  Supports:

* train batches  {tokens, labels, loss_mask}
* frontend stubs (audio frames / vision patches) keyed off the arch config
* host-sharded iteration: each JAX process materializes only its shard
  (here there is one process; the API mirrors multi-host usage)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8


class SyntheticLM:
    """Deterministic, restartable synthetic token stream."""

    def __init__(self, cfg: DataConfig, arch: ArchConfig | None = None):
        self.cfg = cfg
        self.arch = arch
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # motif table: each token deterministically suggests a follower, so
        # p(next|cur) has learnable structure
        self._next = rng.integers(0, v, size=(v,), dtype=np.int64)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self._probs = p / p.sum()

    def batch(self, step: int) -> dict:
        """Batch for a given step (stateless — random access by step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        base = rng.choice(v, size=(B, S + 1), p=self._probs)
        # with prob .5 follow the motif instead of fresh draw
        follow = rng.random((B, S)) < 0.5
        toks = base.copy()
        for t in range(1, S + 1):
            toks[:, t] = np.where(follow[:, t - 1],
                                  self._next[toks[:, t - 1]], base[:, t])
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
               "loss_mask": jnp.ones((B, S), jnp.float32)}
        if self.arch is not None and self.arch.frontend:
            if self.arch.frontend == "vision":
                from repro.models import vlm
                out["frontend"] = vlm.make_patches(rng, B, self.arch)
                F = self.arch.frontend_len
                out["loss_mask"] = out["loss_mask"].at[:, :F].set(0.0)
            else:
                from repro.models import whisper
                out["frontend"] = whisper.make_frames(rng, B, self.arch)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def shard_batch(batch: dict, mesh, batch_axes=("pod", "data")):
    """Place a host batch on the mesh, sharded over the batch axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    spec_b = P(axes if len(axes) > 1 else (axes[0] if axes else None))

    def put(x):
        spec = P(*(spec_b + P(*([None] * (x.ndim - 1)))))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, batch)
