"""Training loop: step factory (fwd+bwd+AdamW, optional grad accumulation),
metric aggregation, checkpoint hooks.  The jitted step is the unit the
multi-pod dry-run lowers.

Two step factories: :func:`make_train_step` is the classic unguarded step;
:func:`make_guarded_train_step` adds the resilience runtime's in-step
health check (one fused non-finite tree-reduce over loss + grads) and an
in-jit skip — on a non-finite verdict the params/opt update is suppressed
with a select, so the host never sees a poisoned tree.  With no fault
firing the guarded step is bit-identical to the unguarded one (fault
multipliers of 1.0 are exact; the healthy select branch is bitwise).
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig, RunConfig
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLM, shard_batch
from repro.models import model as model_lib, transformer
from repro.optim import adamw


def make_train_step(ctx: transformer.ModelCtx, run: RunConfig,
                    opt_cfg: adamw.AdamWConfig | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Pure function of its inputs — jit it (optionally with shardings).
    """
    if opt_cfg is None:
        opt_cfg = adamw.AdamWConfig(
            learning_rate=run.learning_rate, warmup_steps=run.warmup_steps,
            total_steps=run.total_steps, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip)

    def loss(params, batch):
        return transformer.loss_fn(params, batch, ctx,
                                   aux_weight=run.aux_weight)

    def step(params, opt_state, batch):
        rules = model_lib.default_rules(ctx.mesh) if ctx.mesh else None
        ctxm = sharding.axis_rules(rules) if rules else _null()
        with ctxm:
            if run.microbatch and run.microbatch < batch["tokens"].shape[0]:
                params_new, opt_state, metrics = _accum_step(
                    params, opt_state, batch, loss, opt_cfg, run.microbatch)
                return params_new, opt_state, metrics
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
            params, opt_state, opt_metrics = adamw.apply_updates(
                params, grads, opt_state, opt_cfg)
            metrics = dict(metrics, **opt_metrics)
            return params, opt_state, metrics

    return step


def make_guarded_train_step(ctx: transformer.ModelCtx, run: RunConfig,
                            opt_cfg: adamw.AdamWConfig | None = None):
    """Guarded step: step(params, opt_state, batch, fault) -> (p, o, m).

    ``fault`` is the chaos injection channel — ``{"loss_mult",
    "grad_mult"}`` scalars (traced arguments, so no recompile per step;
    pass 1.0 when nothing fires).  Both ride the *differentiated* total:
    the chain rule delivers them to every grad leaf with zero per-leaf
    work, and reported metrics stay raw.  The loss-spike fault
    (``param_scale``) is applied by the host loop *between* steps on its
    scheduled step only — injected post-update so the global-norm clip
    can't neutralize it, and off the jitted path so the healthy step
    never pays for it.

    The guard itself is free by construction: the non-finite verdict
    reuses the optimizer's global-norm reduce (``sqrt(sum g^2)`` is NaN
    or inf exactly when any grad element is — the same single fused
    tree-reduce ``guards.nonfinite_score`` spells out standalone), and
    the skip *action* costs nothing in-step: the step always returns the
    updated trees plus ``metrics["nonfinite"]``, and on a bad verdict
    the host loop simply keeps its still-live references to the previous
    params/opt instead of assigning the poisoned ones (nothing is
    donated, so the old trees are intact on device).  In-jit ``where``
    selects / ``lax.cond`` branches over the trees were measured at
    8-15% of step time — the whole guard must stay under 5%
    (``benchmarks/dispatch_sweep.py`` gates it), so every tree-sized
    action lives on the host where it is a pointer swap.
    """
    if opt_cfg is None:
        opt_cfg = adamw.AdamWConfig(
            learning_rate=run.learning_rate, warmup_steps=run.warmup_steps,
            total_steps=run.total_steps, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip)
    def loss(params, batch):
        return transformer.loss_fn(params, batch, ctx,
                                   aux_weight=run.aux_weight)

    def step(params, opt_state, batch, fault):
        rules = model_lib.default_rules(ctx.mesh) if ctx.mesh else None
        ctxm = sharding.axis_rules(rules) if rules else _null()
        with ctxm:
            def scaled(p, b):
                total, metrics = loss(p, b)
                # both multipliers via the chain rule: d(c*L)/dp = c*dL/dp,
                # so grads are scaled without a per-leaf pass (1.0 * 1.0
                # is exact, keeping the healthy path bitwise)
                return total * (fault["loss_mult"] * fault["grad_mult"]), \
                    metrics

            if run.microbatch and run.microbatch < batch["tokens"].shape[0]:
                grads, metrics = _accum_grads(params, batch, scaled,
                                              run.microbatch)
            else:
                (_, metrics), grads = jax.value_and_grad(
                    scaled, has_aux=True)(params, batch)
            new_p, new_o, opt_metrics = adamw.apply_updates(
                params, grads, opt_state, opt_cfg)
            # the optimizer's clipping reduce doubles as the health check:
            # sqrt(sum g^2) is non-finite iff any grad element is
            ok = jnp.logical_and(jnp.isfinite(metrics["loss"]),
                                 jnp.isfinite(opt_metrics["grad_norm"]))
            metrics = dict(metrics, **opt_metrics)
            metrics["nonfinite"] = 1.0 - ok.astype(jnp.float32)
            return new_p, new_o, metrics

    return step


def _null():
    import contextlib
    return contextlib.nullcontext()


def _accum_grads(params, batch, loss, micro: int):
    """Microbatched grad accumulation: returns (mean grads, mean metrics)."""
    B = batch["tokens"].shape[0]
    n = B // micro
    split = jax.tree_util.tree_map(
        lambda x: x.reshape((n, micro) + x.shape[1:]), batch)

    def body(carry, mb):
        gsum, msum = carry
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, mb)
        gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
        msum = jax.tree_util.tree_map(jnp.add, msum, metrics)
        return (gsum, msum), None

    zeros_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    mb0 = jax.tree_util.tree_map(lambda x: x[0], split)
    m_shapes = jax.eval_shape(lambda p, mb: loss(p, mb)[1], params, mb0)
    zeros_m = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), m_shapes)
    (gsum, msum), _ = jax.lax.scan(body, (zeros_g, zeros_m), split)
    grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
    metrics = jax.tree_util.tree_map(lambda m: m / n, msum)
    return grads, metrics


def _accum_step(params, opt_state, batch, loss, opt_cfg, micro: int):
    grads, metrics = _accum_grads(params, batch, loss, micro)
    params, opt_state, opt_metrics = adamw.apply_updates(
        params, grads, opt_state, opt_cfg)
    return params, opt_state, dict(metrics, **opt_metrics)


@dataclasses.dataclass
class TrainResult:
    losses: list
    metrics_history: list
    steps_per_sec: float
    params: object
    opt_state: object
    # resilience accounting (0 on unguarded runs) — the same counters ride
    # every logged metrics_history entry
    skipped_steps: int = 0
    rollbacks: int = 0
    replans: int = 0


def _rolling_path(ckpt_path: str, step: int) -> str:
    base, ext = os.path.splitext(ckpt_path)
    return f"{base}-{step:06d}{ext or '.npz'}"


def _prune_rolling(rolling: list, keep: int) -> None:
    while len(rolling) > keep:
        _, path = rolling.pop(0)
        for p in (path, path + ".meta.json"):
            if os.path.exists(p):
                os.unlink(p)


def _restore_last_good(rolling: list, template):
    """Walk rolling checkpoints newest-first; restore the first one whose
    sha256 manifest verifies (a corrupt newest falls back to the previous
    — the integrity-hash contract of checkpoint/ckpt.py)."""
    for step, path in reversed(rolling):
        if ckpt.verify(path):
            return step, ckpt.restore(path, template)
    raise RuntimeError(
        "rollback requested but no rolling checkpoint passes integrity "
        "verification")


def train(arch: ArchConfig, run: RunConfig, mesh, *, steps: int,
          aux_mode: str | None = None, log_every: int = 10,
          ckpt_path: str | None = None, ckpt_every: int = 0,
          ckpt_keep: int = 3, eval_fn=None,
          data_seed: int | None = None, verbose: bool = True
          ) -> TrainResult:
    """End-to-end training driver (used by examples + benchmarks).

    When ``run.topology`` carries a nested spec, the mesh's hierarchy axes
    must match it — the level-indexed dispatch plan is derived from the
    mesh, so a mismatched spec would silently train under the wrong
    per-level capacities.

    ``ckpt_every > 0`` writes rolling checkpoints (``<base>-<step>.npz``,
    newest ``ckpt_keep`` kept) with sha256 manifests; they are the
    rollback target of the resilience policy.  ``run.resilience`` (a
    ``repro.resilience.ResilienceConfig``) switches the loop onto the
    guarded step: in-jit skip on non-finite grads, rollback on sustained
    loss spike, and the degraded-topology replan at ``replan_every``
    boundaries (plans are static per compilation, so a replan re-jits).
    """
    aux_mode = aux_mode or run.aux_mode
    want = run.mesh_axis_sizes()
    if want:
        got = tuple(mesh.shape[a] for a in sharding.hierarchy_axes(mesh))
        if got != want:
            raise ValueError(
                f"RunConfig.topology {run.topology!r} implies hierarchy "
                f"sizes {want} but the mesh has {got}; build the mesh with "
                f"repro.launch.mesh.mesh_from_topology(run.topology)")
    ctx = model_lib.build_ctx(arch, mesh, seq_len=run.seq_len,
                              global_batch=run.global_batch,
                              aux_mode=aux_mode, remat=run.remat,
                              dispatch=run.dispatch,
                              a2a_num_chunks=run.a2a_num_chunks,
                              dispatch_override=run.dispatch_override,
                              use_pallas=run.use_pallas,
                              wire_codec=run.wire_codec,
                              resilience=run.resilience)
    res = run.resilience
    guarded = res is not None
    policy = None
    chaos = None
    if guarded:
        from repro.resilience import chaos as chaos_lib
        from repro.resilience.policy import RecoveryPolicy
        policy = RecoveryPolicy(res)
        chaos = res.chaos
        if res.rollback_on_spike and not (ckpt_path and ckpt_every > 0):
            raise ValueError(
                "ResilienceConfig.rollback_on_spike needs ckpt_path and "
                "ckpt_every > 0 — rolling checkpoints are the rollback "
                "target")
    rules = model_lib.default_rules(mesh)
    key = jax.random.PRNGKey(run.seed)
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx, rules=rules)
        opt_state = adamw.init_state(params)

        def make_fn(c):
            return jax.jit(make_guarded_train_step(c, run) if guarded
                           else make_train_step(c, run))
        step_fn = make_fn(ctx)
        data = SyntheticLM(DataConfig(vocab_size=arch.vocab_size,
                                      seq_len=run.seq_len,
                                      global_batch=run.global_batch,
                                      seed=data_seed if data_seed is not None
                                      else run.seed), arch)
        losses, history = [], []
        rolling = []                     # [(step, path)] oldest-first
        t0 = time.time()
        for i in range(steps):
            # degraded-topology fallback: probe at epoch boundaries only
            # (a plan change means a re-jit, so it must land between jits)
            if (guarded and res.replan_every and i > 0
                    and i % res.replan_every == 0 and ctx.plan is not None):
                slow = policy.observe_links(mesh, ctx.ep.axis_names, i)
                new_ctx = policy.replan(ctx, slow)
                if new_ctx is not None:
                    ctx = new_ctx
                    step_fn = make_fn(ctx)
                    if verbose:
                        print(f"step {i:5d} replan: caps -> "
                              f"{ctx.plan.caps}")
            if chaos is not None:
                chaos_lib.maybe_straggle(chaos, i)
            batch = shard_batch(data.batch(i), mesh)
            if guarded:
                scales = chaos_lib.fault_scales(chaos, i)
                fault = {k: jnp.float32(scales[k])
                         for k in ("loss_mult", "grad_mult")}
                new_p, new_o, metrics = step_fn(params, opt_state, batch,
                                                fault)
                if scales["param_scale"] != 1.0:
                    # loss-spike fault: wreck the updated params between
                    # steps (host-gated, so the healthy path never traces
                    # or pays for it)
                    ps = jnp.float32(scales["param_scale"])
                    new_p = jax.tree_util.tree_map(
                        lambda p: (p * ps).astype(p.dtype), new_p)
                verdict = {
                    "nonfinite": float(metrics["nonfinite"]),
                    "loss": float(metrics["loss"]),
                    "dropped": (float(metrics["dropped"])
                                if "dropped" in metrics else None)}
                action = policy.classify(i, verdict)
                if action == "rollback":
                    template = {"params": params, "opt": opt_state}
                    at, good = _restore_last_good(rolling, template)
                    params, opt_state = good["params"], good["opt"]
                    policy.on_rollback()
                    if verbose:
                        print(f"step {i:5d} rollback -> checkpoint of "
                              f"step {at}")
                elif action == "skip":
                    # the poisoned trees are simply never assigned — the
                    # previous params/opt are still live on device (nothing
                    # is donated), so the skip is a host pointer swap
                    pass
                else:
                    params, opt_state = new_p, new_o
            else:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            if i % log_every == 0 or i == steps - 1:
                # scalar metrics become floats; vector metrics (e.g. the
                # level-indexed frac_by_level) become lists
                m = {k: (float(v) if getattr(v, "ndim", 0) == 0
                         else [float(x) for x in v])
                     for k, v in metrics.items()}
                m.update(policy.counters() if policy is not None else
                         {"skipped_steps": 0, "rollbacks": 0, "replans": 0,
                          "drop_alarms": 0})
                losses.append(m["loss"])
                history.append(m)
                if verbose:
                    fb = m.get("frac_by_level")
                    extra = (" frac_by_level=[" +
                             ",".join(f"{x:.2f}" for x in fb) + "]"
                             if fb else "")
                    print(f"step {i:5d} loss {m['loss']:.4f} "
                          f"nll {m['nll']:.4f} aux {m.get('aux', 0):.4f}"
                          f"{extra}")
            if (ckpt_path and ckpt_every > 0 and (i + 1) % ckpt_every == 0
                    and (policy is None or policy.healthy)):
                rp = _rolling_path(ckpt_path, i)
                ckpt.save(rp, {"params": params, "opt": opt_state}, step=i)
                rolling.append((i, rp))
                _prune_rolling(rolling, ckpt_keep)
                if chaos is not None and chaos_lib.should_corrupt(chaos, i):
                    chaos_lib.corrupt_checkpoint(rp, chaos.seed)
        dt = time.time() - t0
        if ckpt_path:
            ckpt.save(ckpt_path, {"params": params, "opt": opt_state},
                      step=steps)
    counters = policy.counters() if policy is not None else {}
    return TrainResult(losses=losses, metrics_history=history,
                       steps_per_sec=steps / max(dt, 1e-9),
                       params=params, opt_state=opt_state,
                       skipped_steps=counters.get("skipped_steps", 0),
                       rollbacks=counters.get("rollbacks", 0),
                       replans=counters.get("replans", 0))
