"""Training loop: step factory (fwd+bwd+AdamW, optional grad accumulation),
metric aggregation, checkpoint hooks.  The jitted step is the unit the
multi-pod dry-run lowers."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig, RunConfig
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLM, shard_batch
from repro.models import model as model_lib, transformer
from repro.optim import adamw


def make_train_step(ctx: transformer.ModelCtx, run: RunConfig,
                    opt_cfg: adamw.AdamWConfig | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Pure function of its inputs — jit it (optionally with shardings).
    """
    if opt_cfg is None:
        opt_cfg = adamw.AdamWConfig(
            learning_rate=run.learning_rate, warmup_steps=run.warmup_steps,
            total_steps=run.total_steps, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip)

    def loss(params, batch):
        return transformer.loss_fn(params, batch, ctx,
                                   aux_weight=run.aux_weight)

    def step(params, opt_state, batch):
        rules = model_lib.default_rules(ctx.mesh) if ctx.mesh else None
        ctxm = sharding.axis_rules(rules) if rules else _null()
        with ctxm:
            if run.microbatch and run.microbatch < batch["tokens"].shape[0]:
                params_new, opt_state, metrics = _accum_step(
                    params, opt_state, batch, loss, opt_cfg, run.microbatch)
                return params_new, opt_state, metrics
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
            params, opt_state, opt_metrics = adamw.apply_updates(
                params, grads, opt_state, opt_cfg)
            metrics = dict(metrics, **opt_metrics)
            return params, opt_state, metrics

    return step


def _null():
    import contextlib
    return contextlib.nullcontext()


def _accum_step(params, opt_state, batch, loss, opt_cfg, micro: int):
    B = batch["tokens"].shape[0]
    n = B // micro
    split = jax.tree_util.tree_map(
        lambda x: x.reshape((n, micro) + x.shape[1:]), batch)

    def body(carry, mb):
        gsum, msum = carry
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, mb)
        gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
        msum = jax.tree_util.tree_map(jnp.add, msum, metrics)
        return (gsum, msum), None

    zeros_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    mb0 = jax.tree_util.tree_map(lambda x: x[0], split)
    m_shapes = jax.eval_shape(lambda p, mb: loss(p, mb)[1], params, mb0)
    zeros_m = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), m_shapes)
    (gsum, msum), _ = jax.lax.scan(body, (zeros_g, zeros_m), split)
    grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
    metrics = jax.tree_util.tree_map(lambda m: m / n, msum)
    params, opt_state, opt_metrics = adamw.apply_updates(
        params, grads, opt_state, opt_cfg)
    return params, opt_state, dict(metrics, **opt_metrics)


@dataclasses.dataclass
class TrainResult:
    losses: list
    metrics_history: list
    steps_per_sec: float
    params: object
    opt_state: object


def train(arch: ArchConfig, run: RunConfig, mesh, *, steps: int,
          aux_mode: str | None = None, log_every: int = 10,
          ckpt_path: str | None = None, eval_fn=None,
          data_seed: int | None = None, verbose: bool = True
          ) -> TrainResult:
    """End-to-end training driver (used by examples + benchmarks).

    When ``run.topology`` carries a nested spec, the mesh's hierarchy axes
    must match it — the level-indexed dispatch plan is derived from the
    mesh, so a mismatched spec would silently train under the wrong
    per-level capacities.
    """
    aux_mode = aux_mode or run.aux_mode
    want = run.mesh_axis_sizes()
    if want:
        got = tuple(mesh.shape[a] for a in sharding.hierarchy_axes(mesh))
        if got != want:
            raise ValueError(
                f"RunConfig.topology {run.topology!r} implies hierarchy "
                f"sizes {want} but the mesh has {got}; build the mesh with "
                f"repro.launch.mesh.mesh_from_topology(run.topology)")
    ctx = model_lib.build_ctx(arch, mesh, seq_len=run.seq_len,
                              global_batch=run.global_batch,
                              aux_mode=aux_mode, remat=run.remat,
                              dispatch=run.dispatch,
                              a2a_num_chunks=run.a2a_num_chunks,
                              dispatch_override=run.dispatch_override,
                              use_pallas=run.use_pallas,
                              wire_codec=run.wire_codec)
    rules = model_lib.default_rules(mesh)
    key = jax.random.PRNGKey(run.seed)
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx, rules=rules)
        opt_state = adamw.init_state(params)
        step_fn = jax.jit(make_train_step(ctx, run))
        data = SyntheticLM(DataConfig(vocab_size=arch.vocab_size,
                                      seq_len=run.seq_len,
                                      global_batch=run.global_batch,
                                      seed=data_seed if data_seed is not None
                                      else run.seed), arch)
        losses, history = [], []
        t0 = time.time()
        for i in range(steps):
            batch = shard_batch(data.batch(i), mesh)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if i % log_every == 0 or i == steps - 1:
                # scalar metrics become floats; vector metrics (e.g. the
                # level-indexed frac_by_level) become lists
                m = {k: (float(v) if getattr(v, "ndim", 0) == 0
                         else [float(x) for x in v])
                     for k, v in metrics.items()}
                losses.append(m["loss"])
                history.append(m)
                if verbose:
                    fb = m.get("frac_by_level")
                    extra = (" frac_by_level=[" +
                             ",".join(f"{x:.2f}" for x in fb) + "]"
                             if fb else "")
                    print(f"step {i:5d} loss {m['loss']:.4f} "
                          f"nll {m['nll']:.4f} aux {m.get('aux', 0):.4f}"
                          f"{extra}")
        dt = time.time() - t0
        if ckpt_path:
            ckpt.save(ckpt_path, {"params": params, "opt": opt_state},
                      step=steps)
    return TrainResult(losses=losses, metrics_history=history,
                       steps_per_sec=steps / max(dt, 1e-9),
                       params=params, opt_state=opt_state)
