"""Render EXPERIMENTS.md tables from dry-run JSONL records."""

import json
import sys
from collections import OrderedDict


def load(path):
    recs = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"],
                   r.get("optimized", False))
            recs[key] = r
    return recs


def gib(x):
    return f"{x/2**30:.2f}"


def ms(x):
    return f"{x*1e3:.2f}"


def roofline_table(recs, mesh="pod1", optimized=False):
    print(f"\n### Roofline — {mesh}"
          + (" (optimized)" if optimized else " (baseline)"))
    print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
          "MODEL_FLOPs/HLO | mem/dev GiB |")
    print("|---|---|---:|---:|---:|---|---:|---:|")
    for (a, s, m, o), r in recs.items():
        if m != mesh or o != optimized:
            continue
        if r["status"] == "skipped":
            print(f"| {a} | {s} | — | — | — | skipped | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {a} | {s} | — | — | — | FAIL | — | — |")
            continue
        print(f"| {a} | {s} | {ms(r['t_compute'])} | {ms(r['t_memory'])} | "
              f"{ms(r['t_collective'])} | {r['dominant']} | "
              f"{r['useful_ratio']:.3f} | {gib(r['bytes_per_device'])} |")


def dryrun_table(recs, mesh="pod2", optimized=False):
    print(f"\n### Dry-run — {mesh}")
    print("| arch | shape | params | bytes/dev GiB | GFLOP/chip | "
          "ICI MB/chip | DCI MB/chip | compile s |")
    print("|---|---|---:|---:|---:|---:|---:|---:|")
    for (a, s, m, o), r in recs.items():
        if m != mesh or o != optimized:
            continue
        if r["status"] != "ok":
            print(f"| {a} | {s} | — | — | — | — | — | "
                  f"{r.get('note', r.get('error', ''))[:40]} |")
            continue
        print(f"| {a} | {s} | {r['n_params']/1e9:.2f}B | "
              f"{gib(r['bytes_per_device'])} | "
              f"{r['flops_per_chip']/1e9:.1f} | "
              f"{r['ici_bytes_per_chip']/1e6:.1f} | "
              f"{r['dci_bytes_per_chip']/1e6:.1f} | "
              f"{r['t_compile_s']} |")


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1
                else "results/dryrun_baseline.jsonl")
    which = sys.argv[2] if len(sys.argv) > 2 else "all"
    opt = len(sys.argv) > 3 and sys.argv[3] == "opt"
    if which in ("all", "roofline"):
        roofline_table(recs, "pod1", opt)
    if which in ("all", "dryrun"):
        dryrun_table(recs, "pod1", opt)
        dryrun_table(recs, "pod2", opt)
