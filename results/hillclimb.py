"""§Perf hillclimb driver: for each of the three chosen pairs, lower the
even baseline, the paper-faithful TA configuration, and each beyond-paper
iteration, recording the roofline terms per run (EXPERIMENTS.md §Perf).

Run AFTER the baseline matrix:
    PYTHONPATH=src python results/hillclimb.py [pairA|pairB|pairC ...]
"""

import json
import sys

sys.path.insert(0, "src")

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)

OUT = "results/hillclimb.jsonl"

# (name, arch, shape, multi_pod, runs)
# each run: (tag, aux_mode, ctx_overrides)
PAIRS = {
    # worst roofline fraction: t_mem ~6.6x t_comp, 258 GiB/dev
    "pairA": ("jamba_v0_1_52b", "train_4k", False, [
        ("even-baseline", "lb", {}),
        # ta-paper row comes from the baseline matrix
        ("it1-blockwise-attn", "ta", {"use_blockwise": True}),
        ("it2-chunked-mamba-scan", "ta", {"use_blockwise": True,
                                          "mamba_scan_chunk": 512}),
        ("it3-fused-xent", "ta", {"use_blockwise": True,
                                  "mamba_scan_chunk": 512,
                                  "fused_xent": True}),
        ("it4-chunk128", "ta", {"use_blockwise": True,
                                "mamba_scan_chunk": 128,
                                "fused_xent": True}),
    ]),
    # most collective-bound: 41.5 s t_coll on pod1
    "pairB": ("deepseek_v2_236b", "prefill_32k", False, [
        ("even-baseline", "lb", {}),
        ("it1-blockwise-mla", "ta", {"use_blockwise": True}),
        ("it2-cf1.0", "ta", {"use_blockwise": True,
                             "capacity_factor": 1.0}),
        ("it3-f8-a2a", "ta", {"use_blockwise": True,
                              "capacity_factor": 1.0,
                              "a2a_dtype": "float8_e4m3fn"}),
    ]),
    # most representative of the paper: pod-spanning MoE, TA vs even on DCI
    "pairC": ("deepseek_v2_236b", "train_4k", True, [
        ("even-baseline", "lb", {}),
        ("ta-paper", "ta", {}),       # explicit for the A/B comparison
        ("it1-f8-a2a", "ta", {"a2a_dtype": "float8_e4m3fn"}),
        ("it2-blockwise+fused", "ta", {"a2a_dtype": "float8_e4m3fn",
                                       "use_blockwise": True,
                                       "fused_xent": True}),
    ]),
}


def main():
    names = sys.argv[1:] or list(PAIRS)
    for name in names:
        arch, shape, multi, runs = PAIRS[name]
        for tag, aux, overrides in runs:
            try:
                rec, _ = dryrun.lower_one(arch, shape, multi, aux_mode=aux,
                                          ctx_overrides=overrides or None,
                                          tag=f"{name}:{tag}")
                print(f"[{name}:{tag}] dom={rec['dominant']} "
                      f"tC={rec['t_compute']*1e3:.1f} "
                      f"tM={rec['t_memory']*1e3:.1f} "
                      f"tX={rec['t_collective']*1e3:.1f} ms "
                      f"mem={rec['bytes_per_device']/2**30:.1f}GiB "
                      f"DCI={rec['dci_bytes_per_chip']/1e6:.0f}MB",
                      flush=True)
            except Exception as e:
                import traceback
                traceback.print_exc(limit=4)
                rec = {"tag": f"{name}:{tag}", "status": "fail",
                       "error": str(e)[:300]}
            with open(OUT, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
