"""Docs freshness, in-repo: the same check CI's lint lane runs, plus a
negative case proving the checker still catches stale references."""

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

import check_docs  # noqa: E402


def test_repo_docs_are_fresh():
    missing, problems = check_docs.check(REPO)
    assert not missing, f"docs missing: {missing}"
    assert not problems, f"stale doc references: {problems}"


def test_checker_catches_stale_refs(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "README.md").write_text(
        "see `src/repro/gone.py`, `--no-such-flag`, `repro.nope.mod`\n")
    missing, problems = check_docs.check(str(tmp_path), ("README.md",))
    assert not missing
    assert sorted(k for _, k, _ in problems) == ["flag", "module", "path"]


def test_checker_reports_missing_doc(tmp_path):
    missing, problems = check_docs.check(str(tmp_path), ("nope.md",))
    assert missing == ["nope.md"] and not problems
