"""Unit + property tests for the TA-MoE topology core (paper §4)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import topology as T
from repro.core import capacity as C
from repro.core import comm_model as CM
from repro.core.gating import ta_penalties


def _sym_model(spec, betas=None):
    topo = T.TreeTopology(spec)
    L = topo.num_levels
    if betas is None:
        betas = tuple(1.0 / (100e9 / (10 ** l)) for l in range(L))
    alphas = tuple(1e-6 * l for l in range(L))
    return T.CommModel(topo=topo, alpha=alphas, beta=betas)


class TestTreeTopology:
    def test_levels_flat(self):
        topo = T.TreeTopology(4)
        assert topo.num_devices == 4
        assert topo.num_levels == 2
        assert topo.level(0, 0) == 0
        assert topo.level(0, 3) == 1

    def test_levels_two_tier(self):
        topo = T.TreeTopology((2, 2))
        lm = topo.level_matrix()
        assert lm[0, 1] == 1 and lm[0, 2] == 2 and lm[2, 3] == 1
        assert topo.is_symmetric()

    def test_levels_three_tier(self):
        topo = T.TreeTopology(((2, 2), (2, 2)))
        assert topo.num_levels == 4
        assert topo.level(0, 1) == 1
        assert topo.level(0, 2) == 2
        assert topo.level(0, 4) == 3

    def test_asymmetric_detected_and_merged(self):
        topo = T.TreeTopology(((2, 2), (2,)))
        assert not topo.is_symmetric()
        merged = T.symmetrize(topo)
        assert merged.is_symmetric()
        assert merged.num_devices == topo.num_devices  # no device lost
        assert merged.spec == (2, 2, 2)                # paper's example

    def test_level_sizes(self):
        topo = T.TreeTopology((2, 2))
        assert list(topo.level_sizes(0)) == [1, 1, 2]


class TestEq7:
    def test_row_and_col_sums(self):
        m = _sym_model((2, 2))
        c = T.target_dispatch(m, tokens_sent=1024.0)
        np.testing.assert_allclose(c.sum(1), 1024.0, rtol=1e-9)
        np.testing.assert_allclose(c.sum(0), 1024.0, rtol=1e-9)

    def test_bandwidth_proportionality(self):
        # Eq 7: chunk size linear in link bandwidth
        m = _sym_model((2, 2), betas=(1 / 800e9, 1 / 200e9, 1 / 12.5e9))
        c = T.target_dispatch(m, tokens_sent=1000.0)
        assert c[0, 1] / c[0, 2] == pytest.approx(200 / 12.5, rel=1e-6)

    def test_homogeneous_reduces_to_even(self):
        m = _sym_model(4, betas=(1 / 100e9, 1 / 100e9))
        c = T.target_dispatch(m, tokens_sent=400.0)
        np.testing.assert_allclose(c, 100.0, rtol=1e-9)

    def test_asymmetric_goes_through_merge(self):
        topo = T.TreeTopology(((2, 2), (2,)))
        m = T.CommModel(topo=topo, alpha=(0, 1e-6, 1e-5, 1e-5),
                        beta=(1 / 800e9, 1 / 200e9, 1 / 12.5e9, 1 / 12.5e9))
        c = T.target_dispatch(m, tokens_sent=600.0)
        assert c.shape == (6, 6)
        np.testing.assert_allclose(c.sum(1), 600.0, rtol=1e-9)

    @given(n_nodes=st.integers(2, 6), node_size=st.integers(1, 6),
           b1=st.floats(10, 1000), b2=st.floats(1, 9))
    @settings(max_examples=40, deadline=None)
    def test_property_constraints_hold(self, n_nodes, node_size, b1, b2):
        """Eq 3/4 constraints hold for arbitrary 2-tier symmetric trees."""
        spec = tuple([node_size] * n_nodes)
        topo = T.TreeTopology(spec)
        m = T.CommModel(topo=topo, alpha=(0.0, 1e-6, 1e-5),
                        beta=(1 / (b1 * 2e9), 1 / (b1 * 1e9), 1 / (b2 * 1e9)))
        c = T.target_dispatch(m, tokens_sent=512.0)
        assert (c > 0).all()
        np.testing.assert_allclose(c.sum(1), 512.0, rtol=1e-6)
        np.testing.assert_allclose(c.sum(0), 512.0, rtol=1e-6)
        # faster links never get smaller chunks
        lm = topo.level_matrix()
        near = c[0][lm[0] == 1].mean() if (lm[0] == 1).any() else None
        far = c[0][lm[0] == 2].mean()
        if near is not None:
            assert near >= far


class TestEq5Smoothing:
    def test_smoothing_recovers_level_constants(self):
        topo = T.TreeTopology((2, 2))
        lm = topo.level_matrix()
        rng = np.random.default_rng(0)
        beta_true = np.array([1e-12, 5e-12, 80e-12])
        noise = rng.normal(1.0, 0.05, lm.shape)
        beta_ij = beta_true[lm] * noise
        alpha_ij = np.full(lm.shape, 1e-6)
        m = T.smooth_profile(topo, alpha_ij, beta_ij)
        assert m.beta[1] == pytest.approx(5e-12, rel=0.2)
        assert m.beta[2] == pytest.approx(80e-12, rel=0.2)


class TestRatiosAndPenalties:
    def test_ratio_conservation(self):
        m = T.tpu_topology(2, 16)
        r = T.per_level_ratios(m)
        n = m.topo.level_sizes(0)
        assert float((r * n).sum()) == pytest.approx(m.topo.num_devices)

    def test_single_pod_is_even(self):
        m = T.tpu_topology(1, 16)
        r = T.per_level_ratios(m)
        np.testing.assert_allclose(r, 1.0)

    def test_penalties_mean_one_weighted(self):
        m = T.tpu_topology(2, 16)
        r = T.per_level_ratios(m)
        sizes = tuple(int(x) for x in m.topo.level_sizes(0))
        p = ta_penalties(tuple(r), level_sizes=sizes)
        mean = sum(pi * si for pi, si in zip(p, sizes)) / sum(sizes)
        assert mean == pytest.approx(1.0, rel=1e-6)
        assert p[2] > p[1]  # slow level penalized harder


class TestCapacityPlan:
    def test_even_plan(self):
        p = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                        mode="even")
        assert p.cap_near == p.cap_far
        assert p.experts_per_rank == 2

    def test_ta_plan_ratio_matches_beta(self):
        p = C.make_plan(tokens_per_device=65536, num_experts=160, top_k=6,
                        capacity_factor=1.2, num_pods=2, ep_per_pod=16,
                        mode="ta", round_multiple=1)
        assert p.cap_near / p.cap_far == pytest.approx(
            T.ICI_BW / T.DCI_BW, rel=0.02)

    def test_ta_single_pod_equals_even(self):
        pa = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                         capacity_factor=1.0, num_pods=1, ep_per_pod=16,
                         mode="ta")
        pe = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                         capacity_factor=1.0, num_pods=1, ep_per_pod=16,
                         mode="even")
        assert pa.cap_near == pe.cap_near

    def test_hir_plan_enforces_ratio(self):
        p = C.make_plan(tokens_per_device=8192, num_experts=32, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                        mode="hir", hir_ratio=4.0, round_multiple=1)
        assert p.cap_near / p.cap_far == pytest.approx(4.0, rel=0.05)

    def test_bytes_accounting(self):
        p = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                        mode="ta")
        b = C.a2a_bytes(p, d_model=128, bytes_per_el=2, num_pods=2,
                        ep_per_pod=4)
        assert b["near_bytes"] == p.cap_near * p.experts_per_rank * 3 * 128 * 2
        assert b["far_bytes"] == p.cap_far * p.experts_per_rank * 4 * 128 * 2


class TestCommModelSim:
    """Paper §3.3 motivation: uneven dispatch beats even on slow links."""

    def test_uneven_beats_even_on_tree(self):
        m = _sym_model((2, 2), betas=(1 / 800e9, 1 / 200e9, 1 / 12.5e9))
        even = CM.dispatch_matrix_from_ratios(m, 1.0, 128e6, mode="even")
        c_hat = T.target_dispatch(m, tokens_sent=1.0)
        ta = CM.dispatch_matrix_from_ratios(m, 1.0, 128e6, mode="ta",
                                            c_hat=c_hat)
        t_even = CM.simulate_exchange(m, even)
        t_ta = CM.simulate_exchange(m, ta)
        assert t_ta.contention < t_even.contention
        assert t_ta.lower_bound <= t_even.lower_bound * 1.001

    @given(fast=st.floats(100, 1000), slow=st.floats(1, 50))
    @settings(max_examples=25, deadline=None)
    def test_property_ta_never_slower(self, fast, slow):
        m = _sym_model((4, 4), betas=(1 / (fast * 2e9), 1 / (fast * 1e9),
                                      1 / (slow * 1e9)))
        even = CM.dispatch_matrix_from_ratios(m, 1.0, 64e6, mode="even")
        c_hat = T.target_dispatch(m, tokens_sent=1.0)
        ta = CM.dispatch_matrix_from_ratios(m, 1.0, 64e6, mode="ta",
                                            c_hat=c_hat)
        assert (CM.simulate_exchange(m, ta).lower_bound
                <= CM.simulate_exchange(m, even).lower_bound * 1.001)


class TestRingTopology:
    """Paper Fig. 2(b): ring topologies share the Eq. 7 solution pattern."""

    def test_hop_levels(self):
        r = T.RingTopology(8)
        assert r.level(0, 1) == 1
        assert r.level(0, 7) == 1      # wraparound
        assert r.level(0, 4) == 4
        assert r.num_levels == 5
        assert r.is_symmetric()

    def test_level_sizes(self):
        r = T.RingTopology(6)
        assert list(r.level_sizes()) == [1, 2, 2, 1]

    def test_eq7_on_ring(self):
        r = T.RingTopology(6)
        # per-hop bandwidth decays with distance (multi-hop bottleneck)
        beta = tuple(1.0 / (200e9 / max(h, 1) ** 1.0)
                     for h in range(r.num_levels))
        m = T.CommModel(topo=r, alpha=(0.0,) * r.num_levels, beta=beta)
        c = T.target_dispatch(m, tokens_sent=600.0)
        np.testing.assert_allclose(c.sum(1), 600.0, rtol=1e-9)
        np.testing.assert_allclose(c.sum(0), 600.0, rtol=1e-9)
        # nearer hops carry proportionally more
        assert c[0, 1] > c[0, 2] > c[0, 3]
        assert c[0, 1] == pytest.approx(2 * c[0, 2], rel=1e-6)

    def test_ratio_conservation_ring(self):
        r = T.RingTopology(8)
        beta = tuple(1.0 / (100e9 / max(h, 1))
                     for h in range(r.num_levels))
        m = T.CommModel(topo=r, alpha=(0.0,) * r.num_levels, beta=beta)
        ratios = T.per_level_ratios(m)
        n = r.level_sizes()
        assert float((ratios * n).sum()) == pytest.approx(8.0)


class TestDispatchPlanLevels:
    """Level-indexed DispatchPlan API (N-level generalization)."""

    def test_two_level_plans_byte_identical_via_compat_aliases(self):
        """make_dispatch_plan on a (pods, data) hierarchy must produce the
        exact capacities make_plan (the PR-2 near/far entry point) does,
        readable through the deprecated cap_near/cap_far properties."""
        for pods, epp, mode in [(2, 4, "ta"), (2, 4, "even"), (1, 16, "ta"),
                                (4, 8, "hir"), (2, 16, "ta")]:
            old = C.make_plan(tokens_per_device=4096, num_experts=32,
                              top_k=2, capacity_factor=1.25, num_pods=pods,
                              ep_per_pod=epp, mode=mode)
            sizes = (pods, epp) if pods > 1 else (epp,)
            new = C.make_dispatch_plan(
                tokens_per_device=4096, num_experts=32, top_k=2,
                capacity_factor=1.25, axis_sizes=sizes, mode=mode)
            assert new.caps == old.caps, (pods, epp, mode)
            assert new.cap_near == old.cap_near
            assert new.cap_far == old.cap_far
            assert new.ratios == old.ratios

    def test_three_level_caps_follow_bandwidth_ladder(self):
        p = C.make_dispatch_plan(tokens_per_device=8192, num_experts=32,
                                 top_k=2, capacity_factor=1.0,
                                 axis_sizes=(2, 2, 2), mode="ta",
                                 round_multiple=1)
        assert p.num_stages == 3
        assert p.level_axes == (("data",), ("node", "data"),
                                ("pod", "node", "data"))
        # innermost (ICI) stage gets the most capacity, outermost the least
        assert p.caps[0] > p.caps[1] > p.caps[2] > 0
        # stage ratios mirror the ICI : DCN : DCI bandwidth ordering
        assert p.caps[1] / p.caps[2] == pytest.approx(
            T.NODE_BW / T.DCI_BW, rel=0.05)

    def test_degenerate_single_member_level_rule(self):
        """Pinned: a level with no members beyond self has ratio 0; stage 0
        then falls back to the *self* ratio (ratios[0]) so the folded-in
        self chunk is never starved, and any outer empty stage is simply
        inactive (cap 0)."""
        # one device per pod: level 1 (intra-pod) is empty
        p = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=1,
                        mode="ta", round_multiple=1)
        assert p.level_sizes[1] == 0 and p.ratios[1] == 0.0
        assert C.stage_ratio(p.ratios, p.level_sizes, 0) == p.ratios[0]
        c_even = 4096 * 2 * 1.0 / 16
        assert p.caps[0] == max(1, int(np.ceil(c_even * p.ratios[0])))
        # middle axis of size 1: stage 1 inactive, stages 0/2 alive
        p3 = C.make_dispatch_plan(tokens_per_device=4096, num_experts=16,
                                  top_k=2, capacity_factor=1.0,
                                  axis_sizes=(2, 1, 4), mode="ta",
                                  round_multiple=1)
        assert p3.caps[1] == 0
        assert p3.caps[0] > 0 and p3.caps[2] > 0
        assert p3.active_stages() == (0, 2)

    @given(depth=st.integers(3, 4), arity=st.integers(2, 3),
           fan=st.integers(2, 4))
    @settings(max_examples=20, deadline=None)
    def test_property_deep_tree_ratios_non_increasing(self, depth, arity,
                                                      fan):
        """Eq. (7) ratio vectors from 3- and 4-level trees are
        non-increasing with level (slower links never get bigger chunks
        under the default bandwidth ladder)."""
        sizes = (fan,) + (arity,) * (depth - 1)
        m = T.tree_topology_nd(sizes)
        assert m.topo.num_levels == depth + 1
        r = T.per_level_ratios(m)
        assert len(r) == depth + 1
        assert (r > 0).all()
        for a, b in zip(r, r[1:]):
            assert a >= b - 1e-12
        # conservation: sum_l n_l * ratio_l == P
        n = m.topo.level_sizes(0)
        assert float((r * n).sum()) == pytest.approx(m.topo.num_devices)

    @given(tokens=st.integers(1024, 32768), cf=st.floats(0.5, 2.0),
           sizes=st.sampled_from([(2, 2, 2), (2, 2, 4), (2, 4, 2),
                                  (2, 2, 2, 2), (3, 2, 2)]),
           k=st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_property_caps_preserve_total_capacity(self, tokens, cf, sizes,
                                                   k):
        """TA caps weighted by per-stage destination counts (self folded
        into stage 0, the Eq. 3 send-volume accounting) preserve the even
        plan's total capacity within integer rounding."""
        world = int(np.prod(sizes))
        experts = 2 * world
        pe = C.make_dispatch_plan(tokens_per_device=tokens,
                                  num_experts=experts, top_k=k,
                                  capacity_factor=cf, axis_sizes=sizes,
                                  mode="even", round_multiple=1)
        pt = C.make_dispatch_plan(tokens_per_device=tokens,
                                  num_experts=experts, top_k=k,
                                  capacity_factor=cf, axis_sizes=sizes,
                                  mode="ta", round_multiple=1)
        assert pt.num_stages == len(sizes)

        def dests(p, s):
            return p.stage_dests(s) + (1 if s == 0 else 0)
        tot_t = sum(pt.caps[s] * dests(pt, s) for s in pt.active_stages())
        tot_e = sum(pe.caps[s] * dests(pe, s) for s in pe.active_stages())
        if min(pe.caps[s] for s in pe.active_stages()) > 8:
            assert abs(tot_t - tot_e) / tot_e < 0.05
        # rounding: aligning to chunks never shrinks any stage
        al = C.align_to_chunks(pt, 3)
        for s in range(pt.num_stages):
            assert al.caps[s] >= pt.caps[s]
            if pt.caps[s]:
                assert al.caps[s] % 3 == 0
                assert al.caps[s] - pt.caps[s] < 3

    def test_a2a_bytes_by_level(self):
        p = C.make_dispatch_plan(tokens_per_device=4096, num_experts=16,
                                 top_k=2, capacity_factor=1.0,
                                 axis_sizes=(2, 2, 2), mode="ta")
        b = C.a2a_bytes(p, d_model=128, bytes_per_el=2)
        E = p.experts_per_rank
        assert len(b["by_level"]) == 3
        assert b["by_level"][0] == p.caps[0] * E * 1 * 128 * 2    # 1 peer
        assert b["by_level"][1] == p.caps[1] * E * 2 * 128 * 2    # 1 node x 2
        assert b["by_level"][2] == p.caps[2] * E * 4 * 128 * 2    # 1 pod x 4
        # deprecated aliases stay consistent with the vector
        assert b["near_bytes"] == b["by_level"][0]
        assert b["far_bytes"] == sum(b["by_level"][1:])


class TestCapacityProperties:
    @given(tokens=st.integers(8192, 65536), experts=st.sampled_from([16, 32, 64, 160]),
           k=st.integers(1, 6), pods=st.sampled_from([1, 2]),
           epp=st.sampled_from([4, 8, 16]))
    @settings(max_examples=40, deadline=None)
    def test_plan_invariants(self, tokens, experts, k, pods, epp):
        """TA plans never increase total send volume vs even, and the
        near/far split respects the beta ratio within rounding."""
        if experts % (pods * epp) != 0:
            return
        pe = C.make_plan(tokens_per_device=tokens, num_experts=experts,
                         top_k=k, capacity_factor=1.25, num_pods=pods,
                         ep_per_pod=epp, mode="even", round_multiple=1)
        pt = C.make_plan(tokens_per_device=tokens, num_experts=experts,
                         top_k=k, capacity_factor=1.25, num_pods=pods,
                         ep_per_pod=epp, mode="ta", round_multiple=1)
        assert pt.cap_near >= 1 and pe.cap_near >= 1
        if pods == 1:
            assert pt.cap_near == pe.cap_near
        elif pe.cap_far > 8:   # above the rounding floor
            assert pt.cap_near > pe.cap_near          # near gets more
            assert pt.cap_far < pe.cap_far            # far gets less
            # total sent volume conserved (Eq. 3), within integer rounding
            n_near, n_far = epp, (pods - 1) * epp
            tot_t = pt.cap_near * n_near + pt.cap_far * n_far
            tot_e = pe.cap_near * n_near + pe.cap_far * n_far
            assert abs(tot_t - tot_e) / tot_e < 0.05
