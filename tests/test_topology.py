"""Unit + property tests for the TA-MoE topology core (paper §4)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import topology as T
from repro.core import capacity as C
from repro.core import comm_model as CM
from repro.core.gating import ta_penalties


def _sym_model(spec, betas=None):
    topo = T.TreeTopology(spec)
    L = topo.num_levels
    if betas is None:
        betas = tuple(1.0 / (100e9 / (10 ** l)) for l in range(L))
    alphas = tuple(1e-6 * l for l in range(L))
    return T.CommModel(topo=topo, alpha=alphas, beta=betas)


class TestTreeTopology:
    def test_levels_flat(self):
        topo = T.TreeTopology(4)
        assert topo.num_devices == 4
        assert topo.num_levels == 2
        assert topo.level(0, 0) == 0
        assert topo.level(0, 3) == 1

    def test_levels_two_tier(self):
        topo = T.TreeTopology((2, 2))
        lm = topo.level_matrix()
        assert lm[0, 1] == 1 and lm[0, 2] == 2 and lm[2, 3] == 1
        assert topo.is_symmetric()

    def test_levels_three_tier(self):
        topo = T.TreeTopology(((2, 2), (2, 2)))
        assert topo.num_levels == 4
        assert topo.level(0, 1) == 1
        assert topo.level(0, 2) == 2
        assert topo.level(0, 4) == 3

    def test_asymmetric_detected_and_merged(self):
        topo = T.TreeTopology(((2, 2), (2,)))
        assert not topo.is_symmetric()
        merged = T.symmetrize(topo)
        assert merged.is_symmetric()
        assert merged.num_devices == topo.num_devices  # no device lost
        assert merged.spec == (2, 2, 2)                # paper's example

    def test_level_sizes(self):
        topo = T.TreeTopology((2, 2))
        assert list(topo.level_sizes(0)) == [1, 1, 2]


class TestEq7:
    def test_row_and_col_sums(self):
        m = _sym_model((2, 2))
        c = T.target_dispatch(m, tokens_sent=1024.0)
        np.testing.assert_allclose(c.sum(1), 1024.0, rtol=1e-9)
        np.testing.assert_allclose(c.sum(0), 1024.0, rtol=1e-9)

    def test_bandwidth_proportionality(self):
        # Eq 7: chunk size linear in link bandwidth
        m = _sym_model((2, 2), betas=(1 / 800e9, 1 / 200e9, 1 / 12.5e9))
        c = T.target_dispatch(m, tokens_sent=1000.0)
        assert c[0, 1] / c[0, 2] == pytest.approx(200 / 12.5, rel=1e-6)

    def test_homogeneous_reduces_to_even(self):
        m = _sym_model(4, betas=(1 / 100e9, 1 / 100e9))
        c = T.target_dispatch(m, tokens_sent=400.0)
        np.testing.assert_allclose(c, 100.0, rtol=1e-9)

    def test_asymmetric_goes_through_merge(self):
        topo = T.TreeTopology(((2, 2), (2,)))
        m = T.CommModel(topo=topo, alpha=(0, 1e-6, 1e-5, 1e-5),
                        beta=(1 / 800e9, 1 / 200e9, 1 / 12.5e9, 1 / 12.5e9))
        c = T.target_dispatch(m, tokens_sent=600.0)
        assert c.shape == (6, 6)
        np.testing.assert_allclose(c.sum(1), 600.0, rtol=1e-9)

    @given(n_nodes=st.integers(2, 6), node_size=st.integers(1, 6),
           b1=st.floats(10, 1000), b2=st.floats(1, 9))
    @settings(max_examples=40, deadline=None)
    def test_property_constraints_hold(self, n_nodes, node_size, b1, b2):
        """Eq 3/4 constraints hold for arbitrary 2-tier symmetric trees."""
        spec = tuple([node_size] * n_nodes)
        topo = T.TreeTopology(spec)
        m = T.CommModel(topo=topo, alpha=(0.0, 1e-6, 1e-5),
                        beta=(1 / (b1 * 2e9), 1 / (b1 * 1e9), 1 / (b2 * 1e9)))
        c = T.target_dispatch(m, tokens_sent=512.0)
        assert (c > 0).all()
        np.testing.assert_allclose(c.sum(1), 512.0, rtol=1e-6)
        np.testing.assert_allclose(c.sum(0), 512.0, rtol=1e-6)
        # faster links never get smaller chunks
        lm = topo.level_matrix()
        near = c[0][lm[0] == 1].mean() if (lm[0] == 1).any() else None
        far = c[0][lm[0] == 2].mean()
        if near is not None:
            assert near >= far


class TestEq5Smoothing:
    def test_smoothing_recovers_level_constants(self):
        topo = T.TreeTopology((2, 2))
        lm = topo.level_matrix()
        rng = np.random.default_rng(0)
        beta_true = np.array([1e-12, 5e-12, 80e-12])
        noise = rng.normal(1.0, 0.05, lm.shape)
        beta_ij = beta_true[lm] * noise
        alpha_ij = np.full(lm.shape, 1e-6)
        m = T.smooth_profile(topo, alpha_ij, beta_ij)
        assert m.beta[1] == pytest.approx(5e-12, rel=0.2)
        assert m.beta[2] == pytest.approx(80e-12, rel=0.2)


class TestRatiosAndPenalties:
    def test_ratio_conservation(self):
        m = T.tpu_topology(2, 16)
        r = T.per_level_ratios(m)
        n = m.topo.level_sizes(0)
        assert float((r * n).sum()) == pytest.approx(m.topo.num_devices)

    def test_single_pod_is_even(self):
        m = T.tpu_topology(1, 16)
        r = T.per_level_ratios(m)
        np.testing.assert_allclose(r, 1.0)

    def test_penalties_mean_one_weighted(self):
        m = T.tpu_topology(2, 16)
        r = T.per_level_ratios(m)
        sizes = tuple(int(x) for x in m.topo.level_sizes(0))
        p = ta_penalties(tuple(r), level_sizes=sizes)
        mean = sum(pi * si for pi, si in zip(p, sizes)) / sum(sizes)
        assert mean == pytest.approx(1.0, rel=1e-6)
        assert p[2] > p[1]  # slow level penalized harder


class TestCapacityPlan:
    def test_even_plan(self):
        p = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                        mode="even")
        assert p.cap_near == p.cap_far
        assert p.experts_per_rank == 2

    def test_ta_plan_ratio_matches_beta(self):
        p = C.make_plan(tokens_per_device=65536, num_experts=160, top_k=6,
                        capacity_factor=1.2, num_pods=2, ep_per_pod=16,
                        mode="ta", round_multiple=1)
        assert p.cap_near / p.cap_far == pytest.approx(
            T.ICI_BW / T.DCI_BW, rel=0.02)

    def test_ta_single_pod_equals_even(self):
        pa = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                         capacity_factor=1.0, num_pods=1, ep_per_pod=16,
                         mode="ta")
        pe = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                         capacity_factor=1.0, num_pods=1, ep_per_pod=16,
                         mode="even")
        assert pa.cap_near == pe.cap_near

    def test_hir_plan_enforces_ratio(self):
        p = C.make_plan(tokens_per_device=8192, num_experts=32, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                        mode="hir", hir_ratio=4.0, round_multiple=1)
        assert p.cap_near / p.cap_far == pytest.approx(4.0, rel=0.05)

    def test_bytes_accounting(self):
        p = C.make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                        capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                        mode="ta")
        b = C.a2a_bytes(p, d_model=128, bytes_per_el=2, num_pods=2,
                        ep_per_pod=4)
        assert b["near_bytes"] == p.cap_near * p.experts_per_rank * 3 * 128 * 2
        assert b["far_bytes"] == p.cap_far * p.experts_per_rank * 4 * 128 * 2


class TestCommModelSim:
    """Paper §3.3 motivation: uneven dispatch beats even on slow links."""

    def test_uneven_beats_even_on_tree(self):
        m = _sym_model((2, 2), betas=(1 / 800e9, 1 / 200e9, 1 / 12.5e9))
        even = CM.dispatch_matrix_from_ratios(m, 1.0, 128e6, mode="even")
        c_hat = T.target_dispatch(m, tokens_sent=1.0)
        ta = CM.dispatch_matrix_from_ratios(m, 1.0, 128e6, mode="ta",
                                            c_hat=c_hat)
        t_even = CM.simulate_exchange(m, even)
        t_ta = CM.simulate_exchange(m, ta)
        assert t_ta.contention < t_even.contention
        assert t_ta.lower_bound <= t_even.lower_bound * 1.001

    @given(fast=st.floats(100, 1000), slow=st.floats(1, 50))
    @settings(max_examples=25, deadline=None)
    def test_property_ta_never_slower(self, fast, slow):
        m = _sym_model((4, 4), betas=(1 / (fast * 2e9), 1 / (fast * 1e9),
                                      1 / (slow * 1e9)))
        even = CM.dispatch_matrix_from_ratios(m, 1.0, 64e6, mode="even")
        c_hat = T.target_dispatch(m, tokens_sent=1.0)
        ta = CM.dispatch_matrix_from_ratios(m, 1.0, 64e6, mode="ta",
                                            c_hat=c_hat)
        assert (CM.simulate_exchange(m, ta).lower_bound
                <= CM.simulate_exchange(m, even).lower_bound * 1.001)


class TestRingTopology:
    """Paper Fig. 2(b): ring topologies share the Eq. 7 solution pattern."""

    def test_hop_levels(self):
        r = T.RingTopology(8)
        assert r.level(0, 1) == 1
        assert r.level(0, 7) == 1      # wraparound
        assert r.level(0, 4) == 4
        assert r.num_levels == 5
        assert r.is_symmetric()

    def test_level_sizes(self):
        r = T.RingTopology(6)
        assert list(r.level_sizes()) == [1, 2, 2, 1]

    def test_eq7_on_ring(self):
        r = T.RingTopology(6)
        # per-hop bandwidth decays with distance (multi-hop bottleneck)
        beta = tuple(1.0 / (200e9 / max(h, 1) ** 1.0)
                     for h in range(r.num_levels))
        m = T.CommModel(topo=r, alpha=(0.0,) * r.num_levels, beta=beta)
        c = T.target_dispatch(m, tokens_sent=600.0)
        np.testing.assert_allclose(c.sum(1), 600.0, rtol=1e-9)
        np.testing.assert_allclose(c.sum(0), 600.0, rtol=1e-9)
        # nearer hops carry proportionally more
        assert c[0, 1] > c[0, 2] > c[0, 3]
        assert c[0, 1] == pytest.approx(2 * c[0, 2], rel=1e-6)

    def test_ratio_conservation_ring(self):
        r = T.RingTopology(8)
        beta = tuple(1.0 / (100e9 / max(h, 1))
                     for h in range(r.num_levels))
        m = T.CommModel(topo=r, alpha=(0.0,) * r.num_levels, beta=beta)
        ratios = T.per_level_ratios(m)
        n = r.level_sizes()
        assert float((ratios * n).sum()) == pytest.approx(8.0)


class TestCapacityProperties:
    @given(tokens=st.integers(8192, 65536), experts=st.sampled_from([16, 32, 64, 160]),
           k=st.integers(1, 6), pods=st.sampled_from([1, 2]),
           epp=st.sampled_from([4, 8, 16]))
    @settings(max_examples=40, deadline=None)
    def test_plan_invariants(self, tokens, experts, k, pods, epp):
        """TA plans never increase total send volume vs even, and the
        near/far split respects the beta ratio within rounding."""
        if experts % (pods * epp) != 0:
            return
        pe = C.make_plan(tokens_per_device=tokens, num_experts=experts,
                         top_k=k, capacity_factor=1.25, num_pods=pods,
                         ep_per_pod=epp, mode="even", round_multiple=1)
        pt = C.make_plan(tokens_per_device=tokens, num_experts=experts,
                         top_k=k, capacity_factor=1.25, num_pods=pods,
                         ep_per_pod=epp, mode="ta", round_multiple=1)
        assert pt.cap_near >= 1 and pe.cap_near >= 1
        if pods == 1:
            assert pt.cap_near == pe.cap_near
        elif pe.cap_far > 8:   # above the rounding floor
            assert pt.cap_near > pe.cap_near          # near gets more
            assert pt.cap_far < pe.cap_far            # far gets less
            # total sent volume conserved (Eq. 3), within integer rounding
            n_near, n_far = epp, (pods - 1) * epp
            tot_t = pt.cap_near * n_near + pt.cap_far * n_far
            tot_e = pe.cap_near * n_near + pe.cap_far * n_far
            assert abs(tot_t - tot_e) / tot_e < 0.05
