"""moe_permute: Pallas kernels vs the jnp reference, the routing index
builder, and the engine hot path with ``use_pallas`` forced on.

This file is also the CI Pallas-interpret lane's workload: run with
``JAX_PLATFORMS=cpu REPRO_KERNEL_INTERPRET=1`` every kernel body executes
under the Pallas interpreter, so CPU-only CI still exercises the real
kernel code (``use_pallas=True`` on CPU always interprets; the env var
additionally flips the ``None``/auto engine default onto the kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import dispatch as dispatch_lib, gating
from repro.core.capacity import make_dispatch_plan
from repro.core.dispatch import routing, transport
from repro.kernels.moe_permute import kernel as pk
from repro.kernels.moe_permute import ops as permute_ops
from repro.kernels.moe_permute import ref as pr


def _random_maps(rng, T, S, K):
    """Random (slot_to_token, inv-consistent) fixtures for the raw kernels."""
    s2t = np.where(rng.random(S) < 0.8, rng.integers(0, T, S), T)
    inv_idx = np.where(rng.random((T, K)) < 0.8,
                       rng.integers(0, S, (T, K)), S)
    inv_w = rng.random((T, K)).astype(np.float32)
    inv_w[inv_idx == S] = 0.0
    return (jnp.asarray(s2t, jnp.int32), jnp.asarray(inv_idx, jnp.int32),
            jnp.asarray(inv_w))


# ---------------------------------------------------------------------------
# kernel bodies vs reference (interpret mode)
# ---------------------------------------------------------------------------


class TestKernelVsRef:
    @pytest.mark.parametrize("T,S,K,d", [
        (8, 12, 2, 16),
        (33, 40, 4, 24),      # ragged row widths
        (64, 64, 1, 128),
        (5, 100, 2, 32),      # many slots, few tokens
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_permute_sweep(self, T, S, K, d, dtype):
        rng = np.random.default_rng(T * S + d)
        x = jnp.asarray(rng.standard_normal((T, d)), dtype)
        s2t, _, _ = _random_maps(rng, T, S, K)
        got = pk.permute_pallas(pr._with_zero_row(x), s2t, interpret=True)
        want = pr.permute_ref(x, s2t)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("T,S,K,d", [
        (8, 12, 2, 16),
        (33, 40, 4, 24),
        (64, 64, 1, 128),
    ])
    def test_unpermute_sweep(self, T, S, K, d):
        rng = np.random.default_rng(T + S + K)
        y = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
        _, inv_idx, inv_w = _random_maps(rng, T, S, K)
        got = pk.unpermute_pallas(pr._with_zero_row(y), inv_idx, inv_w,
                                  interpret=True)
        want = pr.unpermute_ref(y, inv_idx, inv_w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)

    def test_ops_grads_match_ref(self):
        """The custom VJP on the Pallas entries equals jnp autodiff of the
        reference — token grads and gate-weight grads both."""
        rng = np.random.default_rng(0)
        T, S, K, d = 12, 16, 2, 8
        x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
        s2t, inv_idx, inv_w = _random_maps(rng, T, S, K)

        def via_pallas(x_, w_):
            y = permute_ops._permute_pallas(x_, s2t, True)
            return jnp.sum(permute_ops._unpermute_pallas(
                y, inv_idx, w_, True) ** 2)

        def via_ref(x_, w_):
            y = pr.permute_ref(x_, s2t)
            return jnp.sum(pr.unpermute_ref(y, inv_idx, w_) ** 2)

        gx_p, gw_p = jax.grad(via_pallas, (0, 1))(x, inv_w)
        gx_r, gw_r = jax.grad(via_ref, (0, 1))(x, inv_w)
        np.testing.assert_allclose(gx_p, gx_r, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(gw_p, gw_r, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# property tests: round trip, masking, segment conservation
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 48), st.integers(8, 64))
def test_roundtrip_inverse_permutation_identity(seed, T, d):
    """A bijective permutation (S == T, every slot valid, unit weights)
    round-trips exactly: unpermute(permute(x)) == x."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    perm = jnp.asarray(rng.permutation(T), jnp.int32)
    buf = permute_ops.permute(x, perm)
    inv_idx = jnp.argsort(perm).astype(jnp.int32)[:, None]
    out = permute_ops.unpermute(buf, inv_idx, jnp.ones((T, 1), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               atol=1e-6, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 32), st.integers(6, 40))
def test_dropped_token_masking(seed, T, S):
    """Sentinel slots come back as exact zero rows on dispatch, and dropped
    picks (sentinel inverse entries) contribute exactly zero on combine."""
    d = 16
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32) + 100.0
    s2t, inv_idx, inv_w = _random_maps(rng, T, S, 2)
    buf = np.asarray(permute_ops.permute(x, s2t))
    empty = np.asarray(s2t) == T
    assert (buf[empty] == 0.0).all()
    assert (np.abs(buf[~empty]) > 0).any() or (~empty).sum() == 0
    # zeroing the weights of dropped picks is a no-op (they already are)
    y = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    out = permute_ops.unpermute(y, inv_idx, inv_w)
    wiped = permute_ops.unpermute(
        y, inv_idx, jnp.where(inv_idx == S, 0.0, inv_w))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(wiped))


def _route_as_rank0(plan, axis_sizes, T, N, K, seed=0):
    """Run the real routing stage as rank 0 of an ``axis_sizes`` EP mesh
    (unit mesh axes: only axis_index is consumed, no collectives)."""
    names = {2: ("pod", "data"), 3: ("pod", "node", "data"),
             4: ("pod", "node0", "node1", "data")}[len(axis_sizes)]
    cfg = dispatch_lib.MoEConfig(d_model=8, d_ff=16, num_experts=N, top_k=K,
                                 dtype=jnp.float32)
    ep = dispatch_lib.EPSpec.from_axes(names, axis_sizes)
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dispatch_lib.init_moe_params(jax.random.PRNGKey(seed), cfg, ep,
                                          gate_cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, 8), jnp.float32)
    mesh = make_mesh((1,) * len(names), names)

    def body(p, xx):
        routed = routing.route(p, xx, cfg, ep, plan, gate_cfg,
                               with_bufs=False)
        di = routing.build_indices(routed.sels,
                                   routed.gate_out["topk_idx"], T)
        return di[:4] + (di.rows_per_expert,)
    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                   out_specs=(P(), P(), P(), P(), P()), check_vma=False)
    with mesh:
        out = fn(params, x)
    ep_stages = transport.plan_stages(plan, ep)
    return out, ep_stages, plan.experts_per_rank


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(((2, 2), (2, 2, 2), (2, 2, 2, 2))),
       st.integers(0, 1_000), st.floats(1.0, 4.0))
def test_segment_offsets_conserve_plan_caps(axis_sizes, seed, cf):
    """build_indices' flat slot count and per-stage spans must match the
    DispatchPlan capacities exactly — one contiguous
    ``num_dests * E_local * cap`` span per active stage, in stage order —
    and inversion must conserve total combine weight."""
    T, N, K = 32, 16, 2
    plan = make_dispatch_plan(tokens_per_device=T, num_experts=N, top_k=K,
                              capacity_factor=cf, axis_sizes=axis_sizes,
                              mode="ta")
    (s2t, slot_w, inv_idx, inv_w, rows_per_expert), stages, E_l = \
        _route_as_rank0(plan, axis_sizes, T, N, K, seed=seed)
    S = int(s2t.shape[0])
    # routing clamps each stage's capacity to the local token count
    want_spans = [st_.num_dests * E_l * min(st_.cap, T) for st_ in stages]
    assert S == sum(want_spans)
    # spans are contiguous and stage-ordered: reconstruct from the plan
    off = 0
    for st_, span in zip(stages, want_spans):
        assert st_.cap == plan.caps[st_.index] > 0
        off += span
    assert off == S
    # the runtime occupancy view agrees with the slot weights: one count
    # per (stage, destination, expert) segment, prefix-valid, summing to
    # the kept slots and bounded by each stage's capacity
    counts = np.asarray(rows_per_expert)
    assert counts.shape[0] == sum(st_.num_dests * E_l for st_ in stages)
    assert counts.sum() == int((np.asarray(slot_w) > 0).sum())
    off = 0
    for st_ in stages:
        n_seg = st_.num_dests * E_l
        assert (counts[off:off + n_seg] <= min(st_.cap, T)).all()
        off += n_seg
    # weight conservation through inversion: every kept (token, pick) weight
    # appears exactly once on each side
    np.testing.assert_allclose(float(jnp.sum(slot_w)),
                               float(jnp.sum(inv_w)), rtol=1e-6)
    # inverse entries point back at slots holding the same token
    inv = np.asarray(inv_idx)
    s2t_np = np.concatenate([np.asarray(s2t), [T]])   # sentinel row
    for t in range(T):
        for k in range(K):
            s = inv[t, k]
            if s < S:
                assert s2t_np[s] == t


# ---------------------------------------------------------------------------
# engine hot path with the kernels forced on
# ---------------------------------------------------------------------------


def _engine_setup(T=48, N=4, K=2):
    cfg = dispatch_lib.MoEConfig(d_model=16, d_ff=32, num_experts=N,
                                 top_k=K, capacity_factor=8.0,
                                 dtype=jnp.float32)
    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dispatch_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                          gate_cfg)
    from repro.core.capacity import make_plan
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=8.0, num_pods=1, ep_per_pod=1,
                     mode="even")
    x = jax.random.normal(jax.random.PRNGKey(1), (T, 16), jnp.float32)
    return cfg, ep, gate_cfg, params, plan, x


def _engine_apply(name, params, x, cfg, ep, gate_cfg, **kw):
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = dispatch_lib.make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                   **kw)
    fn = shard_map(lambda p, xx: eng(p, xx), mesh=mesh,
                   in_specs=(P(), P()), out_specs=(P(), P()),
                   check_vma=False)
    with mesh:
        return fn(params, x)


@pytest.mark.parametrize("name,kw", [
    ("a2a", {}),
    ("a2a_pipelined", {"num_chunks": 3}),
    ("gather", {}),
])
@pytest.mark.parametrize("use_pallas", [None, True])
def test_engine_use_pallas_matches_einsum_oracle(name, kw, use_pallas):
    """Every registered selection path == the einsum oracle with the
    permutation kernels on (``True`` interprets on CPU) and at the auto
    default (which the CI interpret lane flips onto the kernels via
    REPRO_KERNEL_INTERPRET=1)."""
    cfg, ep, gate_cfg, params, plan, x = _engine_setup()
    y_or, _ = _engine_apply("einsum", params, x, cfg, ep, gate_cfg,
                            capacity=x.shape[0])
    needs_plan = name != "gather"
    y, m = _engine_apply(name, params, x, cfg, ep, gate_cfg,
                         use_pallas=use_pallas,
                         **(dict(plan=plan) if needs_plan else {}), **kw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_or),
                               atol=1e-4, rtol=1e-3)
    assert set(m) == set(dispatch_lib.METRIC_KEYS)


def test_engine_grad_flows_with_pallas_kernels():
    """Gate + expert grads are nonzero and finite through the kernel path
    (exercises both custom VJPs end to end)."""
    cfg, ep, gate_cfg, params, plan, x = _engine_setup(T=24)

    def loss(p):
        y, m = _engine_apply("a2a", p, x, cfg, ep, gate_cfg, plan=plan,
                             use_pallas=True)
        return jnp.sum(y ** 2) + m["aux_loss"]

    g = jax.grad(loss)(params)
    gw = np.asarray(g["w_in"])
    gg = np.asarray(g["gate"]["w"])
    assert np.isfinite(gw).all() and np.abs(gw).sum() > 0
    assert np.isfinite(gg).all() and np.abs(gg).sum() > 0
