"""Beyond-paper perf optimizations must be numerically equivalent to the
baseline paths (EXPERIMENTS.md §Perf): fused vocab-sharded xent and
flash-style blockwise attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.configs.base import get_config
from repro.models import layers, model as model_lib, transformer


@pytest.fixture(scope="module")
def setup(mesh11):
    arch = dataclasses.replace(get_config("internlm2_1_8b").reduced(),
                               dtype="float32")
    ctx0 = model_lib.build_ctx(arch, mesh11, seq_len=24, global_batch=2,
                               aux_mode="none")
    rules = model_lib.default_rules(mesh11)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              arch.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(jax.random.PRNGKey(0), ctx0)
    return mesh11, rules, ctx0, params, batch


def test_fused_xent_matches_baseline(setup):
    mesh, rules, ctx0, params, batch = setup
    ctx1 = dataclasses.replace(ctx0, fused_xent=True)
    with mesh, sharding.axis_rules(rules):
        l0, _ = jax.jit(lambda p, b: transformer.loss_fn(p, b, ctx0))(
            params, batch)
        l1, _ = jax.jit(lambda p, b: transformer.loss_fn(p, b, ctx1))(
            params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)


def test_fused_xent_grads_match(setup):
    mesh, rules, ctx0, params, batch = setup
    ctx1 = dataclasses.replace(ctx0, fused_xent=True)
    with mesh, sharding.axis_rules(rules):
        g0 = jax.jit(jax.grad(
            lambda p: transformer.loss_fn(p, batch, ctx0)[0]))(params)
        g1 = jax.jit(jax.grad(
            lambda p: transformer.loss_fn(p, batch, ctx1)[0]))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-6, rtol=1e-4)


def test_blockwise_forward_matches(setup):
    mesh, rules, ctx0, params, batch = setup
    ctx1 = dataclasses.replace(ctx0, use_blockwise=True)
    with mesh, sharding.axis_rules(rules):
        f0, _ = jax.jit(lambda p, b: transformer.forward(p, b, ctx0))(
            params, batch)
        f1, _ = jax.jit(lambda p, b: transformer.forward(p, b, ctx1))(
            params, batch)
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16),
                                           (False, 0)])
def test_blockwise_sdpa_vs_naive(causal, window):
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 50, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(3), (2, 50, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (2, 50, 2, 16), jnp.float32)
    a = layers._blockwise_sdpa(q, k, v, causal=causal,
                               sliding_window=window, block_k=16)
    b = layers._sdpa(q, k, v, causal=causal, sliding_window=window,
                     q_positions=jnp.arange(50), k_positions=jnp.arange(50))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_blockwise_mla_matches(mesh11, key):
    from repro.models import mla as mla_lib
    cfg0 = mla_lib.MLAConfig(d_model=64, num_heads=4, kv_lora_rank=32,
                             qk_nope_dim=16, qk_rope_dim=8, v_dim=16,
                             dtype=jnp.float32)
    cfg1 = dataclasses.replace(cfg0, use_blockwise=True)
    params = mla_lib.init_mla(key, cfg0)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 40, 64), jnp.float32)
    y0, _ = mla_lib.mla_apply(params, x, cfg0)
    y1, _ = mla_lib.mla_apply(params, x, cfg1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-5, rtol=1e-4)


def test_mamba_chunked_scan_matches():
    from repro.models import mamba as mamba_lib
    cfg0 = mamba_lib.MambaConfig(d_model=32, d_state=8, dtype=jnp.float32)
    cfg1 = dataclasses.replace(cfg0, scan_chunk=16)
    params = mamba_lib.init_mamba(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    y0 = mamba_lib.mamba_apply(params, x, cfg0)
    y1 = mamba_lib.mamba_apply(params, x, cfg1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-5, rtol=1e-4)


def test_quantized_a2a_close_to_exact(mesh11, key):
    from repro.core import gating, moe as moe_lib
    from repro.core.capacity import make_plan
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    D, F, N, K, T = 16, 32, 4, 2, 64
    ep = moe_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                        data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=8.0, num_pods=1, ep_per_pod=1,
                     mode="even")
    cfg0 = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                             capacity_factor=8.0, dtype=jnp.float32)
    cfg1 = dataclasses.replace(cfg0, a2a_dtype="float8_e4m3fn")
    params = moe_lib.init_moe_params(key, cfg0, ep, gate_cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (T, D), jnp.float32)

    def run(cfg):
        body = shard_map(
            lambda p, xx: moe_lib.moe_apply_a2a(p, xx, cfg, ep, plan,
                                                gate_cfg)[0],
            mesh=mesh11, in_specs=(P(), P()), out_specs=P(),
            check_vma=False)
        with mesh11:
            return body(params, x)
    y0, y1 = run(cfg0), run(cfg1)
    # f8 wire: relative error bounded by e4m3 resolution (~6%)
    err = np.abs(np.asarray(y0) - np.asarray(y1))
    rel = err.max() / (np.abs(np.asarray(y0)).max() + 1e-9)
    assert rel < 0.12, rel


def test_mlstm_chunkwise_matches():
    from repro.models import xlstm as xlstm_lib
    cfg0 = xlstm_lib.XLSTMConfig(d_model=32, num_heads=2, dtype=jnp.float32)
    cfg1 = dataclasses.replace(cfg0, chunk_size=8)
    params = xlstm_lib.init_mlstm(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    y0 = xlstm_lib.mlstm_apply(params, x, cfg0)
    y1 = xlstm_lib.mlstm_apply(params, x, cfg1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-5, rtol=1e-4)
