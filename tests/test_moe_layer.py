"""MoE layer correctness on a 1-device mesh: the a2a path degenerates to
identity collectives, which isolates the selection/combine logic; the
gather path must match a dense hand-computed MoE exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gating, moe as moe_lib
from repro.core.capacity import make_plan

D, F, N, K, T = 16, 32, 4, 2, 64


def _setup(key, mesh11, capacity_factor=8.0, shared=0):
    cfg = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                            capacity_factor=capacity_factor,
                            num_shared_experts=shared, dtype=jnp.float32)
    ep = moe_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                        data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = moe_lib.init_moe_params(key, cfg, ep, gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=capacity_factor, num_pods=1,
                     ep_per_pod=1, mode="even")
    return cfg, ep, gate_cfg, params, plan


def _dense_reference(params, x, cfg, gate_cfg):
    """Every expert computed on every token, combined by top-k weights."""
    out = gating.gate_forward(params["gate"], x, gate_cfg, None)
    y = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_in"][e])
        fe = h @ params["w_out"][e]
        w = jnp.sum(jnp.where(out["topk_idx"] == e, out["topk_weight"], 0.0),
                    axis=1)
        y = y + fe * w[:, None]
    if cfg.num_shared_experts:
        h = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_in"])
        y = y + h @ params["shared_out"]
    return y


def _run_shardmap(fn, mesh, params, x):
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    body = shard_map(fn, mesh=mesh, in_specs=(P(), P()),
                     out_specs=(P(), P()), check_vma=False)
    return body(params, x)


def test_a2a_matches_dense_when_capacity_ample(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key, mesh11)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
    with mesh11:
        y, metrics = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_a2a(p, xx, cfg, ep, plan,
                                                gate_cfg),
            mesh11, params, x)
    want = _dense_reference(params, x, cfg, gate_cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
    assert float(metrics["dropped"]) == pytest.approx(0.0, abs=1e-6)


def test_gather_matches_dense(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key, mesh11, shared=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, D), jnp.float32)
    with mesh11:
        y, _ = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_gather(p, xx, cfg, ep, gate_cfg),
            mesh11, params, x)
    want = _dense_reference(params, x, cfg, gate_cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_a2a_vs_gather_agree(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key, mesh11)
    x = jax.random.normal(jax.random.PRNGKey(3), (T, D), jnp.float32)
    with mesh11:
        y1, _ = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_a2a(p, xx, cfg, ep, plan,
                                                gate_cfg),
            mesh11, params, x)
        y2, _ = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_gather(p, xx, cfg, ep, gate_cfg),
            mesh11, params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)


def test_tight_capacity_drops_tokens(key, mesh11):
    cfg, ep, gate_cfg, params, _ = _setup(key, mesh11, capacity_factor=0.25)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=0.25, num_pods=1, ep_per_pod=1,
                     mode="even", round_multiple=1)
    x = jax.random.normal(jax.random.PRNGKey(4), (T, D), jnp.float32)
    with mesh11:
        y, metrics = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_a2a(p, xx, cfg, ep, plan,
                                                gate_cfg),
            mesh11, params, x)
    assert float(metrics["dropped"]) > 0.1
    assert np.isfinite(np.asarray(y)).all()


def test_grad_flows_through_dispatch(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key, mesh11)
    x = jax.random.normal(jax.random.PRNGKey(5), (T, D), jnp.float32)

    def loss(p):
        with mesh11:
            y, m = _run_shardmap(
                lambda pp, xx: moe_lib.moe_apply_a2a(pp, xx, cfg, ep, plan,
                                                     gate_cfg),
                mesh11, p, x)
        return jnp.sum(y ** 2) + m["aux_loss"]

    g = jax.grad(loss)(params)
    gate_g = np.asarray(g["gate"]["w"])
    expert_g = np.asarray(g["w_in"])
    assert np.abs(gate_g).max() > 0      # gate learns (via combine + aux)
    assert np.abs(expert_g).max() > 0    # experts learn
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_einsum_path_matches_dense(key, mesh11):
    """GShard einsum formulation (paper §2 baseline) == dense reference."""
    cfg, ep, gate_cfg, params, plan = _setup(key, mesh11)
    x = jax.random.normal(jax.random.PRNGKey(6), (T, D), jnp.float32)
    with mesh11:
        y, metrics = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_einsum(p, xx, cfg, ep, gate_cfg,
                                                   capacity=T),
            mesh11, params, x)
    want = _dense_reference(params, x, cfg, gate_cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
    assert float(metrics["dropped"]) == pytest.approx(0.0, abs=1e-6)


def test_einsum_and_a2a_paths_agree(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key, mesh11)
    x = jax.random.normal(jax.random.PRNGKey(7), (T, D), jnp.float32)
    with mesh11:
        y1, _ = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_a2a(p, xx, cfg, ep, plan,
                                                gate_cfg),
            mesh11, params, x)
        y2, _ = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_einsum(p, xx, cfg, ep, gate_cfg,
                                                   capacity=T),
            mesh11, params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)


def test_einsum_capacity_drops(key, mesh11):
    cfg, ep, gate_cfg, params, _ = _setup(key, mesh11)
    x = jax.random.normal(jax.random.PRNGKey(8), (T, D), jnp.float32)
    with mesh11:
        y, metrics = _run_shardmap(
            lambda p, xx: moe_lib.moe_apply_einsum(p, xx, cfg, ep, gate_cfg,
                                                   capacity=4),
            mesh11, params, x)
    assert float(metrics["dropped"]) > 0.1
    assert np.isfinite(np.asarray(y)).all()
