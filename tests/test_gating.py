"""Gate + auxiliary-loss unit tests (paper Eq. 1 / Eq. 8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import gating


def _gate_out(key, T=64, d=16, N=8, k=2, mode="lb", penalties=(1., 1., 1.)):
    cfg = gating.GateConfig(num_experts=N, top_k=k, aux_mode=mode,
                            penalty_by_level=penalties)
    params = gating.init_gate_params(key, d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    return cfg, gating.gate_forward(params, x, cfg, None)


def test_topk_shapes_and_normalization(key):
    cfg, out = _gate_out(key, k=2)
    assert out["topk_idx"].shape == (64, 2)
    np.testing.assert_allclose(out["topk_weight"].sum(-1), 1.0, rtol=1e-5)
    assert (out["probs"] >= 0).all()


def test_dispatch_fractions_sum_to_one(key):
    _, out = _gate_out(key)
    f = gating.dispatch_fractions(out["topk_idx"], 8)
    assert float(f.sum()) == pytest.approx(1.0)


def test_lb_loss_is_one_for_perfect_balance():
    """With uniform probs and perfectly balanced dispatch, l_aux == 1."""
    N, T = 4, 16
    probs = jnp.full((T, N), 1.0 / N)
    idx = jnp.tile(jnp.arange(N), T // N * 2).reshape(T, 2)[:, :1]
    gate_out = {"probs": probs, "topk_idx": idx,
                "topk_weight": jnp.ones((T, 1))}
    cfg = gating.GateConfig(num_experts=N, top_k=1, aux_mode="lb")
    assert float(gating.aux_loss(gate_out, cfg)) == pytest.approx(1.0)


def test_ta_loss_penalizes_far_dispatch_more():
    """Same dispatch stats, far experts -> larger l_topo than near."""
    N, T = 4, 32
    probs = jnp.full((T, N), 1.0 / N)
    cfg = gating.GateConfig(num_experts=N, top_k=1, aux_mode="ta",
                            penalty_by_level=(0.5, 0.5, 2.0))
    near_levels = jnp.array([0, 1, 1, 1])
    far_levels = jnp.array([2, 2, 2, 2])
    idx = jnp.tile(jnp.arange(N), T // N).reshape(T, 1)
    gate_out = {"probs": probs, "topk_idx": idx,
                "topk_weight": jnp.ones((T, 1))}
    l_near = float(gating.aux_loss(gate_out, cfg, near_levels))
    l_far = float(gating.aux_loss(gate_out, cfg, far_levels))
    assert l_far > l_near


def test_ta_equals_lb_when_penalties_uniform(key):
    cfg_ta, out = _gate_out(key, mode="ta")
    cfg_lb = gating.GateConfig(num_experts=8, top_k=2, aux_mode="lb")
    levels = jnp.zeros((8,), jnp.int32)
    assert float(gating.aux_loss(out, cfg_ta, levels)) == pytest.approx(
        float(gating.aux_loss(out, cfg_lb)), rel=1e-6)


def test_hir_bias_shifts_dispatch_toward_near(key):
    cfg = gating.GateConfig(num_experts=8, top_k=2, aux_mode="hir",
                            hir_bias=5.0)
    params = gating.init_gate_params(key, 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
    levels = jnp.array([0, 1, 1, 1, 2, 2, 2, 2])
    out = gating.gate_forward(params, x, cfg, levels)
    f = gating.dispatch_fractions(out["topk_idx"], 8)
    near = float(f[:4].sum())
    assert near > 0.9  # strong compulsory preference


def test_expert_levels_mapping():
    lv = gating.expert_levels(num_experts=8, experts_per_rank=2,
                              ep_per_pod=2, num_pods=2,
                              my_pod=jnp.int32(0), my_data=jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(lv), [0, 0, 1, 1, 2, 2, 2, 2])


@given(st.integers(2, 16), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_property_penalties_positive_mean_one(n_levels_seed, _):
    ratios = tuple(np.random.default_rng(n_levels_seed)
                   .uniform(0.1, 3.0, 3))
    p = gating.ta_penalties(ratios)
    assert all(x > 0 for x in p)
    assert np.mean(p) == pytest.approx(1.0, rel=1e-6)


def test_ta_penalties_softmax_norm():
    """Pin the (fixed) softmax normalization: population mean 1 (also under
    level-size weighting), ratio ordering preserved, spread compressed vs
    the plain "sum" norm, and equality in the degenerate uniform case."""
    ratios = (2.0, 1.0, 0.25)
    sizes = (2, 6, 24)
    p_sum = np.asarray(gating.ta_penalties(ratios, norm="sum",
                                           level_sizes=sizes))
    p_soft = np.asarray(gating.ta_penalties(ratios, norm="softmax",
                                            level_sizes=sizes))
    w = np.asarray(sizes, np.float64)
    for p in (p_sum, p_soft):
        assert float((p * w).sum() / w.sum()) == pytest.approx(1.0, rel=1e-9)
    # smaller capacity ratio -> larger penalty, in both norms
    assert np.all(np.diff(p_sum) > 0) and np.all(np.diff(p_soft) > 0)
    # the exp reweighting genuinely changes the penalties ...
    assert not np.allclose(p_soft, p_sum)
    # ... and with equal ratios both norms collapse to all-ones
    uniform = gating.ta_penalties((1.0, 1.0, 1.0), norm="softmax")
    np.testing.assert_allclose(uniform, (1.0, 1.0, 1.0), rtol=1e-12)


def test_ta_penalties_rejects_unknown_norm():
    with pytest.raises(ValueError, match="unknown norm"):
        gating.ta_penalties((1.0, 1.0, 1.0), norm="l2")
