"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned arch runs one forward/train step on CPU — output shapes + no NaNs.

The FULL configs are exercised only by the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.configs.base import ARCH_IDS, RunConfig, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib, transformer
from repro.optim import adamw
from repro.training import trainer

SEQ, BATCH = 16, 2


def _setup(arch_id, mesh11, aux="ta"):
    arch = get_config(arch_id).reduced()
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=SEQ, global_batch=BATCH,
                              aux_mode=aux if arch.is_moe else "none")
    rules = model_lib.default_rules(mesh11)
    return arch, ctx, rules


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id, mesh11, key):
    arch, ctx, rules = _setup(arch_id, mesh11)
    data = SyntheticLM(DataConfig(vocab_size=arch.vocab_size, seq_len=SEQ,
                                  global_batch=BATCH), arch)
    batch = data.batch(0)
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx)
        logits, aux = jax.jit(
            lambda p, b: transformer.forward(p, b, ctx))(params, batch)
    assert logits.shape == (BATCH, SEQ, arch.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step(arch_id, mesh11, key):
    arch, ctx, rules = _setup(arch_id, mesh11)
    run = RunConfig(seq_len=SEQ, global_batch=BATCH, total_steps=4,
                    warmup_steps=1,
                    aux_mode="ta" if arch.is_moe else "none")
    data = SyntheticLM(DataConfig(vocab_size=arch.vocab_size, seq_len=SEQ,
                                  global_batch=BATCH), arch)
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx)
        opt = adamw.init_state(params)
        step = jax.jit(trainer.make_train_step(ctx, run))
        p2, o2, metrics = step(params, opt, data.batch(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step_runs(arch_id, mesh11, key):
    from repro.models import decode as decode_lib
    arch, ctx, rules = _setup(arch_id, mesh11)
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx)
        cache = decode_lib.init_cache(ctx, BATCH, max_len=SEQ)
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        logits, cache2 = jax.jit(
            lambda p, c, t: decode_lib.decode_step(p, c, t, ctx))(
                params, cache, tok)
    assert logits.shape == (BATCH, 1, arch.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_reduced_configs_respect_budgets():
    for arch_id in ARCH_IDS:
        r = get_config(arch_id).reduced()
        assert r.d_model <= 512
        if r.moe:
            assert r.moe.num_experts <= 4
        assert r.num_layers <= 8


def test_full_configs_match_assignment():
    """The exact assigned numbers (system prompt) are encoded."""
    expect = {
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
        "internlm2_1_8b": (24, 2048, 16, 8, 8192, 92544),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553),
        "olmo_1b": (16, 2048, 16, 16, 8192, 50304),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, None, 102400),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "deepseek_v2_236b": (60, 5120, 128, 128, None, 102400),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
    }
    for aid, (L, d, H, kv, ff, V) in expect.items():
        c = get_config(aid)
        assert c.num_layers == L and c.d_model == d
        assert c.num_heads == H and c.num_kv_heads == kv
        assert c.vocab_size == V
        if ff is not None:
            assert c.d_ff == ff
    assert get_config("jamba_v0_1_52b").moe.num_experts == 16
    assert get_config("deepseek_v2_lite_16b").moe.num_experts == 64
    assert get_config("deepseek_v2_lite_16b").moe.top_k == 6
    assert get_config("deepseek_v2_236b").moe.num_experts == 160
    assert get_config("deepseek_v2_lite_16b").mla.kv_lora_rank == 512
