"""moe_fused: the dispatch→GEMM→combine megakernel vs the three-kernel
path (permute → ragged grouped GEMM → unpermute), its custom VJP, and the
engine with the fused local path forced on.

Run in the CI Pallas-interpret lane (``JAX_PLATFORMS=cpu
REPRO_KERNEL_INTERPRET=1``) the fused kernel body executes under the
Pallas interpreter, so CPU-only CI exercises the real gather / occupancy
gate / scatter-accumulate code, not just the jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import dispatch as dispatch_lib
from repro.core.capacity import make_dispatch_plan
from repro.kernels.moe_fused import ops as fused_ops
from repro.kernels.moe_fused.ref import local_moe_ref
from repro.kernels.moe_gemm import ops as gemm_ops
from repro.kernels.moe_permute import ops as permute_ops
from repro.kernels.moe_permute import ref as pr
from test_moe_permute import (_engine_apply, _engine_setup, _random_maps,
                              _route_as_rank0)


def _weights(rng, E, d, f):
    wi = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.3, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((E, f, d)) * 0.3, jnp.float32)
    return wi, wg, wo


def _slot_fixture(rng, T, offs, occupancy, garbage=True):
    """Slot maps with an ``occupancy`` fraction of each segment valid.

    Valid slots are a prefix holding distinct real tokens with positive
    weights (the build_indices contract).  When ``garbage`` is set, the
    slack rows past the valid count are adversarial: *real* token indices
    with *nonzero* weights — both the fused kernel and the three-kernel
    path must mask them to exactly zero contribution.
    """
    S = offs[-1]
    tok = np.full(S, T, np.int32)
    w = np.zeros(S, np.float32)
    valid = []
    for s in range(len(offs) - 1):
        width = offs[s + 1] - offs[s]
        nv = min(int(round(width * occupancy)), T)
        valid.append(nv)
        tok[offs[s]:offs[s] + nv] = rng.choice(T, size=nv, replace=False)
        w[offs[s]:offs[s] + nv] = rng.uniform(0.1, 1.0, nv)
        if garbage:
            slack = width - nv
            tok[offs[s] + nv:offs[s + 1]] = rng.integers(0, T, slack)
            w[offs[s] + nv:offs[s + 1]] = rng.uniform(0.1, 1.0, slack)
    return (jnp.asarray(tok), jnp.asarray(w),
            jnp.asarray(valid, jnp.int32))


def _unfused(x, tok, w, offs, exps, valid, wi, wg, wo):
    """The three-kernel path on the kernel entries: permute row-gather →
    occupancy-aware ragged grouped GEMM → weighted scatter combine."""
    buf = permute_ops.permute(x, tok, use_pallas=True)
    ys = gemm_ops.grouped_ffn_ragged(buf, offs, exps, valid, wi, wg, wo,
                                     use_pallas=True)
    T = x.shape[0]
    # inverse pick map of the valid slots (slack slots by contract carry
    # zero output rows, so they are simply absent from the inverse)
    tok_np, w_np, valid_np = map(np.asarray, (tok, w, valid))
    picks = [[] for _ in range(T)]
    for s in range(len(exps)):
        for i in range(int(valid_np[s])):
            slot = offs[s] + i
            picks[int(tok_np[slot])].append(slot)
    K = max(1, max(len(p) for p in picks))
    S = offs[-1]
    inv_idx = np.full((T, K), S, np.int32)
    inv_w = np.zeros((T, K), np.float32)
    for t, slots in enumerate(picks):
        for k, slot in enumerate(slots):
            inv_idx[t, k] = slot
            inv_w[t, k] = w_np[slot]
    return permute_ops.unpermute(ys, jnp.asarray(inv_idx),
                                 jnp.asarray(inv_w), use_pallas=True)


# ---------------------------------------------------------------------------
# fused == three-kernel == oracle
# ---------------------------------------------------------------------------


class TestFusedVsThreeKernel:
    @pytest.mark.parametrize("occupancy", [0.0, 0.5, 1.0])
    @pytest.mark.parametrize("caps", [(6,), (6, 4), (8, 4, 2)])
    def test_synthetic_layouts(self, occupancy, caps):
        """Stage-major (stage, expert) segment layouts at empty / partial /
        full occupancy, with garbage slack rows (real tokens, nonzero
        weights past the valid count) that must not leak."""
        rng = np.random.default_rng(int(occupancy * 10) + len(caps))
        T, d, f, E = 23, 8, 12, 3
        offs, exps = [0], []
        for c in caps:
            for e in range(E):
                offs.append(offs[-1] + c)
                exps.append(e)
        offs, exps = tuple(offs), tuple(exps)
        x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
        wi, wg, wo = _weights(rng, E, d, f)
        tok, w, valid = _slot_fixture(rng, T, offs, occupancy)
        want = local_moe_ref(x, tok, w, offs, exps, valid, wi, wg, wo)
        fused = fused_ops.local_moe(x, tok, w, offs, exps, valid, wi, wg,
                                    wo, use_pallas=True)
        unfused = _unfused(x, tok, w, offs, exps, valid, wi, wg, wo)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   atol=1e-5, rtol=1e-5)
        if occupancy == 0.0:
            assert np.abs(np.asarray(fused)).max() == 0.0

    @pytest.mark.parametrize("axis_sizes", [(2, 2), (2, 2, 2), (2, 2, 2, 2)])
    @pytest.mark.parametrize("cf", [1.0, 8.0])
    def test_plan_derived_layouts(self, axis_sizes, cf):
        """Real routing on 2-/3-/4-level plans: the fused kernel on
        build_indices' maps equals the three-kernel path on the same maps
        (cf=1 drops tokens → partial occupancy; cf=8 keeps everything)."""
        T, N, K = 32, 16, 2
        plan = make_dispatch_plan(tokens_per_device=T, num_experts=N,
                                  top_k=K, capacity_factor=cf,
                                  axis_sizes=axis_sizes, mode="ta")
        (tok, w, inv_idx, inv_w, counts), stages, E_l = _route_as_rank0(
            plan, axis_sizes, T, N, K, seed=len(axis_sizes))
        offs, exps = [0], []
        for stg in stages:
            width = min(stg.cap, T)
            for _dest in range(stg.num_dests):
                for e in range(E_l):
                    offs.append(offs[-1] + width)
                    exps.append(e)
        offs, exps = tuple(offs), tuple(exps)
        assert offs[-1] == tok.shape[0] and len(exps) == counts.shape[0]
        rng = np.random.default_rng(7)
        d, f = 8, 16
        x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
        wi, wg, wo = _weights(rng, E_l, d, f)
        fused = fused_ops.local_moe(x, tok, w, offs, exps, counts, wi, wg,
                                    wo, use_pallas=True)
        want = local_moe_ref(x, tok, w, offs, exps, counts, wi, wg, wo)
        # three-kernel on the *real* inverse maps build_indices emitted
        buf = permute_ops.permute(x, tok, use_pallas=True)
        ys = gemm_ops.grouped_ffn_ragged(buf, offs, exps, counts, wi, wg,
                                         wo, use_pallas=True)
        unfused = permute_ops.unpermute(ys, inv_idx, inv_w, use_pallas=True)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 24), st.integers(1, 6))
def test_fused_kernel_matches_ref_property(seed, T, cap):
    """Random layouts/occupancies: kernel body == oracle."""
    rng = np.random.default_rng(seed)
    E, d, f = 3, 8, 8
    offs = tuple(cap * i for i in range(2 * E + 1))
    exps = tuple(list(range(E)) + list(range(E)))
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    wi, wg, wo = _weights(rng, E, d, f)
    tok, w, valid = _slot_fixture(rng, T, offs, float(rng.uniform(0, 1)))
    fused = fused_ops.local_moe(x, tok, w, offs, exps, valid, wi, wg, wo,
                                use_pallas=True)
    want = local_moe_ref(x, tok, w, offs, exps, valid, wi, wg, wo)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# custom VJP through expert_ffn_flat
# ---------------------------------------------------------------------------


def test_fused_vjp_through_expert_ffn_flat():
    """Gradients through the fused expert_ffn_flat mode (kernel path) equal
    jnp autodiff of the reference path — tokens, gate weights, and all
    three expert weight tensors."""
    rng = np.random.default_rng(3)
    T, d, f, E = 16, 8, 12, 4
    cfg = dispatch_lib.MoEConfig(d_model=d, d_ff=f, num_experts=E, top_k=2,
                                 dtype=jnp.float32)
    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis=None)
    offs = tuple(6 * e for e in range(E + 1))
    exps = tuple(range(E))
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    wi, wg, wo = _weights(rng, E, d, f)
    tok, w, valid = _slot_fixture(rng, T, offs, 0.6)

    def loss(x_, w_, wi_, wg_, wo_, use_pallas):
        params = {"w_in": wi_, "w_gate": wg_, "w_out": wo_}
        y = dispatch_lib.expert_ffn_flat(
            params, x_, offs, cfg, ep, seg_experts=exps, rows_valid=valid,
            slot_to_token=tok, slot_w=w_, use_pallas=use_pallas)
        return jnp.sum(y ** 2)

    args = (x, w, wi, wg, wo)
    g_k = jax.grad(lambda *a: loss(*a, True), range(5))(*args)
    g_r = jax.grad(lambda *a: loss(*a, False), range(5))(*args)
    for a, b, name in zip(g_k, g_r, ("x", "slot_w", "w_in", "w_gate",
                                     "w_out")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4, err_msg=name)
        assert np.abs(np.asarray(a)).sum() > 0, name


def test_unpermute_bwd_is_chunked_and_correct():
    """The unpermute backward no longer materializes [T, K, d]: K chunked
    scatter-adds give identical grads at K=4 (the grad-correctness pin for
    the memory rewrite)."""
    rng = np.random.default_rng(11)
    T, S, K, d = 24, 40, 4, 16
    y = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    _, inv_idx, inv_w = _random_maps(rng, T, S, K)

    def via_pallas(y_, w_):
        return jnp.sum(permute_ops._unpermute_pallas(y_, inv_idx, w_,
                                                     True) ** 2)

    def via_ref(y_, w_):
        return jnp.sum(pr.unpermute_ref(y_, inv_idx, w_) ** 2)

    gy_p, gw_p = jax.grad(via_pallas, (0, 1))(y, inv_w)
    gy_r, gw_r = jax.grad(via_ref, (0, 1))(y, inv_w)
    np.testing.assert_allclose(np.asarray(gy_p), np.asarray(gy_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# engine with the fused path forced on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,kw", [
    ("a2a", {}),
    ("a2a_pipelined", {"num_chunks": 3}),
    ("gather", {}),
])
def test_engine_fused_matches_einsum_oracle(name, kw):
    """Every path with the fused megakernel forced on == the einsum oracle
    (on the unit test mesh every stage is local, so the a2a paths run
    entirely through the fused kernel — no permute, no transport)."""
    cfg, ep, gate_cfg, params, plan, x = _engine_setup()
    y_or, _ = _engine_apply("einsum", params, x, cfg, ep, gate_cfg,
                            capacity=x.shape[0])
    needs_plan = name != "gather"
    y, m = _engine_apply(name, params, x, cfg, ep, gate_cfg, use_pallas=True,
                         **(dict(plan=plan) if needs_plan else {}), **kw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_or),
                               atol=1e-4, rtol=1e-3)
    assert set(m) == set(dispatch_lib.METRIC_KEYS)
    # and fused == the unfused kernel-off engine, metrics included
    y_off, m_off = _engine_apply(name, params, x, cfg, ep, gate_cfg,
                                 use_pallas=False,
                                 **(dict(plan=plan) if needs_plan else {}),
                                 **kw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_off),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(m["dropped"]), float(m_off["dropped"]),
                               atol=1e-6)


def test_fused_a2a_path_emits_no_collectives_or_sorted_buffer():
    """The structural pin on the tentpole, now enforced by the static
    checker: with the kernels on, a fully local (unit-mesh) a2a engine
    call must verify against an *empty* collective inventory — no
    all_to_all, no staged transport at all.  With the kernels off the
    staged chain must still be there (an empty expectation has to fail)."""
    from repro.analysis import hlo_check

    fused = hlo_check.Scenario("fused-unit-mesh", (1,), "a2a", True)
    assert hlo_check.expected_inventory(fused) == []
    assert hlo_check.verify(fused) == []

    # the checker is not vacuous: the same unit mesh at 2 ranks with the
    # kernels off must carry the staged all_to_all chain again
    unfused = hlo_check.Scenario("unfused-2rank", (2,), "a2a", False)
    expected = hlo_check.expected_inventory(unfused)
    assert any(c.kind == "all_to_all" for c in expected)
    assert hlo_check.verify(unfused) == []
    # and claiming the fused (empty) inventory for it must be rejected
    assert hlo_check.verify(unfused, expected=[])


def test_engine_fused_grad_flows():
    """Gate + expert grads are nonzero and finite end to end through the
    fused megakernel's custom VJP."""
    cfg, ep, gate_cfg, params, plan, x = _engine_setup(T=24)

    def loss(p):
        y, m = _engine_apply("a2a", p, x, cfg, ep, gate_cfg, plan=plan,
                             use_pallas=True)
        return jnp.sum(y ** 2) + m["aux_loss"]

    g = jax.grad(loss)(params)
    for k in ("w_in", "w_gate", "w_out"):
        gk = np.asarray(g[k])
        assert np.isfinite(gk).all() and np.abs(gk).sum() > 0, k
    gg = np.asarray(g["gate"]["w"])
    assert np.isfinite(gg).all() and np.abs(gg).sum() > 0
