"""Minimal deterministic stand-in for the hypothesis API this suite uses.

CI installs real hypothesis (requirements-dev.txt) and these shims are never
imported.  On machines where hypothesis is unavailable the property tests
still run, against a fixed pseudo-random sample of each strategy instead of
hypothesis's adaptive search — strictly weaker shrinking/coverage, but the
same assertions over dozens of drawn examples, and collection never dies on
the import.

Supported surface: ``given`` (positional or keyword strategies), ``settings``
(``max_examples`` honoured, ``deadline`` ignored), and the ``strategies``
members ``integers``, ``floats``, ``sampled_from``.
"""

from __future__ import annotations

import inspect
import itertools

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the hypothesis module name
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        n_examples = getattr(fn, "_fallback_max_examples",
                             _DEFAULT_MAX_EXAMPLES)
        params = [p for p in inspect.signature(fn).parameters
                  if p != "self"]
        bound_kw = dict(zip(params, pos_strategies))
        bound_kw.update(kw_strategies)

        def wrapper(*args):
            # args is () for module-level tests, (self,) for methods; any
            # strategy-bound parameter is filled here, so pytest sees a
            # zero-fixture signature exactly as with real hypothesis.
            rng = np.random.default_rng(0)
            for _ in range(n_examples):
                drawn = {k: s.example(rng) for k, s in bound_kw.items()}
                fn(*args, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
