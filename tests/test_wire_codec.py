"""WireCodec API: registry resolution, config-time validation, the
deprecated wire_dtype/a2a_dtype alias, per-codec round-trip error bounds,
scale-block conservation, straight-through gradients through the scaled
wire, the quantized ragged grouped GEMM vs its references, and the
codec-aware byte accounting that drives the chunk chooser."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import dispatch as dispatch_lib
from repro.core import gating
from repro.core.capacity import a2a_bytes, make_dispatch_plan, make_plan
from repro.core.dispatch import transport, wire
from repro.models import model as model_lib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# registry + config-time validation
# ---------------------------------------------------------------------------


def test_registry_contents():
    assert set(wire.CODECS) >= {"bf16", "int8", "fp8e4m3"}
    assert wire.CODECS["bf16"].scaled is False
    assert wire.CODECS["int8"].scaled and wire.CODECS["int8"].quantize_compute
    assert wire.CODECS["fp8e4m3"].scaled
    assert not wire.CODECS["fp8e4m3"].quantize_compute
    # wire bytes come from the codec, not the model dtype
    assert wire.CODECS["bf16"].wire_bytes_per_elem == 2
    assert wire.CODECS["int8"].wire_bytes_per_elem == 1
    assert wire.CODECS["fp8e4m3"].wire_bytes_per_elem == 1


def test_get_codec_resolution():
    assert wire.get_codec(None) is None
    assert wire.get_codec("") is None
    assert wire.get_codec("int8") is wire.CODECS["int8"]
    c = wire.ScaledCodec(name="my4bit", wire_dtype="int8", qmax=7.0)
    assert wire.get_codec(c) is c


def test_unknown_codec_is_a_config_time_error():
    """The old stringly path died deep inside jnp.dtype; now the error
    names the registry up front."""
    with pytest.raises(ValueError, match=r"registered codecs.*bf16"):
        wire.get_codec("int4")
    with pytest.raises(ValueError, match="registered codec"):
        wire.cast_codec("bogus_dtype")
    with pytest.raises(ValueError, match="registered codecs"):
        dispatch_lib.MoEConfig(d_model=8, d_ff=16, num_experts=4, top_k=2,
                               wire_codec="nope")


def test_build_ctx_rejects_unknown_codec(mesh11):
    from repro.configs.base import get_config
    arch = get_config("gpt3_medium_moe").reduced()
    with pytest.raises(ValueError, match="registered codecs"):
        model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                            wire_codec="int4")


def test_deprecated_aliases_warn_and_resolve_to_cast():
    with pytest.warns(DeprecationWarning, match="wire_dtype=/a2a_dtype="):
        cfg = dispatch_lib.MoEConfig(d_model=8, d_ff=16, num_experts=4,
                                     top_k=2, a2a_dtype="bfloat16")
    assert isinstance(cfg.wire_codec, wire.CastCodec)
    assert cfg.wire_codec.wire_dtype == "bfloat16"
    assert not cfg.wire_codec.scaled

    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis=None)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        tr = transport.A2ATransport(ep=ep, wire_dtype="float16")
    assert isinstance(tr.codec, wire.CastCodec)
    # first-class codec passes silently
    tr2 = transport.A2ATransport(ep=ep, codec="int8")
    assert tr2.codec is wire.CODECS["int8"]


# ---------------------------------------------------------------------------
# round-trip error bounds
# ---------------------------------------------------------------------------


def _roundtrip(codec, x, block_ndim=2):
    payload, scale = codec.encode(x, block_ndim=block_ndim)
    if scale is not None:
        scale = scale.reshape(scale.shape + (1,) * block_ndim)
    return codec.decode(payload, scale, x.dtype)


def test_cast_roundtrip_bf16():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16), jnp.float32)
    y = _roundtrip(wire.CODECS["bf16"], x)
    # bf16 keeps 8 mantissa bits: relative error <= 2^-8
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2 ** -8)


@pytest.mark.parametrize("name,bound", [("int8", 0.5 / 127.0),
                                        ("fp8e4m3", 0.0625)])
def test_scaled_roundtrip_error_bound(name, bound):
    """Per-block: |x - decode(encode(x))| <= bound * block_absmax
    (half a quantization step for int8, one ulp of the 3-bit mantissa for
    fp8e4m3), and all-zero blocks come back exactly zero."""
    codec = wire.CODECS[name]
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 8, 16), jnp.float32)
    x = x.at[0, 2].set(0.0)                    # an all-zero block
    y = np.asarray(_roundtrip(codec, x))
    xn = np.asarray(x)
    absmax = np.abs(xn).max(axis=(-2, -1), keepdims=True)
    assert (np.abs(y - xn) <= bound * absmax + 1e-7).all()
    assert (y[0, 2] == 0.0).all()


def test_scaled_payload_dtype_and_scale_shape():
    codec = wire.CODECS["int8"]
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 4, 8), jnp.float32)
    payload, scale = codec.encode(x, block_ndim=2)
    assert payload.dtype == jnp.int8
    assert payload.shape == x.shape
    assert scale.dtype == jnp.float32
    assert scale.shape == (2, 3)               # one scale per [4, 8] block
    assert int(np.abs(np.asarray(payload)).max()) <= 127


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 8),
       st.sampled_from(["int8", "fp8e4m3"]),
       st.floats(0.01, 100.0))
def test_scale_conservation_property(nd, el, c, name, amp):
    """Property: for any [num_dests, E_l, C, d] buffer, each (dest,
    expert) block's scale is its absmax / qmax, zero-filled slack rows
    never inflate a block's scale, and the round trip respects the
    per-block bound at any amplitude."""
    codec = wire.CODECS[name]
    rng = np.random.default_rng(nd * 100 + el * 10 + c)
    x = (amp * rng.standard_normal((nd, el, c + 2, 8))).astype(np.float32)
    x[:, :, c:] = 0.0                          # routing's zero slack rows
    payload, scale = codec.encode(jnp.asarray(x), block_ndim=2)
    absmax = np.abs(x).max(axis=(-2, -1))
    want = np.where(absmax > 0, absmax, codec.qmax) / codec.qmax
    np.testing.assert_allclose(np.asarray(scale), want, rtol=1e-6)
    y = np.asarray(codec.decode(payload, scale[..., None, None],
                                jnp.float32))
    bound = (0.5 / 127.0) if name == "int8" else 0.0625
    assert (np.abs(y - x) <= bound * absmax[..., None, None] + 1e-7).all()
    assert (y[:, :, c:] == 0.0).all()          # slack rows stay exact zero


# ---------------------------------------------------------------------------
# codec through the transport + engine (single device)
# ---------------------------------------------------------------------------

D, F, N, K, T = 16, 32, 4, 2, 64


def _setup(key, capacity_factor=8.0):
    cfg = dispatch_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                                 capacity_factor=capacity_factor,
                                 dtype=jnp.float32)
    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dispatch_lib.init_moe_params(key, cfg, ep, gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=capacity_factor, num_pods=1,
                     ep_per_pod=1, mode="even")
    return cfg, ep, gate_cfg, params, plan


def _apply(mesh, params, x, cfg, ep, gate_cfg, **kw):
    eng = dispatch_lib.make_engine("a2a", cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                   **kw)
    body = shard_map(lambda p, xx: eng(p, xx), mesh=mesh,
                     in_specs=(P(), P()), out_specs=(P(), P()),
                     check_vma=False)
    with mesh:
        return body(params, x)


@pytest.mark.parametrize("codec", ("bf16", "int8", "fp8e4m3"))
def test_engine_output_close_under_codec(key, mesh11, codec):
    """The a2a engine with each registered codec must stay close to the
    raw-wire engine — the wire (and, for int8, the quantized expert
    GEMMs) only add bounded low-precision noise."""
    cfg, ep, gate_cfg, params, plan = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(2), (T, D), jnp.float32)
    y_raw, m_raw = _apply(mesh11, params, x, cfg, ep, gate_cfg, plan=plan)
    cfg_c = dataclasses.replace(cfg, wire_codec=codec)
    y_c, m_c = _apply(mesh11, params, x, cfg_c, ep, gate_cfg, plan=plan)
    ref = np.abs(np.asarray(y_raw)).max()
    err = np.abs(np.asarray(y_c) - np.asarray(y_raw)).max()
    assert err < 0.08 * max(ref, 1.0), (codec, err, ref)
    # routing metadata is exact: the codec must not move any token
    np.testing.assert_allclose(float(m_c["dropped"]),
                               float(m_raw["dropped"]), atol=1e-6)


def test_engine_grads_flow_through_scaled_wire(key, mesh11):
    """Straight-through backward: with the int8 codec the loss still
    differentiates to every expert weight and to the tokens (round/int8
    casts would otherwise zero the whole dispatch path)."""
    cfg, ep, gate_cfg, params, plan = _setup(key)
    cfg = dataclasses.replace(cfg, wire_codec="int8")
    eng = dispatch_lib.make_engine("a2a", cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                   plan=plan)

    def loss(p, xx):
        y, _ = eng(p, xx)
        return jnp.sum(y ** 2)

    x = jax.random.normal(jax.random.PRNGKey(3), (T, D), jnp.float32)
    fn = shard_map(jax.grad(loss, argnums=(0, 1)), mesh=mesh11,
                   in_specs=(P(), P()), out_specs=(P(), P()),
                   check_vma=False)
    with mesh11:
        gp, gx = fn(params, x)
    for name in ("w_in", "w_gate", "w_out"):
        g = np.asarray(gp[name])
        assert np.isfinite(g).all() and np.abs(g).max() > 0, name
    gx = np.asarray(gx)
    assert np.isfinite(gx).all() and np.abs(gx).max() > 0


# ---------------------------------------------------------------------------
# quantized ragged grouped GEMM vs references
# ---------------------------------------------------------------------------


def _ragged_case(seed, widths, d=16, f=32, dtype=jnp.float32):
    from repro.core.dispatch.transport import stage_segments
    E = len(widths)
    offs, exps = stage_segments(E, ((1, max(widths) + 1),))
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    R = offs[-1]
    x = jax.random.normal(k1, (R, d), dtype)
    # zero the slack rows (routing's valid-prefix convention)
    rows_valid = jnp.asarray(widths, jnp.int32)
    mask = np.zeros((R,), np.float32)
    for s, w in enumerate(widths):
        mask[offs[s]:offs[s] + w] = 1.0
    x = x * jnp.asarray(mask)[:, None]
    w_in = jax.random.normal(k2, (E, d, f), dtype) / np.sqrt(d)
    w_gate = jax.random.normal(k3, (E, d, f), dtype) / np.sqrt(d)
    w_out = jax.random.normal(k4, (E, f, d), dtype) / np.sqrt(f)
    return offs, exps, rows_valid, x, w_in, w_gate, w_out


@pytest.mark.parametrize("use_pallas", (False, True))
@pytest.mark.parametrize("widths", [(5, 0, 7, 3), (8, 8, 8, 8),
                                    (0, 0, 0, 0), (1, 2, 0, 6)])
def test_quant_gemm_matches_fp_reference(use_pallas, widths):
    """grouped_ffn_ragged_quant (jnp quant ref and Pallas interpret) vs
    the full-precision ragged reference: int8 per-segment quantization
    error only, and exact zeros on invalid rows."""
    from repro.kernels.moe_gemm import ops, ref
    offs, exps, rows_valid, x, w_in, w_gate, w_out = _ragged_case(7, widths)
    y_fp = ref.grouped_ffn_ragged_ref(x, offs, exps, rows_valid,
                                      w_in, w_gate, w_out)
    y_q = ops.grouped_ffn_ragged_quant(x, offs, exps, rows_valid,
                                       w_in, w_gate, w_out,
                                       use_pallas=use_pallas)
    ref_mag = max(float(np.abs(np.asarray(y_fp)).max()), 1e-3)
    err = float(np.abs(np.asarray(y_q) - np.asarray(y_fp)).max())
    assert err < 0.05 * ref_mag, (err, ref_mag)
    # invalid rows are exactly zero on the quant path too
    yq = np.asarray(y_q)
    for s, w in enumerate(widths):
        assert (yq[offs[s] + w:offs[s + 1]] == 0.0).all(), s


def test_quant_gemm_kernel_matches_quant_ref_exactly():
    """The Pallas kernel (interpret mode on CPU) and the jnp quant
    reference share the quantization recipe bit-for-bit."""
    from repro.kernels.moe_gemm import ops
    offs, exps, rows_valid, x, w_in, w_gate, w_out = _ragged_case(
        11, (6, 3, 0, 8))
    y_ref = ops.grouped_ffn_ragged_quant(x, offs, exps, rows_valid,
                                         w_in, w_gate, w_out,
                                         use_pallas=False)
    y_k = ops.grouped_ffn_ragged_quant(x, offs, exps, rows_valid,
                                       w_in, w_gate, w_out, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_ref))


def test_quant_gemm_grads_flow():
    """AQT convention: quantized forward, full-precision backward — the
    custom_vjp must hand nonzero finite grads to x and all three weights."""
    from repro.kernels.moe_gemm import ops
    offs, exps, rows_valid, x, w_in, w_gate, w_out = _ragged_case(
        13, (5, 2, 7, 1))

    def loss(xx, wi, wg, wo):
        y = ops.grouped_ffn_ragged_quant(xx, offs, exps, rows_valid,
                                         wi, wg, wo, use_pallas=False)
        return jnp.sum(y ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(x, w_in, w_gate, w_out)
    for name, g in zip(("x", "w_in", "w_gate", "w_out"), grads):
        g = np.asarray(g)
        assert np.isfinite(g).all() and np.abs(g).max() > 0, name


# ---------------------------------------------------------------------------
# byte accounting: quantized wire bytes drive the chunk chooser
# ---------------------------------------------------------------------------


def test_a2a_bytes_uses_wire_dtype_plus_scale_sideband():
    plan = make_dispatch_plan(tokens_per_device=64, num_experts=16, top_k=2,
                              capacity_factor=2.0, axis_sizes=(2, 2),
                              mode="ta")
    raw = a2a_bytes(plan, d_model=64, bytes_per_el=4)
    q = a2a_bytes(plan, d_model=64, bytes_per_el=4, codec="int8")
    E = plan.experts_per_rank
    for s in range(plan.num_stages):
        if not plan.caps[s]:
            continue
        segs = E * plan.stage_dests(s)
        # payload shrinks 4x, plus one f32 scale per segment
        assert q["by_level"][s] == raw["by_level"][s] // 4 + segs * 4
    # cast codec: pure element-size rescale, no sideband
    h = a2a_bytes(plan, d_model=64, bytes_per_el=4, codec="bf16")
    assert tuple(h["by_level"]) == tuple(b // 2 for b in raw["by_level"])


def test_codec_swap_changes_chunk_verdict():
    """Acceptance hook: the chunk chooser sees quantized wire bytes, so
    swapping bf16 -> int8 at matched shapes flips its verdict (smaller
    exchanges stop amortizing the per-collective alpha as well)."""
    from repro.core.comm_model import choose_num_chunks, moe_overlap_terms
    plan = make_dispatch_plan(tokens_per_device=512, num_experts=32,
                              top_k=2, capacity_factor=2.0,
                              axis_sizes=(4, 8), mode="ta")
    kw = dict(d_model=1024, d_ff=2048, bytes_per_el=2)
    verdicts = {}
    for codec in ("bf16", "int8"):
        terms = moe_overlap_terms(plan, codec=codec, **kw)
        verdicts[codec] = choose_num_chunks(
            t_exchange=terms["t_exchange"], t_compute=terms["t_compute"],
            alpha=terms["alpha"])
    t_bf16 = moe_overlap_terms(plan, codec="bf16", **kw)["t_exchange"]
    t_int8 = moe_overlap_terms(plan, codec="int8", **kw)["t_exchange"]
    assert t_int8 < t_bf16 / 1.9               # ~2x fewer wire bytes
    assert verdicts["int8"] != verdicts["bf16"], verdicts
    assert verdicts["int8"] < verdicts["bf16"]


# ---------------------------------------------------------------------------
# multi-rank parity (slow subprocess lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_int8_wire_trains_at_parity_with_bf16_wire():
    """4-rank EP (2 pods x 2): short training runs with the int8 wire
    codec (quantized payloads + scale sideband + quantized expert GEMMs +
    straight-through backward) must track the bf16-wire run's loss curve
    — quantization noise, not divergence."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import dataclasses
        import numpy as np
        from repro.configs.base import RunConfig, get_config
        from repro.compat import make_mesh
        from repro.training import trainer

        mesh = make_mesh((2, 2, 1), ("pod", "data", "model"))
        arch = get_config("gpt3_medium_moe").reduced()
        arch = dataclasses.replace(arch, moe=dataclasses.replace(
            arch.moe, num_experts=8, top_k=2, capacity_factor=4.0))
        base = dict(seq_len=32, global_batch=8, learning_rate=1e-3,
                    total_steps=8, warmup_steps=2, aux_mode="ta")
        runs = {}
        for codec in ("bf16", "int8"):
            r = trainer.train(arch, RunConfig(**base, wire_codec=codec),
                              mesh, steps=6, log_every=1, verbose=False,
                              data_seed=0)
            runs[codec] = np.asarray(r.losses)
            assert np.isfinite(runs[codec]).all(), (codec, r.losses)
        # both make progress at some point (short runs are noisy) and
        # stay within a few percent of each other step-for-step
        for codec, losses in runs.items():
            assert losses.min() < losses[0], (codec, losses)
        rel = np.abs(runs["int8"] - runs["bf16"]) / np.abs(runs["bf16"])
        print("REL", [round(float(v), 4) for v in rel])
        assert float(rel.max()) < 0.12, rel
        print("INT8-PARITY-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    assert "INT8-PARITY-OK" in r.stdout


@pytest.mark.slow
def test_scale_sideband_rides_multilevel_chains():
    """Real 2- and 3-level meshes: the int8-codec a2a engine must stay
    close to the raw-wire engine — the per-(destination, expert) scales
    land next to the right segments after every hop of the chain, on both
    dispatch and combine."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import dispatch as dl, gating
        from repro.core.capacity import make_dispatch_plan

        D, F, N, K, T = 16, 32, 8, 2, 32
        for shape in ((2, 2), (2, 2, 2)):
            names = ("pod", "data") if len(shape) == 2 \\
                else ("pod", "node", "data")
            mesh = make_mesh(shape, names)
            ranks = int(np.prod(shape))
            cfg = dl.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                               capacity_factor=8.0, dtype=jnp.float32)
            ep = dl.EPSpec.from_axes(names, shape)
            gate_cfg = gating.GateConfig(num_experts=N, top_k=K,
                                         aux_mode="ta")
            params = dl.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                        gate_cfg)
            plan = make_dispatch_plan(
                tokens_per_device=T, num_experts=N, top_k=K,
                capacity_factor=8.0, axis_sizes=shape, mode="ta",
                round_multiple=1)
            assert all(c > 0 for c in plan.caps)
            x = jax.random.normal(jax.random.PRNGKey(1), (ranks * T, D),
                                  jnp.float32)
            pspecs = {"gate": {"w": P()},
                      "w_in": P(names, None, None),
                      "w_gate": P(names, None, None),
                      "w_out": P(names, None, None)}

            def run(c):
                eng = dl.make_engine("a2a", cfg=c, ep=ep,
                                     gate_cfg=gate_cfg, plan=plan)
                fn = shard_map(lambda p, xx: eng(p, xx)[0], mesh=mesh,
                               in_specs=(pspecs, P(names, None)),
                               out_specs=P(names, None), check_vma=False)
                with mesh:
                    return np.asarray(fn(params, x))

            y_raw = run(cfg)
            y_q = run(dataclasses.replace(cfg, wire_codec="int8"))
            ref = max(float(np.abs(y_raw).max()), 1.0)
            err = float(np.abs(y_q - y_raw).max())
            print(shape, "ERR", err, "REF", ref)
            assert err < 0.08 * ref, (shape, err, ref)
        print("SCALE-CHAIN-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    assert "SCALE-CHAIN-OK" in r.stdout
