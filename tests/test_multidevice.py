"""Multi-device behaviour via subprocesses (XLA host-device forcing must
happen before jax import, so these cannot run in the pytest process).

Covers: hierarchical a2a dispatch correctness across ranks, TA-vs-even
collective-byte reduction on a 2-pod mesh, and a miniature dry-run."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(ndev: int, code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_a2a_dispatch_matches_dense_across_ranks():
    """4-rank EP (2 pods x 2): hierarchical a2a output == dense reference."""
    out = _run(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import gating, moe as moe_lib
        from repro.core.capacity import make_plan

        mesh = make_mesh((2, 2), ("pod", "data"))
        D, F, N, K, T = 16, 32, 8, 2, 32   # T per rank
        cfg = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                                capacity_factor=8.0, dtype=jnp.float32)
        ep = moe_lib.EPSpec(num_pods=2, ep_per_pod=2, pod_axis="pod",
                            data_axis="data", model_axis=None)
        gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
        params = moe_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                         gate_cfg)
        plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                         capacity_factor=8.0, num_pods=2, ep_per_pod=2,
                         mode="even")
        x = jax.random.normal(jax.random.PRNGKey(1), (4 * T, D), jnp.float32)

        def body(p, xx):
            y, m = moe_lib.moe_apply_a2a(p, xx, cfg, ep, plan, gate_cfg)
            return y
        pspecs = {"gate": {"w": P()},
                  "w_in": P(("pod", "data"), None, None),
                  "w_gate": P(("pod", "data"), None, None),
                  "w_out": P(("pod", "data"), None, None)}
        fn = shard_map(body, mesh=mesh,
                       in_specs=(pspecs, P(("pod", "data"), None)),
                       out_specs=P(("pod", "data"), None), check_vma=False)
        with mesh:
            y = fn(params, x)

        # dense reference on the full batch
        out = gating.gate_forward(params["gate"], x, gate_cfg, None)
        want = jnp.zeros_like(x)
        for e in range(N):
            h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_in"][e])
            fe = h @ params["w_out"][e]
            w = jnp.sum(jnp.where(out["topk_idx"] == e,
                                  out["topk_weight"], 0.0), axis=1)
            want = want + fe * w[:, None]
        err = float(jnp.abs(y - want).max())
        print("ERR", err)
        assert err < 1e-3, err
    """)
    assert "ERR" in out


@pytest.mark.slow
def test_pipelined_matches_a2a_across_ranks():
    """4-rank EP (2 pods x 2): the chunked comm–compute-overlap schedule
    must be allclose to the sync a2a path at matched capacities, for every
    chunk count, including the TA (hierarchical near/far) plan."""
    out = _run(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import capacity, gating, moe as moe_lib

        mesh = make_mesh((2, 2), ("pod", "data"))
        D, F, N, K, T = 16, 32, 8, 2, 32   # T per rank
        cfg = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                                capacity_factor=4.0, dtype=jnp.float32)
        ep = moe_lib.EPSpec(num_pods=2, ep_per_pod=2, pod_axis="pod",
                            data_axis="data", model_axis=None)
        gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="ta")
        params = moe_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                         gate_cfg)
        plan = capacity.make_plan(tokens_per_device=T, num_experts=N,
                                  top_k=K, capacity_factor=4.0, num_pods=2,
                                  ep_per_pod=2, mode="ta", round_multiple=1)
        assert plan.cap_far > 0   # exercise both exchange levels
        x = jax.random.normal(jax.random.PRNGKey(1), (4 * T, D), jnp.float32)
        pspecs = {"gate": {"w": P()},
                  "w_in": P(("pod", "data"), None, None),
                  "w_gate": P(("pod", "data"), None, None),
                  "w_out": P(("pod", "data"), None, None)}

        def run(body):
            fn = shard_map(body, mesh=mesh,
                           in_specs=(pspecs, P(("pod", "data"), None)),
                           out_specs=P(("pod", "data"), None),
                           check_vma=False)
            with mesh:
                return fn(params, x)

        y0 = run(lambda p, xx: moe_lib.moe_apply_a2a(
            p, xx, cfg, ep, plan, gate_cfg)[0])
        for k in (1, 2, 3, 4):
            # matched capacities: sync and pipelined on the aligned plan
            pk = capacity.align_to_chunks(plan, k)
            ys = run(lambda p, xx, pk=pk: moe_lib.moe_apply_a2a(
                p, xx, cfg, ep, pk, gate_cfg)[0])
            yp = run(lambda p, xx, pk=pk, kk=k:
                     moe_lib.moe_apply_a2a_pipelined(
                         p, xx, cfg, ep, pk, gate_cfg, num_chunks=kk)[0])
            err = float(jnp.abs(yp - ys).max())
            print("CHUNKS", k, "ERR", err)
            assert err < 1e-4, (k, err)
        # unaligned plan: internal zero-padding must also reproduce sync
        y3 = run(lambda p, xx: moe_lib.moe_apply_a2a_pipelined(
            p, xx, cfg, ep, plan, gate_cfg, num_chunks=3)[0])
        err = float(jnp.abs(y3 - y0).max())
        print("PAD ERR", err)
        assert err < 1e-4, err
        print("PIPELINED-OK")
    """)
    assert "PIPELINED-OK" in out


@pytest.mark.slow
def test_ta_reduces_crosspod_bytes_vs_even():
    """On a (2,2,1) mesh the TA plan must shrink the far a2a buffers and
    therefore cross-pod wire bytes in the compiled HLO."""
    out = _run(4, """
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config, RunConfig
        from repro.models import model as model_lib
        from repro.training import trainer as trainer_lib
        from repro import sharding
        from repro.launch import analysis
        from repro.optim import adamw

        from repro.compat import make_mesh
        mesh = make_mesh((2, 2, 1), ("pod", "data", "model"))
        arch = get_config("gpt3_medium_moe").reduced()
        import dataclasses
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(arch.moe, num_experts=4, top_k=2))
        res = {}
        for mode in ("lb", "ta"):
            ctx = model_lib.build_ctx(arch, mesh, seq_len=64,
                                      global_batch=8, aux_mode=mode)
            rules = model_lib.default_rules(mesh)
            run = RunConfig(seq_len=64, global_batch=8, aux_mode=mode)
            with mesh, sharding.axis_rules(rules):
                ap = model_lib.abstract_params(jax.random.PRNGKey(0), ctx)
                specs = model_lib.input_specs(arch, "train_4k", mesh, ctx=ctx)
                # shrink to this test's shape
                import jax as j
                specs = {k: j.ShapeDtypeStruct((8, 64), v.dtype,
                                               sharding=v.sharding)
                         for k, v in specs.items() if k != "frontend"}
                aopt = j.eval_shape(adamw.init_state, ap)
                step = trainer_lib.make_train_step(ctx, run)
                lowered = j.jit(step).lower(ap, aopt, specs)
                comp = lowered.compile()
                st = analysis.collective_stats(comp.as_text(),
                                               num_devices=4,
                                               devices_per_pod=2)
                res[mode] = (st.ici_bytes, st.dci_bytes)
        print("LB", res["lb"], "TA", res["ta"])
        assert res["ta"][1] < res["lb"][1], (res)
    """)
    assert "TA" in out


@pytest.mark.slow
def test_three_level_engine_matches_einsum_oracle():
    """8-rank EP on a 3-tier 2x2x2 (pod x node x data) mesh: the
    level-indexed a2a and a2a_pipelined paths must match the einsum oracle
    (computed on the replicated full batch) at matched ample capacities,
    with a length-3 frac_by_level exercising every level."""
    out = _run(8, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import capacity, dispatch as dl, gating

        mesh = make_mesh((2, 2, 2), ("pod", "node", "data"))
        D, F, N, K, T = 16, 32, 8, 2, 32   # T per rank
        cfg = dl.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                           capacity_factor=8.0, dtype=jnp.float32)
        ep = dl.EPSpec.from_axes(("pod", "node", "data"), (2, 2, 2))
        gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
        params = dl.init_moe_params(jax.random.PRNGKey(0), cfg, ep, gate_cfg)
        plan = capacity.make_dispatch_plan(
            tokens_per_device=T, num_experts=N, top_k=K,
            capacity_factor=8.0, axis_sizes=(2, 2, 2), mode="ta",
            round_multiple=1)
        assert plan.num_stages == 3 and all(c > 0 for c in plan.caps)
        x = jax.random.normal(jax.random.PRNGKey(1), (8 * T, D), jnp.float32)
        ep_axes = ("pod", "node", "data")
        pspecs = {"gate": {"w": P()},
                  "w_in": P(ep_axes, None, None),
                  "w_gate": P(ep_axes, None, None),
                  "w_out": P(ep_axes, None, None)}

        def run(name, **kw):
            eng = dl.make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                 **kw)
            fn = shard_map(lambda p, xx: eng(p, xx), mesh=mesh,
                           in_specs=(pspecs, P(ep_axes, None)),
                           out_specs=(P(ep_axes, None),
                                      {k: P() for k in dl.METRIC_KEYS}),
                           check_vma=False)
            with mesh:
                y, m = fn(params, x)
            return np.asarray(y), m

        # einsum oracle: shard-local path on the replicated full batch
        ep1 = dl.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                        data_axis="data", model_axis=None)
        eng_o = dl.make_engine("einsum", cfg=cfg, ep=ep1, gate_cfg=gate_cfg,
                               capacity=8 * T)
        fn_o = shard_map(lambda p, xx: eng_o(p, xx)[0], mesh=mesh,
                         in_specs=(P(), P()), out_specs=P(), check_vma=False)
        with mesh:
            y_oracle = np.asarray(fn_o(params, x))

        y_ref, m_ref = run("a2a", plan=plan)
        fb = np.asarray(m_ref["frac_by_level"]).reshape(-1)[:3]
        assert fb.shape == (3,), fb.shape
        assert abs(fb.sum() - 1.0) < 1e-5
        assert (fb > 0.0).all()          # every level exercised
        err = float(np.abs(y_ref - y_oracle).max())
        print("A2A-VS-EINSUM ERR", err)
        assert err < 1e-3, err
        for k in (1, 2, 3):
            yk, mk = run("a2a_pipelined", plan=capacity.align_to_chunks(
                plan, k), num_chunks=k)
            err = float(np.abs(yk - y_oracle).max())
            print("CHUNKS", k, "ERR", err)
            assert err < 1e-3, (k, err)
        print("THREE-LEVEL-ORACLE-OK")
    """)
    assert "THREE-LEVEL-ORACLE-OK" in out


@pytest.mark.slow
def test_three_level_topology_trainer_end_to_end():
    """Acceptance: the nested [[2, 2], [2, 2]] spec runs a2a and
    a2a_pipelined end-to-end through build_ctx -> trainer on 8 fake
    devices, reporting a length-3 frac_by_level in the metrics, with
    pipelined losses allclose to sync; existing 2-level plans stay
    byte-identical through the compat aliases."""
    out = _run(8, """
        import dataclasses
        import numpy as np
        from repro.configs.base import RunConfig, get_config
        from repro.launch.mesh import mesh_from_topology
        from repro.models import model as model_lib
        from repro.training import trainer

        mesh = mesh_from_topology([[2, 2], [2, 2]])
        assert mesh.axis_names == ("pod", "node", "data", "model")
        arch = get_config("gpt3_medium_moe").reduced()
        arch = dataclasses.replace(arch, moe=dataclasses.replace(
            arch.moe, num_experts=8, top_k=2, capacity_factor=8.0))
        ctx = model_lib.build_ctx(arch, mesh, seq_len=32, global_batch=8,
                                  aux_mode="ta")
        assert ctx.plan.num_stages == 3, ctx.plan
        assert ctx.plan.level_axes == (("data",), ("node", "data"),
                                       ("pod", "node", "data"))
        assert ctx.plan.caps[0] > ctx.plan.caps[1] > ctx.plan.caps[2] > 0
        # deprecated aliases stay live on the N-level plan
        assert ctx.plan.cap_near == ctx.plan.caps[0]
        assert ctx.plan.cap_far == ctx.plan.caps[1]

        base = dict(seq_len=32, global_batch=8, learning_rate=1e-3,
                    total_steps=6, warmup_steps=2, aux_mode="ta")
        r_sync = trainer.train(arch, RunConfig(**base), mesh, steps=3,
                               log_every=1, verbose=False, data_seed=0)
        fb = r_sync.metrics_history[-1]["frac_by_level"]
        assert len(fb) == 3, fb
        assert abs(sum(fb) - 1.0) < 1e-4
        r_pipe = trainer.train(
            arch, RunConfig(**base, dispatch="a2a_pipelined",
                            a2a_num_chunks=2),
            mesh, steps=3, log_every=1, verbose=False, data_seed=0)
        np.testing.assert_allclose(r_pipe.losses, r_sync.losses, rtol=1e-4)
        print("FRAC", [round(v, 3) for v in fb])
        print("THREE-LEVEL-TRAINER-OK")
    """)
    assert "THREE-LEVEL-TRAINER-OK" in out


@pytest.mark.slow
def test_mini_dryrun_8dev():
    """The dry-run machinery end-to-end on a small 2x2x2 mesh."""
    out = _run(8, """
        import jax, jax.numpy as jnp
        import repro.launch.dryrun as dr
        # monkeypatch production mesh to the mini mesh
        import repro.launch.mesh as mesh_lib
        from repro.compat import make_mesh
        def mini(multi_pod=False):
            shape = (2, 2, 2) if multi_pod else (4, 2)
            axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            return make_mesh(shape, axes)
        dr.make_production_mesh = mini
        import dataclasses
        from repro.configs import base
        # shrink shapes for CPU feasibility
        base.INPUT_SHAPES["train_4k"] = dict(seq_len=32, global_batch=8,
                                             kind="train")
        base.INPUT_SHAPES["decode_32k"] = dict(seq_len=64, global_batch=8,
                                               kind="decode")
        orig = base.get_config
        base.get_config = lambda a: orig(a).reduced()
        dr.get_config = base.get_config
        dr.INPUT_SHAPES = base.INPUT_SHAPES
        for shape in ("train_4k", "decode_32k"):
            for multi in (False, True):
                rec, comp = dr.lower_one("gpt3_medium_moe", shape, multi)
                assert rec["status"] == "ok", rec
                print(shape, rec["mesh"], rec["dominant"],
                      int(rec["flops_per_chip"]))
        print("MINI-DRYRUN-OK")
    """)
    assert "MINI-DRYRUN-OK" in out


@pytest.mark.slow
def test_fused_local_path_across_ranks_with_kernels_on():
    """Kernels live (REPRO_KERNEL_INTERPRET=1) on real multi-rank meshes:
    (a) on a 2x2 EP mesh no stage has an identity delivery chain, so the
    fused megakernel must stay dormant and the staged a2a path must still
    match the dense reference; (b) on a 2-pod mesh with a unit inner axis,
    stage 0 fuses (local megakernel) while the pod stage keeps its a2a
    chain — the two contributions must add back to the dense reference."""
    out = _run(4, """
        import os
        os.environ["REPRO_KERNEL_INTERPRET"] = "1"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import dispatch as dispatch_lib, gating
        from repro.core.capacity import make_plan
        from repro.core.dispatch.transport import plan_stages

        def dense_ref(params, x, N):
            out = gating.gate_forward(params["gate"], x,
                                      gate_cfg, None)
            want = jnp.zeros_like(x)
            for e in range(N):
                h = (jax.nn.silu(x @ params["w_gate"][e])
                     * (x @ params["w_in"][e]))
                fe = h @ params["w_out"][e]
                w = jnp.sum(jnp.where(out["topk_idx"] == e,
                                      out["topk_weight"], 0.0), axis=1)
                want = want + fe * w[:, None]
            return want

        D, F, N, K, T = 16, 32, 8, 2, 32   # T per rank
        for shape, pods, per_pod in (((2, 2), 2, 2), ((2, 1), 2, 1)):
            mesh = make_mesh(shape, ("pod", "data"))
            ranks = shape[0] * shape[1]
            cfg = dispatch_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N,
                                         top_k=K, capacity_factor=8.0,
                                         dtype=jnp.float32)
            ep = dispatch_lib.EPSpec(num_pods=pods, ep_per_pod=per_pod,
                                     pod_axis="pod", data_axis="data",
                                     model_axis=None)
            gate_cfg = gating.GateConfig(num_experts=N, top_k=K,
                                         aux_mode="lb")
            params = dispatch_lib.init_moe_params(jax.random.PRNGKey(0),
                                                  cfg, ep, gate_cfg)
            plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                             capacity_factor=8.0, num_pods=pods,
                             ep_per_pod=per_pod, mode="even")
            stages = plan_stages(plan, ep)
            fusable = [s.num_dests == 1 for s in stages]
            print("mesh", shape, "num_dests",
                  [s.num_dests for s in stages])
            # shape (2,2): nothing local; shape (2,1): stage 0 is
            assert fusable == ([False, False] if shape == (2, 2)
                               else [True, False]), fusable
            x = jax.random.normal(jax.random.PRNGKey(1), (ranks * T, D),
                                  jnp.float32)
            eng = dispatch_lib.make_engine("a2a", cfg=cfg, ep=ep,
                                           gate_cfg=gate_cfg, plan=plan,
                                           use_pallas=None)
            pspecs = {"gate": {"w": P()},
                      "w_in": P(("pod", "data"), None, None),
                      "w_gate": P(("pod", "data"), None, None),
                      "w_out": P(("pod", "data"), None, None)}
            fn = shard_map(lambda p, xx: eng(p, xx)[0], mesh=mesh,
                           in_specs=(pspecs, P(("pod", "data"), None)),
                           out_specs=P(("pod", "data"), None),
                           check_vma=False)
            with mesh:
                y = fn(params, x)
            err = float(jnp.abs(y - dense_ref(params, x, N)).max())
            print("ERR", shape, err)
            assert err < 1e-3, (shape, err)
        print("FUSED-MULTIRANK-OK")
    """)
    assert "FUSED-MULTIRANK-OK" in out


@pytest.mark.slow
def test_degraded_link_replan_flips_dispatch_local_heavy():
    """Resilience chaos: a 64x beta degradation on the pod axis from step 2
    must make the recovery policy re-solve the Eq. (7) plan at the next
    replan boundary with the cross-pod level collapsed to 0 capacity
    (local-heavy dispatch), and training must continue with finite loss."""
    out = _run(4, """
        import math
        from repro.configs.base import get_config, RunConfig
        from repro.compat import make_mesh
        from repro.training import trainer
        from repro.resilience import ChaosConfig, ResilienceConfig

        arch = get_config("gpt3_medium_moe").reduced()
        mesh = make_mesh((2, 2, 1), ("pod", "data", "model"))
        run = RunConfig(seq_len=32, global_batch=4, total_steps=8,
                        warmup_steps=2, aux_mode="ta", seed=0,
                        resilience=ResilienceConfig(
                            replan_every=4, degrade_threshold=4.0,
                            collapse_slowdown=64.0,
                            chaos=ChaosConfig(
                                degraded_links=((2, "pod", 64.0),))))
        r = trainer.train(arch, run, mesh, steps=8, log_every=1,
                          verbose=True)
        assert r.replans == 1, r.replans
        assert all(math.isfinite(l) for l in r.losses), r.losses
        assert r.metrics_history[-1]["replans"] == 1
        print("REPLAN-OK")
    """)
    assert "REPLAN-OK" in out
    assert "replan: caps -> (64, 0)" in out    # cross-pod level collapsed
