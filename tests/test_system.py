"""End-to-end behaviour tests: training convergence, TA-vs-LB parity (the
paper's Fig. 3 claim in miniature), grad-accumulation equivalence,
checkpoint-resume, and serving generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.configs.base import RunConfig, get_config
from repro.models import model as model_lib
from repro.serving import engine
from repro.training import trainer


def test_loss_decreases_dense(mesh11):
    arch = get_config("olmo_1b").reduced()
    run = RunConfig(seq_len=32, global_batch=4, learning_rate=1e-3,
                    total_steps=30, warmup_steps=2, aux_mode="none")
    res = trainer.train(arch, run, mesh11, steps=25, log_every=5,
                        verbose=False)
    assert res.losses[-1] < res.losses[0] - 0.3


def test_loss_decreases_moe_with_ta(mesh11):
    arch = get_config("gpt3_medium_moe").reduced()
    run = RunConfig(seq_len=32, global_batch=4, learning_rate=1e-3,
                    total_steps=30, warmup_steps=2, aux_mode="ta")
    res = trainer.train(arch, run, mesh11, steps=25, log_every=5,
                        verbose=False)
    assert res.losses[-1] < res.losses[0] - 0.2
    assert all(np.isfinite(l) for l in res.losses)


def test_ta_and_lb_convergence_parity(mesh11):
    """Paper Fig. 3: TA-MoE must not hurt convergence vs the LB baseline.
    On a single-level topology the penalties coincide, so this checks the
    plumbing end-to-end; heterogeneous-penalty parity is exercised in the
    fig3 benchmark."""
    arch = get_config("gpt3_medium_moe").reduced()
    run = RunConfig(seq_len=32, global_batch=4, learning_rate=1e-3,
                    total_steps=20, warmup_steps=2)
    r_lb = trainer.train(arch, run, mesh11, steps=15, aux_mode="lb",
                         log_every=5, verbose=False)
    r_ta = trainer.train(arch, run, mesh11, steps=15, aux_mode="ta",
                         log_every=5, verbose=False)
    assert abs(r_ta.losses[-1] - r_lb.losses[-1]) < 0.15


def test_grad_accumulation_equivalence(mesh11, key):
    arch = get_config("internlm2_1_8b").reduced()
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.optim import adamw
    data = SyntheticLM(DataConfig(vocab_size=arch.vocab_size, seq_len=16,
                                  global_batch=4), arch)
    batch = data.batch(0)
    rules = model_lib.default_rules(mesh11)
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=16, global_batch=4,
                              aux_mode="none")
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx)
        opt = adamw.init_state(params)
        run_full = RunConfig(seq_len=16, global_batch=4, aux_mode="none")
        run_acc = RunConfig(seq_len=16, global_batch=4, aux_mode="none",
                            microbatch=2)
        p1, _, m1 = jax.jit(trainer.make_train_step(ctx, run_full))(
            params, opt, batch)
        p2, _, m2 = jax.jit(trainer.make_train_step(ctx, run_acc))(
            params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    l1 = np.asarray(jax.tree_util.tree_leaves(p1)[0], np.float32)
    l2 = np.asarray(jax.tree_util.tree_leaves(p2)[0], np.float32)
    np.testing.assert_allclose(l1, l2, atol=1e-4, rtol=1e-3)


def test_checkpoint_resume(tmp_path, mesh11):
    from repro.checkpoint import ckpt
    arch = get_config("olmo_1b").reduced()
    run = RunConfig(seq_len=16, global_batch=2, total_steps=10,
                    warmup_steps=1, aux_mode="none")
    path = str(tmp_path / "m.npz")
    res = trainer.train(arch, run, mesh11, steps=3, verbose=False,
                        ckpt_path=path)
    restored = ckpt.restore(path, {"params": res.params,
                                   "opt": res.opt_state})
    l0 = jax.tree_util.tree_leaves(res.params)[0]
    l1 = jax.tree_util.tree_leaves(restored["params"])[0]
    np.testing.assert_array_equal(np.asarray(l0, np.float32),
                                  np.asarray(l1, np.float32))


def test_generation_runs(mesh11, key):
    arch = get_config("internlm2_1_8b").reduced()
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=2,
                              aux_mode="none")
    rules = model_lib.default_rules(mesh11)
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx)
        prompts = jax.random.randint(key, (2, 4), 0, arch.vocab_size,
                                     jnp.int32)
        res = engine.generate(params, ctx, prompts, steps=6, cache_len=32)
    assert res.tokens.shape == (2, 6)
    assert (np.asarray(res.tokens) >= 0).all()
    assert (np.asarray(res.tokens) < arch.vocab_size).all()
