"""Optimizer, data pipeline, checkpoint, sharding-rule unit tests."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.checkpoint import ckpt
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = adamw.AdamWConfig(learning_rate=0.1, warmup_steps=0,
                                total_steps=200, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init_state(params)

        @jax.jit
        def step(p, s):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            return adamw.apply_updates(p, g, s, cfg)
        for _ in range(150):
            params, state, _ = step(params, state)
        assert np.abs(np.asarray(params["w"])).max() < 0.05

    def test_grad_clip(self):
        cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(3)}
        state = adamw.init_state(params)
        g = {"w": jnp.full(3, 100.0)}
        _, _, m = adamw.apply_updates(params, g, state, cfg)
        assert float(m["grad_norm"]) > 100.0  # reported unclipped

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                                total_steps=100, min_lr_ratio=0.1)
        lr0 = float(adamw.schedule(cfg, jnp.int32(1)))
        lr_w = float(adamw.schedule(cfg, jnp.int32(10)))
        lr_end = float(adamw.schedule(cfg, jnp.int32(100)))
        assert lr0 == pytest.approx(0.1, rel=1e-3)
        assert lr_w == pytest.approx(1.0, rel=1e-3)
        assert lr_end == pytest.approx(0.1, rel=1e-2)

    def test_weight_decay_only_on_matrices(self):
        cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=1.0,
                                warmup_steps=0)
        params = {"m": jnp.ones((2, 2)), "v": jnp.ones((2,))}
        state = adamw.init_state(params)
        g = {"m": jnp.zeros((2, 2)), "v": jnp.zeros((2,))}
        p2, _, _ = adamw.apply_updates(params, g, state, cfg)
        assert float(p2["m"][0, 0]) < 1.0   # decayed
        assert float(p2["v"][0]) == 1.0     # not decayed


class TestData:
    def test_determinism(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        a = SyntheticLM(cfg).batch(5)
        b = SyntheticLM(cfg).batch(5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_labels_are_shifted_stream(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b = SyntheticLM(cfg).batch(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_learnable_structure(self):
        """Motif following makes p(next|cur) non-uniform."""
        cfg = DataConfig(vocab_size=50, seq_len=256, global_batch=8)
        ds = SyntheticLM(cfg)
        b = ds.batch(0)
        toks = np.asarray(b["tokens"])
        hits = 0
        for r in range(toks.shape[0]):
            for t in range(toks.shape[1] - 1):
                if toks[r, t + 1] == ds._next[toks[r, t]]:
                    hits += 1
        frac = hits / (toks.shape[0] * (toks.shape[1] - 1))
        assert frac > 0.3   # ~0.5 by construction

    def test_vlm_frontend_and_mask(self):
        arch = get_config("internvl2_26b").reduced()
        cfg = DataConfig(vocab_size=arch.vocab_size, seq_len=32,
                         global_batch=2)
        b = SyntheticLM(cfg, arch).batch(0)
        assert b["frontend"].shape == (2, arch.frontend_len, 1024)
        assert float(b["loss_mask"][:, :arch.frontend_len].sum()) == 0.0

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        ds = SyntheticLM(cfg)
        assert not np.array_equal(np.asarray(ds.batch(0)["tokens"]),
                                  np.asarray(ds.batch(1)["tokens"]))


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, key):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jax.random.normal(key, (4,)),
                      "d": jnp.int32(7)}}
        path = str(tmp_path / "ck.npz")
        ckpt.save(path, tree, step=42)
        out = ckpt.restore(path, tree)
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert ckpt.latest_step(path) == 42

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        ckpt.save(path, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="key 'a' has shape"):
            ckpt.restore(path, {"a": jnp.zeros((3,))})


class TestShardingRules:
    def test_divisibility_fallback(self, mesh11):
        rules = sharding.AxisRules({"model": "model"}, mesh=mesh11)
        with sharding.axis_rules(rules):
            spec = sharding.logical_spec("model", dims=(7,))
            # 7 % 1 == 0 on the 1-wide mesh — sharding kept
            assert spec == jax.sharding.PartitionSpec("model")

    def test_param_specs_by_path(self, mesh11):
        from jax.sharding import PartitionSpec as P
        params = {"layer": {"ffn": {"w_in": jnp.zeros((4, 8))},
                            "norm": {"scale": jnp.zeros((4,))}}}
        rules = sharding.AxisRules({"model": "model"}, mesh=mesh11)
        with sharding.axis_rules(rules):
            specs = sharding.build_param_specs(
                params, [(r"ffn/w_in", P(None, "model"))])
        assert specs["layer"]["ffn"]["w_in"] == P(None, "model")
        assert specs["layer"]["norm"]["scale"] == P()

    def test_constrain_noop_without_rules(self):
        x = jnp.ones((4, 4))
        y = sharding.constrain(x, "batch", None)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
