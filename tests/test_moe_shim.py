"""The repro.core.moe deprecation shim: every ``moe_apply_*`` wrapper must
emit a DeprecationWarning on use while still resolving through the
core/dispatch engine with the level-indexed metrics schema."""

import jax
import jax.numpy as jnp
import pytest

from repro.compat import shard_map
from repro.core import gating, moe as moe_lib
from repro.core.capacity import make_plan

D, F, N, K, T = 16, 32, 4, 2, 32


@pytest.fixture(scope="module")
def setup():
    cfg = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                            capacity_factor=8.0, dtype=jnp.float32)
    ep = moe_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                        data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = moe_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep, gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=8.0, num_pods=1, ep_per_pod=1,
                     mode="even")
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
    return cfg, ep, gate_cfg, params, plan, x


def _run(fn, mesh, params, x):
    from jax.sharding import PartitionSpec as P
    body = shard_map(fn, mesh=mesh, in_specs=(P(), P()),
                     out_specs=(P(), P()), check_vma=False)
    with mesh:
        return body(params, x)


def _cases(setup):
    cfg, ep, gate_cfg, params, plan, x = setup
    return {
        "moe_apply_a2a": lambda p, xx: moe_lib.moe_apply_a2a(
            p, xx, cfg, ep, plan, gate_cfg),
        "moe_apply_a2a_pipelined": lambda p, xx: moe_lib.moe_apply_a2a_pipelined(
            p, xx, cfg, ep, plan, gate_cfg, num_chunks=2),
        "moe_apply_gather": lambda p, xx: moe_lib.moe_apply_gather(
            p, xx, cfg, ep, gate_cfg),
        "moe_apply_einsum": lambda p, xx: moe_lib.moe_apply_einsum(
            p, xx, cfg, ep, gate_cfg, capacity=T),
    }


@pytest.mark.parametrize("wrapper", ["moe_apply_a2a",
                                     "moe_apply_a2a_pipelined",
                                     "moe_apply_gather",
                                     "moe_apply_einsum"])
def test_each_wrapper_warns_deprecation(setup, mesh11, wrapper):
    """The shim claims deprecation in its docstring — it must also *warn*
    (pinned per wrapper; the warning fires on every use so callers see it
    regardless of import/call ordering across a process)."""
    cfg, ep, gate_cfg, params, plan, x = setup
    fn = _cases(setup)[wrapper]
    with pytest.warns(DeprecationWarning, match=wrapper):
        y, metrics = _run(fn, mesh11, params, x)
    assert y.shape == x.shape
    # wrappers inherit the engine's uniform level-indexed schema
    from repro.core import dispatch as dispatch_lib
    assert set(metrics) == set(dispatch_lib.METRIC_KEYS)
    assert metrics["frac_by_level"].shape == (1,)


def test_wrapper_output_matches_engine(setup, mesh11):
    """Deprecated surface and the engine proper are the same computation."""
    import numpy as np

    from repro.core import dispatch as dispatch_lib
    cfg, ep, gate_cfg, params, plan, x = setup
    with pytest.warns(DeprecationWarning):
        y_shim, _ = _run(lambda p, xx: moe_lib.moe_apply_a2a(
            p, xx, cfg, ep, plan, gate_cfg), mesh11, params, x)
    y_eng, _ = _run(lambda p, xx: dispatch_lib.dispatch_moe(
        "a2a", p, xx, cfg=cfg, ep=ep, gate_cfg=gate_cfg, plan=plan),
        mesh11, params, x)
    np.testing.assert_allclose(np.asarray(y_shim), np.asarray(y_eng))
