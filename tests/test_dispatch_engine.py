"""Unified core/dispatch engine: registry resolution, the uniform metrics
schema, the cross-path equivalence oracle, and per-layer dispatch override.

The oracle: at matched, ample capacities the four registered paths
(``einsum`` — the GShard baseline the paper describes in §2 — plus the
selection-based ``a2a``, ``a2a_pipelined``, and the weights-stationary
``gather``) are different *execution schedules* of the same math, so their
outputs must be allclose.  The multipod mesh case runs as a slow
subprocess (forced host devices)."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.core import dispatch as dispatch_lib
from repro.core import gating
from repro.core.capacity import make_plan
from repro.models import model as model_lib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
D, F, N, K, T = 16, 32, 4, 2, 64
PATHS = ("einsum", "a2a", "a2a_pipelined", "gather")


def _setup(key, capacity_factor=8.0, shared=0):
    cfg = dispatch_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                                 capacity_factor=capacity_factor,
                                 num_shared_experts=shared,
                                 dtype=jnp.float32)
    ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                             data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = dispatch_lib.init_moe_params(key, cfg, ep, gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=capacity_factor, num_pods=1,
                     ep_per_pod=1, mode="even")
    return cfg, ep, gate_cfg, params, plan


def _apply(name, mesh, params, x, cfg, ep, gate_cfg, **kw):
    from jax.sharding import PartitionSpec as P
    eng = dispatch_lib.make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                   **kw)
    body = shard_map(lambda p, xx: eng(p, xx), mesh=mesh,
                     in_specs=(P(), P()), out_specs=(P(), P()),
                     check_vma=False)
    with mesh:
        return body(params, x)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_contains_all_paths():
    assert set(PATHS) <= set(dispatch_lib.available())
    for name in PATHS:
        path = dispatch_lib.get_path(name)
        assert path.name == name
    # the staged paths refuse to resolve without a capacity plan
    assert dispatch_lib.get_path("a2a").needs_plan
    assert dispatch_lib.get_path("a2a_pipelined").needs_plan


def test_unknown_path_raises():
    with pytest.raises(ValueError, match="unknown dispatch"):
        dispatch_lib.get_path("ragged_a2a")
    cfg, ep, gate_cfg, _, plan = _setup(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="requires a DispatchPlan"):
        dispatch_lib.make_engine("a2a", cfg=cfg, ep=ep, gate_cfg=gate_cfg)


def test_build_ctx_rejects_unknown_dispatch(mesh11):
    from repro.configs.base import get_config
    arch = get_config("gpt3_medium_moe").reduced()
    with pytest.raises(ValueError, match="unknown dispatch"):
        model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                            dispatch="bogus")
    with pytest.raises(ValueError, match="unknown dispatch"):
        model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                            dispatch_override=((1, "bogus"),))


# ---------------------------------------------------------------------------
# uniform metrics schema
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", PATHS)
def test_uniform_metrics_schema(key, mesh11, name):
    cfg, ep, gate_cfg, params, plan = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
    _, metrics = _apply(name, mesh11, params, x, cfg, ep, gate_cfg,
                        plan=plan, num_chunks=2)
    assert set(metrics) == set(dispatch_lib.METRIC_KEYS)
    for k in dispatch_lib.METRIC_KEYS:
        assert np.isfinite(np.asarray(metrics[k])).all(), k
    # frac_by_level is a fixed-length vector (1 stage on this 1-axis EP
    # spec) summing to 1; the near/far aliases derive from it
    fb = np.asarray(metrics["frac_by_level"])
    assert fb.shape == (1,)
    assert fb.sum() == pytest.approx(1.0, abs=1e-6)
    # ample capacity + single rank: nothing drops, nothing leaves level <= 1
    assert float(metrics["dropped"]) == pytest.approx(0.0, abs=1e-6)
    assert float(metrics["frac_near"]) == pytest.approx(1.0, abs=1e-6)
    assert float(metrics["frac_far"]) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# cross-path equivalence oracle (single-pod)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("a2a", "a2a_pipelined", "gather"))
@pytest.mark.parametrize("shared", (0, 1))
@pytest.mark.parametrize("use_pallas", (False, True))
def test_cross_path_equivalence_vs_einsum_oracle(key, mesh11, name, shared,
                                                 use_pallas):
    """Each selection-based path == the einsum oracle at matched ample
    capacity (einsum capacity=T keeps every token, cf=8 does for a2a),
    with the moe_permute Pallas kernels both off (jnp reference) and
    forced on (Pallas interpreter on CPU)."""
    cfg, ep, gate_cfg, params, plan = _setup(key, shared=shared)
    x = jax.random.normal(jax.random.PRNGKey(2), (T, D), jnp.float32)
    y_oracle, _ = _apply("einsum", mesh11, params, x, cfg, ep, gate_cfg,
                         capacity=T)
    y, _ = _apply(name, mesh11, params, x, cfg, ep, gate_cfg,
                  plan=plan, num_chunks=3, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_oracle),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("name", ("a2a", "a2a_pipelined", "einsum"))
def test_cross_path_equivalence_decode_shapes(key, mesh11, name):
    """At decode shapes (a handful of tokens) the gather path is the
    reference and every other path must agree."""
    Td = 4
    cfg, ep, gate_cfg, params, plan = _setup(key)
    plan = dataclasses.replace(plan, tokens_per_device=Td)
    x = jax.random.normal(jax.random.PRNGKey(3), (Td, D), jnp.float32)
    y_ref, _ = _apply("gather", mesh11, params, x, cfg, ep, gate_cfg)
    y, _ = _apply(name, mesh11, params, x, cfg, ep, gate_cfg,
                  plan=plan, num_chunks=2, capacity=Td)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# occupancy-aware ragged grouped GEMM through the engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("a2a", "a2a_pipelined", "gather"))
def test_ragged_gemm_entry_on_and_off_agree(key, mesh11, name):
    """With the Pallas GEMM forced on, every selection path routes its
    expert compute through the occupancy-aware ragged entry (runtime
    valid-row counts, block-skip predicate) — outputs must equal both the
    dense jnp path and the einsum oracle.  A tight capacity factor makes
    the capacity buffers genuinely under-filled, so slack blocks really
    are skipped rather than trivially full."""
    from repro.kernels.moe_gemm import ops as gemm_ops
    assert gemm_ops.use_ragged(True), "ragged entry must be viable here"
    cfg, ep, gate_cfg, params, plan = _setup(key, capacity_factor=1.5)
    x = jax.random.normal(jax.random.PRNGKey(5), (T, D), jnp.float32)
    kw = dict(plan=plan) if name != "gather" else {}
    if name == "a2a_pipelined":
        kw["num_chunks"] = 3
    y_off, m_off = _apply(name, mesh11, params, x, cfg, ep, gate_cfg,
                          use_pallas=False, **kw)
    y_on, m_on = _apply(name, mesh11, params, x, cfg, ep, gate_cfg,
                        use_pallas=True, **kw)
    np.testing.assert_allclose(np.asarray(y_on), np.asarray(y_off),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(m_on["dropped"]),
                               float(m_off["dropped"]), atol=1e-6)


def test_gather_ragged_skips_unpicked_experts(key, mesh11):
    """Decode regime: at tiny token counts most local experts are picked by
    no token at all — the ragged entry skips their whole segments and the
    output still matches the dense gather compute."""
    Td = 3
    cfg, ep, gate_cfg, params, _ = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(6), (Td, D), jnp.float32)
    y_dense, _ = _apply("gather", mesh11, params, x, cfg, ep, gate_cfg,
                        use_pallas=False)
    y_ragged, _ = _apply("gather", mesh11, params, x, cfg, ep, gate_cfg,
                         use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_ragged), np.asarray(y_dense),
                               atol=1e-5, rtol=1e-5)


def test_rows_per_expert_counts_delivered_tokens(key, mesh11):
    """DispatchIndices.rows_per_expert must sum to the number of kept
    (token, pick) slots and bound every segment by its plan capacity."""
    from jax.sharding import PartitionSpec as P
    from repro.core.dispatch import routing
    cfg, ep, gate_cfg, params, plan = _setup(key, capacity_factor=1.25)

    def body(p, xx):
        routed = routing.route(p, xx, cfg, ep, plan, gate_cfg,
                               with_bufs=False)
        di = routing.build_indices(routed.sels,
                                   routed.gate_out["topk_idx"], T)
        kept = sum(jnp.sum(sel.valid) for _, sel in routed.sels)
        return di.rows_per_expert, di.slot_w, kept

    x = jax.random.normal(jax.random.PRNGKey(7), (T, D), jnp.float32)
    fn = shard_map(body, mesh=mesh11, in_specs=(P(), P()),
                   out_specs=(P(), P(), P()), check_vma=False)
    with mesh11:
        counts, slot_w, kept = fn(params, x)
    counts = np.asarray(counts)
    assert counts.sum() == int(kept) == int((np.asarray(slot_w) > 0).sum())
    # one segment per (stage, dest, expert); each bounded by its stage cap
    off = 0
    for s in range(plan.num_stages):
        if plan.caps[s] <= 0:
            continue
        n_seg = N  # single-rank mesh: num_dests == 1, E_l == N
        seg = counts[off:off + n_seg]
        assert (seg <= min(plan.caps[s], T)).all()
        off += n_seg
    assert off == counts.shape[0]


# ---------------------------------------------------------------------------
# per-layer dispatch override through the model stack
# ---------------------------------------------------------------------------


def _moe_layer_indices(arch):
    from repro.models.transformer import layer_plan
    prefix, group, n_groups = layer_plan(arch)
    idxs = []
    for g in range(n_groups):
        for j, sub in enumerate(group):
            if sub.ffn == "moe":
                idxs.append(len(prefix) + g * len(group) + j)
    return idxs


def test_per_layer_dispatch_override_train(mesh11):
    """Overriding one MoE layer to the num_chunks=1 pipelined schedule (==
    sync) must reproduce the baseline losses exactly; an ample-capacity
    gather override stays allclose (same math, different transport)."""
    from repro.configs.base import RunConfig, get_config
    from repro.training import trainer
    arch = get_config("gpt3_medium_moe").reduced()
    arch = dataclasses.replace(
        arch, moe=dataclasses.replace(arch.moe, capacity_factor=8.0))
    moe_idxs = _moe_layer_indices(arch)
    assert moe_idxs, "reduced gpt3_medium_moe must keep MoE layers"
    base = dict(seq_len=32, global_batch=4, learning_rate=1e-3,
                total_steps=10, warmup_steps=2, aux_mode="ta")
    r_sync = trainer.train(arch, RunConfig(**base), mesh11, steps=3,
                           log_every=1, verbose=False)
    r_ovr = trainer.train(
        arch, RunConfig(**base, a2a_num_chunks=1,
                        dispatch_override=((moe_idxs[0], "a2a_pipelined"),)),
        mesh11, steps=3, log_every=1, verbose=False)
    np.testing.assert_allclose(r_ovr.losses, r_sync.losses, rtol=1e-6)
    r_gather = trainer.train(
        arch, RunConfig(**base,
                        dispatch_override=((moe_idxs[0], "gather"),)),
        mesh11, steps=3, log_every=1, verbose=False)
    np.testing.assert_allclose(r_gather.losses, r_sync.losses, rtol=1e-4)


def test_noop_overrides_keep_the_group_scan(mesh11):
    """Out-of-range indices, overrides equal to the default path, and
    prefix-only overrides must not force the n_groups-fold unroll."""
    from repro.configs.base import get_config
    from repro.models.transformer import _overrides_hit_groups, layer_plan
    arch = get_config("gpt3_medium_moe").reduced()
    prefix, group, n_groups = layer_plan(arch)
    moe_idx = _moe_layer_indices(arch)[0]
    cases = [
        (((999, "gather"),), False),              # stale / out-of-range idx
        (((moe_idx, "a2a"),), False),             # == default: no-op
        (((moe_idx, "gather"),), True),           # genuine change
    ]
    for ovr, want in cases:
        ctx = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                                  dispatch_override=ovr)
        got = _overrides_hit_groups(ctx, len(prefix), group, n_groups)
        assert got == bool(want), (ovr, got)


def test_build_ctx_merges_arch_and_run_overrides(mesh11):
    from repro.configs.base import get_config
    arch = get_config("gpt3_medium_moe").reduced()
    arch = dataclasses.replace(
        arch, moe=dataclasses.replace(
            arch.moe, dispatch_override=((1, "a2a_pipelined"), (2, "gather"))))
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                              dispatch_override=((2, "a2a"),))
    got = dict(ctx.dispatch_override)
    assert got[1] == "a2a_pipelined"      # arch-level survives
    assert got[2] == "a2a"                # run-level wins per layer
    # an a2a_pipelined override alone triggers plan chunk alignment
    assert ctx.a2a_num_chunks >= 1
    assert ctx.plan.cap_near % ctx.a2a_num_chunks == 0


# ---------------------------------------------------------------------------
# multipod mesh case (slow subprocess: forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ragged_gemm_multipod_counts_align():
    """4-rank EP at a *tight* capacity factor: the delivered-count exchange
    (dispatch_counts) must line the runtime occupancy up with the payload
    chunks — a misalignment would zero real token rows and break the
    ragged-on == ragged-off equality that under-filled buffers expose."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import dispatch as dl, gating
        from repro.core.capacity import make_plan

        mesh = make_mesh((2, 2), ("pod", "data"))
        D, F, N, K, T = 16, 32, 8, 2, 32
        cfg = dl.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                           capacity_factor=2.0, dtype=jnp.float32)
        ep = dl.EPSpec(num_pods=2, ep_per_pod=2, pod_axis="pod",
                       data_axis="data", model_axis=None)
        gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="ta")
        params = dl.init_moe_params(jax.random.PRNGKey(0), cfg, ep, gate_cfg)
        plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                         capacity_factor=2.0, num_pods=2, ep_per_pod=2,
                         mode="ta", round_multiple=1)
        x = jax.random.normal(jax.random.PRNGKey(1), (4 * T, D), jnp.float32)
        pspecs = {"gate": {"w": P()},
                  "w_in": P(("pod", "data"), None, None),
                  "w_gate": P(("pod", "data"), None, None),
                  "w_out": P(("pod", "data"), None, None)}

        def run(name, **kw):
            eng = dl.make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                 **kw)
            fn = shard_map(lambda p, xx: eng(p, xx)[0], mesh=mesh,
                           in_specs=(pspecs, P(("pod", "data"), None)),
                           out_specs=P(("pod", "data"), None),
                           check_vma=False)
            with mesh:
                return np.asarray(fn(params, x))

        y_off = run("a2a", plan=plan, use_pallas=False)
        for name, kw in (("a2a", {}), ("a2a_pipelined", {"num_chunks": 2}),
                         ("gather", {})):
            pkw = dict(plan=plan) if name != "gather" else {}
            y_on = run(name, use_pallas=True, **pkw, **kw)
            ref = y_off if name != "gather" \
                else run("gather", use_pallas=False)
            err = float(np.abs(y_on - ref).max())
            print(name, "ERR", err)
            assert err < 1e-4, (name, err)
        print("MULTIRANK-RAGGED-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    assert "MULTIRANK-RAGGED-OK" in r.stdout


@pytest.mark.slow
def test_cross_path_equivalence_multipod():
    """4-rank EP (2 pods x 2) through the engine registry: a2a,
    a2a_pipelined (several chunk counts) and gather must all agree at
    matched ample capacities, with the uniform metrics schema."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import dispatch as dl, gating
        from repro.core.capacity import make_plan

        mesh = make_mesh((2, 2), ("pod", "data"))
        D, F, N, K, T = 16, 32, 8, 2, 32   # T per rank
        cfg = dl.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                           capacity_factor=8.0, dtype=jnp.float32)
        ep = dl.EPSpec(num_pods=2, ep_per_pod=2, pod_axis="pod",
                       data_axis="data", model_axis=None)
        gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="ta")
        params = dl.init_moe_params(jax.random.PRNGKey(0), cfg, ep, gate_cfg)
        plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                         capacity_factor=8.0, num_pods=2, ep_per_pod=2,
                         mode="ta", round_multiple=1)
        assert plan.cap_far > 0
        x = jax.random.normal(jax.random.PRNGKey(1), (4 * T, D), jnp.float32)
        pspecs = {"gate": {"w": P()},
                  "w_in": P(("pod", "data"), None, None),
                  "w_gate": P(("pod", "data"), None, None),
                  "w_out": P(("pod", "data"), None, None)}

        def run(name, **kw):
            eng = dl.make_engine(name, cfg=cfg, ep=ep, gate_cfg=gate_cfg,
                                 **kw)
            fn = shard_map(lambda p, xx: eng(p, xx), mesh=mesh,
                           in_specs=(pspecs, P(("pod", "data"), None)),
                           out_specs=(P(("pod", "data"), None),
                                      {k: P() for k in dl.METRIC_KEYS}),
                           check_vma=False)
            with mesh:
                y, m = fn(params, x)
            m = {k: float(np.asarray(jnp.mean(v))) for k, v in m.items()}
            assert set(m) == set(dl.METRIC_KEYS), m
            return np.asarray(y), m

        y_ref, m_ref = run("a2a", plan=plan)
        assert 0.0 < m_ref["frac_near"] < 1.0    # both levels exercised
        for k in (1, 2, 3):
            yk, mk = run("a2a_pipelined", plan=plan, num_chunks=k)
            err = float(np.abs(yk - y_ref).max())
            print("CHUNKS", k, "ERR", err)
            assert err < 1e-4, (k, err)
            assert abs(mk["dropped"] - m_ref["dropped"]) < 1e-6
        yg, mg = run("gather")
        err = float(np.abs(yg - y_ref).max())
        print("GATHER ERR", err)
        assert err < 1e-3, err
        print("MULTIPOD-ORACLE-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    assert "MULTIPOD-ORACLE-OK" in r.stdout
