"""Unit tests for the HLO collective parser and roofline math
(launch/analysis.py) — these guard the §Roofline numbers."""

import pytest

from repro.launch import analysis


class TestShapeBytes:
    def test_simple(self):
        assert analysis._shape_bytes("bf16[8,128]") == 8 * 128 * 2
        assert analysis._shape_bytes("f32[4]") == 16
        assert analysis._shape_bytes("(f32[2,2], bf16[4])") == 16 + 8

    def test_ignores_unknown_dtypes(self):
        assert analysis._shape_bytes("token[]") == 0

    def test_low_precision_wire_dtypes(self):
        # the quantized-wire dtypes: fp8 variants and 8-bit ints
        assert analysis._shape_bytes("f8e4m3fn[32,16]") == 32 * 16
        assert analysis._shape_bytes("f8e5m2fnuz[8]") == 8
        assert analysis._shape_bytes("f8e4m3b11fnuz[4,4]") == 16
        assert analysis._shape_bytes("s8[128]") == 128
        assert analysis._shape_bytes("u8[64,2]") == 128

    def test_packed_int4_rounds_up(self):
        # 4-bit types pack two elements per byte, ceil'd per shape
        assert analysis._shape_bytes("s4[8]") == 4
        assert analysis._shape_bytes("u4[7]") == 4
        assert analysis._shape_bytes("s4[1]") == 1

    def test_nested_tuple_shapes(self):
        text = "(f32[2,2], (s8[16], u4[6]), bf16[3])"
        assert analysis._shape_bytes(text) == 16 + 16 + 3 + 6

    def test_mixed_tuple_with_unknowns(self):
        text = "(token[], f8e4m3fn[10], (u4[3]))"
        assert analysis._shape_bytes(text) == 10 + 2


class TestGroupParsing:
    def test_explicit_groups(self):
        line = "replica_groups={{0,1},{2,3}}"
        g = analysis._parse_groups(line, 4)
        assert g == [[0, 1], [2, 3]]

    def test_iota_groups(self):
        line = "replica_groups=[4,2]<=[8]"
        g = analysis._parse_groups(line, 8)
        assert g == [[0, 1], [2, 3], [4, 5], [6, 7]]

    def test_iota_transposed(self):
        line = "replica_groups=[2,4]<=[4,2]T(1,0)"
        g = analysis._parse_groups(line, 8)
        assert g == [[0, 2, 4, 6], [1, 3, 5, 7]]

    def test_source_target_pairs(self):
        line = "source_target_pairs={{0,1},{1,0}}"
        g = analysis._parse_groups(line, 4)
        assert g == [[0, 1], [1, 0]]


class TestCollectiveStats:
    def test_tuple_allreduce_counted(self):
        hlo = ("%ar = (f32[256,128]{1,0}, f32[64]{0}) "
               "all-reduce(f32[256,128] %a, f32[64] %b), "
               "replica_groups=[2,4]<=[4,2]T(1,0), to_apply=%add")
        st = analysis.collective_stats(hlo, num_devices=8, devices_per_pod=4)
        want = (256 * 128 * 4 + 64 * 4) * 2 * 3 / 4  # ring all-reduce
        assert st.dci_bytes == pytest.approx(want)
        assert st.ici_bytes == 0

    def test_intra_pod_classified_ici(self):
        hlo = ("%a2a = bf16[16,64]{1,0} all-to-all(bf16[16,64] %x), "
               "replica_groups={{0,1,2,3}}, dimensions={0}")
        st = analysis.collective_stats(hlo, num_devices=8, devices_per_pod=4)
        assert st.ici_bytes == pytest.approx(16 * 64 * 2 * 3 / 4)
        assert st.dci_bytes == 0

    def test_start_done_counted_once(self):
        hlo = ("%s = bf16[8]{0} all-gather-start(bf16[2] %x), "
               "replica_groups={{0,1,2,3}}, dimensions={0}\n"
               "%d = bf16[8]{0} all-gather-done(bf16[8] %s)")
        st = analysis.collective_stats(hlo, num_devices=4, devices_per_pod=4)
        assert st.counts.get("all-gather", 0) == 1

    def test_no_collectives(self):
        st = analysis.collective_stats("%add = f32[2] add(f32[2], f32[2])",
                                       num_devices=4, devices_per_pod=2)
        assert st.ici_bytes == 0 and st.dci_bytes == 0


class TestRooflineMath:
    def test_dominant_selection(self):
        class FakeCompiled:
            def cost_analysis(self):
                return {"flops": 197e12 * 0.001,       # 1 ms compute
                        "bytes accessed": 819e9 * 0.01}  # 10 ms memory
            def as_text(self):
                return ""
        r = analysis.roofline(FakeCompiled(), num_devices=4,
                              devices_per_pod=2, model_flops=197e12 * 0.002)
        assert r.dominant == "memory"
        assert r.t_compute == pytest.approx(1e-3)
        assert r.t_memory == pytest.approx(1e-2)
        assert r.useful_ratio == pytest.approx(0.5)
