"""Per-kernel allclose validation: Pallas interpret mode vs pure-jnp
oracles, swept over shapes and dtypes (system prompt deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_gemm.kernel import grouped_ffn_pallas
from repro.kernels.moe_gemm.ref import grouped_ffn_ref
from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.kernels.decode_attn.kernel import decode_attention_pallas
from repro.kernels.decode_attn.ref import decode_attention_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestMoeGemm:
    @pytest.mark.parametrize("E,C,d,f,bc,bf", [
        (1, 8, 32, 64, 8, 32),
        (3, 40, 64, 96, 16, 32),      # non-divisible C/f vs blocks
        (4, 128, 128, 256, 64, 128),
        (2, 16, 48, 80, 16, 80),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_swiglu_sweep(self, E, C, d, f, bc, bf, dtype):
        ks = jax.random.split(jax.random.PRNGKey(E * C), 4)
        x = jax.random.normal(ks[0], (E, C, d), dtype)
        wi = (jax.random.normal(ks[1], (E, d, f), dtype) * 0.1)
        wg = (jax.random.normal(ks[2], (E, d, f), dtype) * 0.1)
        wo = (jax.random.normal(ks[3], (E, f, d), dtype) * 0.1)
        got = grouped_ffn_pallas(x, wi, wg, wo, block_c=bc, block_f=bf,
                                 interpret=True)
        want = grouped_ffn_ref(x, wi, wg, wo)
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **_tol(dtype))

    def test_gelu_path(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        x = jax.random.normal(ks[0], (2, 24, 32), jnp.float32)
        wi = jax.random.normal(ks[1], (2, 32, 64), jnp.float32) * 0.1
        wo = jax.random.normal(ks[2], (2, 64, 32), jnp.float32) * 0.1
        got = grouped_ffn_pallas(x, wi, None, wo, activation="gelu",
                                 block_c=8, block_f=32, interpret=True)
        want = grouped_ffn_ref(x, wi, None, wo, activation="gelu")
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,hd,bq,bk", [
        (1, 32, 2, 2, 16, 16, 16),
        (2, 64, 4, 2, 32, 16, 32),    # GQA G=2
        (1, 96, 8, 1, 16, 32, 32),    # MQA, ragged blocks
    ])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                               (False, 0)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, S, H, K, hd, bq, bk, causal, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
        got = flash_attention_pallas(q, k, v, causal=causal,
                                     sliding_window=window,
                                     block_q=bq, block_k=bk, interpret=True)
        want = flash_attention_ref(q, k, v, causal=causal,
                                   sliding_window=window)
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **_tol(dtype))


class TestDecodeAttention:
    @pytest.mark.parametrize("B,H,K,hd,L,bl", [
        (1, 4, 4, 16, 64, 32),
        (3, 8, 4, 32, 128, 32),
        (2, 16, 2, 16, 100, 64),      # ragged L vs block
    ])
    @pytest.mark.parametrize("window", [0, 48])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, H, K, hd, L, bl, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(L + H), 3)
        q = jax.random.normal(ks[0], (B, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, L, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, L, K, hd), dtype)
        lens = jnp.asarray(
            np.random.default_rng(0).integers(1, L + 1, B), jnp.int32)
        got = decode_attention_pallas(q, k, v, lens, sliding_window=window,
                                      block_l=bl, interpret=True)
        want = decode_attention_ref(q, k, v, lens, sliding_window=window)
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **_tol(dtype))

    def test_matches_layer_decode_semantics(self):
        """Kernel agrees with the model's attn_decode math (pos = len-1)."""
        from repro.models import layers
        cfg = layers.AttnConfig(d_model=64, num_heads=4, num_kv_heads=2,
                                head_dim=16, dtype=jnp.float32)
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        B, L = 2, 32
        k = jax.random.normal(ks[0], (B, L, 2, 16), jnp.float32)
        v = jax.random.normal(ks[1], (B, L, 2, 16), jnp.float32)
        q = jax.random.normal(ks[2], (B, 4, 16), jnp.float32)
        lens = jnp.array([L, L // 2], jnp.int32)
        out = decode_attention_ref(q, k, v, lens)
        assert out.shape == (B, 4, 16)
        assert np.isfinite(np.asarray(out)).all()
