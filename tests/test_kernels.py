"""Per-kernel allclose validation: Pallas interpret mode vs pure-jnp
oracles, swept over shapes and dtypes (system prompt deliverable (c)).

This file is part of the CI Pallas-interpret lane's workload (run with
``JAX_PLATFORMS=cpu REPRO_KERNEL_INTERPRET=1``), so every moe_gemm kernel
body — including the occupancy-aware ragged entry and its block-skip
predicate — executes on CPU-only CI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - CI has hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.capacity import make_dispatch_plan
from repro.kernels.moe_gemm import ops as gemm_ops
from repro.kernels.moe_gemm.kernel import (grouped_ffn_pallas,
                                           grouped_ffn_ragged_pallas)
from repro.kernels.moe_gemm.ref import grouped_ffn_ragged_ref, grouped_ffn_ref
from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.kernels.decode_attn.kernel import decode_attention_pallas
from repro.kernels.decode_attn.ref import decode_attention_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestMoeGemm:
    @pytest.mark.parametrize("E,C,d,f,bc,bf", [
        (1, 8, 32, 64, 8, 32),
        (3, 40, 64, 96, 16, 32),      # non-divisible C/f vs blocks
        (4, 128, 128, 256, 64, 128),
        (2, 16, 48, 80, 16, 80),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_swiglu_sweep(self, E, C, d, f, bc, bf, dtype):
        ks = jax.random.split(jax.random.PRNGKey(E * C), 4)
        x = jax.random.normal(ks[0], (E, C, d), dtype)
        wi = (jax.random.normal(ks[1], (E, d, f), dtype) * 0.1)
        wg = (jax.random.normal(ks[2], (E, d, f), dtype) * 0.1)
        wo = (jax.random.normal(ks[3], (E, f, d), dtype) * 0.1)
        got = grouped_ffn_pallas(x, wi, wg, wo, block_c=bc, block_f=bf,
                                 interpret=True)
        want = grouped_ffn_ref(x, wi, wg, wo)
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **_tol(dtype))

    def test_gelu_path(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        x = jax.random.normal(ks[0], (2, 24, 32), jnp.float32)
        wi = jax.random.normal(ks[1], (2, 32, 64), jnp.float32) * 0.1
        wo = jax.random.normal(ks[2], (2, 64, 32), jnp.float32) * 0.1
        got = grouped_ffn_pallas(x, wi, None, wo, activation="gelu",
                                 block_c=8, block_f=32, interpret=True)
        want = grouped_ffn_ref(x, wi, None, wo, activation="gelu")
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_output_dtype_matches_input(self):
        """The f32 accumulator is cast back inside the kernel epilogue —
        the output must arrive in the model dtype, not f32."""
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        x = jax.random.normal(ks[0], (2, 16, 32), jnp.bfloat16)
        wi = jax.random.normal(ks[1], (2, 32, 64), jnp.bfloat16) * 0.1
        wo = jax.random.normal(ks[2], (2, 64, 32), jnp.bfloat16) * 0.1
        got = grouped_ffn_pallas(x, wi, wi, wo, interpret=True)
        assert got.dtype == jnp.bfloat16

    @pytest.mark.parametrize("activation", ["swiglu", "gelu"])
    def test_dense_custom_vjp_matches_ref_grads(self, activation):
        """grouped_ffn_pallas carries a custom_vjp with a jnp backward: a
        training step on the kernel path never hits Pallas autodiff and
        its grads equal autodiff of the reference."""
        ks = jax.random.split(jax.random.PRNGKey(11), 4)
        x = jax.random.normal(ks[0], (2, 16, 32), jnp.float32)
        wi = jax.random.normal(ks[1], (2, 32, 48), jnp.float32) * 0.1
        wg = (jax.random.normal(ks[2], (2, 32, 48), jnp.float32) * 0.1
              if activation == "swiglu" else None)
        wo = jax.random.normal(ks[3], (2, 48, 32), jnp.float32) * 0.1

        def loss(fn, x_, wi_, wo_):
            return jnp.sum(fn(x_, wi_, wg, wo_, activation=activation) ** 2)

        pallas = lambda *a, **k: grouped_ffn_pallas(*a, interpret=True, **k)
        gp = jax.grad(lambda *a: loss(pallas, *a), (0, 1, 2))(x, wi, wo)
        gr = jax.grad(lambda *a: loss(grouped_ffn_ref, *a), (0, 1, 2))(
            x, wi, wo)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# occupancy-aware ragged grouped FFN
# ---------------------------------------------------------------------------


def _ragged_fixture(seed, seg_offsets, seg_experts, E, d, f,
                    dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    R = seg_offsets[-1]
    # garbage *everywhere*, including slack rows: both implementations must
    # mask identically, not rely on pre-zeroed inputs
    x = jnp.asarray(rng.standard_normal((R, d)), dtype)
    wi = jnp.asarray(rng.standard_normal((E, d, f)) * 0.1, dtype)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.1, dtype)
    wo = jnp.asarray(rng.standard_normal((E, f, d)) * 0.1, dtype)
    return x, wi, wg, wo


class TestMoeGemmRagged:
    @pytest.mark.parametrize("occ", ["empty", "partial", "full"])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_occupancy_sweep_vs_ref(self, occ, dtype):
        offs = (0, 16, 48, 56, 88)
        exps = (0, 2, 1, 3)
        widths = np.diff(offs)
        rng = np.random.default_rng(3)
        valid = {"empty": np.zeros_like(widths),
                 "partial": rng.integers(0, widths + 1),
                 "full": widths}[occ]
        x, wi, wg, wo = _ragged_fixture(7, offs, exps, 4, 32, 64, dtype)
        valid = jnp.asarray(valid, jnp.int32)
        got = gemm_ops.grouped_ffn_ragged(x, offs, exps, valid, wi, wg, wo,
                                          block_c=8, use_pallas=True)
        want = grouped_ffn_ragged_ref(x, offs, exps, valid, wi, wg, wo)
        assert got.dtype == dtype
        tol = _tol(dtype)
        np.testing.assert_allclose(np.float32(got), np.float32(want), **tol)
        # rows past each segment's realized count are exact zeros
        for s in range(len(exps)):
            lo = offs[s] + int(valid[s])
            assert (np.float32(got)[lo:offs[s + 1]] == 0.0).all()

    def test_gelu_and_full_equals_dense(self):
        """Fully-occupied equal segments == the dense grouped FFN."""
        offs, exps = (0, 16, 32, 48), (0, 1, 2)
        x, wi, _, wo = _ragged_fixture(9, offs, exps, 3, 24, 40)
        got = gemm_ops.grouped_ffn_ragged(x, offs, exps, None, wi, None, wo,
                                          activation="gelu", use_pallas=True)
        want = grouped_ffn_ref(x.reshape(3, 16, 24), wi, None, wo,
                               activation="gelu").reshape(-1, 24)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    def test_block_skip_predicate_fires(self):
        """The occupancy predicate must actually skip whole row blocks: the
        static block plan shows zero-valid blocks, and the kernel emits
        exact zero rows there even though the input rows are garbage (a
        computed block would produce nonzero output)."""
        offs, exps = (0, 32, 64), (0, 1)
        valid = jnp.asarray([8, 0], jnp.int32)   # expert 1 fully slack
        x, wi, wg, wo = _ragged_fixture(13, offs, exps, 2, 16, 32)
        bc, brow, beid, bseg, bloc = gemm_ops.plan_blocks(offs, exps,
                                                          block_c=8)
        nvalid = np.clip(np.asarray(valid)[bseg] - bloc, 0, bc)
        assert (nvalid == 0).sum() >= 3, nvalid   # blocks the kernel skips
        assert (nvalid > 0).any()
        got = np.asarray(grouped_ffn_ragged_pallas(
            x, jnp.asarray(brow), jnp.asarray(beid),
            jnp.asarray(nvalid, jnp.int32), wi, wg, wo, block_c=bc,
            interpret=True))
        for b in range(len(brow)):
            rows = slice(brow[b] * bc, (brow[b] + 1) * bc)
            if nvalid[b] == 0:
                assert (got[rows] == 0.0).all(), b
            else:
                assert np.abs(got[rows][:nvalid[b]]).max() > 0, b

    def test_row_align_pads_blocks_to_mxu_width(self):
        """Chunk slices with awkward widths (pipelined dispatch) must not
        collapse the kernel onto tiny gcd row blocks: row_align pads each
        segment up to an MXU-friendly multiple (the padded rows are slack
        past rows_valid) and the result still matches the reference."""
        offs, exps = (0, 43, 86, 110), (0, 1, 2)   # gcd(43, 24) == 1
        valid = jnp.asarray([20, 0, 24], jnp.int32)
        x, wi, wg, wo = _ragged_fixture(23, offs, exps, 3, 16, 32)
        # un-aligned plan would degrade to 1-row blocks
        bc, brow, *_ = gemm_ops.plan_blocks(offs, exps, block_c=16)
        assert bc == 1 and len(brow) == 110
        # with row_align the padded plan gets full-width blocks
        aligned = tuple(-(-w // 16) * 16 for w in (43, 43, 24))
        poffs = (0,) + tuple(np.cumsum(aligned))
        bc_p, brow_p, *_ = gemm_ops.plan_blocks(poffs, exps, block_c=16)
        assert bc_p == 16
        got = gemm_ops.grouped_ffn_ragged(x, offs, exps, valid, wi, wg, wo,
                                          block_c=16, row_align=16,
                                          use_pallas=True)
        want = grouped_ffn_ragged_ref(x, offs, exps, valid, wi, wg, wo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        # grads flow through the pad/carve gathers too
        g = jax.grad(lambda x_: jnp.sum(gemm_ops.grouped_ffn_ragged(
            x_, offs, exps, valid, wi, wg, wo, block_c=16, row_align=16,
            use_pallas=True) ** 2))(x)
        assert np.isfinite(np.asarray(g)).all()
        assert (np.asarray(g)[20:43] == 0.0).all()   # slack rows: zero grad

    def test_ragged_custom_vjp_matches_ref_grads(self):
        offs, exps = (0, 16, 40), (1, 0)
        valid = jnp.asarray([10, 24], jnp.int32)
        x, wi, wg, wo = _ragged_fixture(17, offs, exps, 2, 16, 32)

        def loss(entry, x_, wi_, wg_, wo_):
            return jnp.sum(entry(x_, offs, exps, valid, wi_, wg_, wo_) ** 2)

        pallas = lambda *a, **k: gemm_ops.grouped_ffn_ragged(
            *a, use_pallas=True, **k)
        gp = jax.grad(lambda *a: loss(pallas, *a), (0, 1, 2, 3))(
            x, wi, wg, wo)
        gr = jax.grad(lambda *a: loss(grouped_ffn_ragged_ref, *a),
                      (0, 1, 2, 3))(x, wi, wg, wo)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
        # slack rows get exactly zero gradient
        assert (np.asarray(gp[0])[10:16] == 0.0).all()

    def test_grads_flow_through_expert_ffn_flat(self):
        """expert_ffn_flat on the ragged kernel path differentiates and
        matches the jnp path's grads (slack rows zero-filled, as the
        permute sentinel guarantees in the engine)."""
        from repro.core import dispatch as dispatch_lib, gating
        cfg = dispatch_lib.MoEConfig(d_model=16, d_ff=32, num_experts=2,
                                     top_k=1, dtype=jnp.float32)
        ep = dispatch_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                                 data_axis="data", model_axis=None)
        gate_cfg = gating.GateConfig(num_experts=2, top_k=1, aux_mode="lb")
        params = dispatch_lib.init_moe_params(jax.random.PRNGKey(0), cfg, ep,
                                              gate_cfg)
        offs, exps = (0, 16, 32), (0, 1)
        valid = jnp.asarray([12, 5], jnp.int32)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        row = np.arange(32)
        mask = ((row < 12) | ((row >= 16) & (row < 21))).astype(np.float32)
        x = x * jnp.asarray(mask)[:, None]        # zero-slot convention

        def loss(p, up):
            y = dispatch_lib.expert_ffn_flat(p, x, offs, cfg, ep,
                                             seg_experts=exps,
                                             rows_valid=valid, use_pallas=up)
            return jnp.sum(y ** 2)

        gk = jax.grad(lambda p: loss(p, True))(params)
        gj = jax.grad(lambda p: loss(p, False))(params)
        for k in ("w_in", "w_gate", "w_out"):
            assert np.isfinite(np.asarray(gk[k])).all()
            np.testing.assert_allclose(np.asarray(gk[k]), np.asarray(gj[k]),
                                       atol=1e-4, rtol=1e-4)
        assert np.abs(np.asarray(gk["w_in"])).sum() > 0


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(((2, 2), (2, 2, 2), (2, 2, 2, 2))),
       st.integers(0, 10_000), st.sampled_from(("empty", "partial", "full")))
def test_ragged_kernel_matches_ref_on_plan_layouts(axis_sizes, seed, occ):
    """Property test over real Eq. (7) capacity plans: build the exact
    (expert, stage, destination) segment layout the engine computes on for
    2-/3-/4-level topologies, draw occupancy in {0, partial, full}, and the
    kernel must equal the reference (and the zero-slot convention must
    hold) at every block granularity the gcd rule picks."""
    from repro.core.dispatch import transport
    T, N, K = 16, 8, 2
    plan = make_dispatch_plan(tokens_per_device=T, num_experts=N, top_k=K,
                              capacity_factor=2.0, axis_sizes=axis_sizes,
                              mode="ta")
    E_l = plan.experts_per_rank
    # stage s delivers from prod(axis_sizes[-(s+1):]) sources at cap[s];
    # the layout comes from the production helper so this test pins the
    # exact segment order the engine computes on
    stage_widths = tuple(
        (int(np.prod(axis_sizes[len(axis_sizes) - s - 1:])),
         min(plan.caps[s], T))
        for s in range(plan.num_stages) if plan.caps[s] > 0)
    offs, exps = transport.stage_segments(E_l, stage_widths)
    widths = np.diff(offs)
    rng = np.random.default_rng(seed)
    valid = {"empty": np.zeros_like(widths),
             "partial": rng.integers(0, widths + 1),
             "full": widths}[occ]
    valid = jnp.asarray(valid, jnp.int32)
    x, wi, wg, wo = _ragged_fixture(seed, offs, exps, E_l, 8, 16)
    got = gemm_ops.grouped_ffn_ragged(x, offs, exps, valid, wi, wg, wo,
                                      use_pallas=True)
    want = grouped_ffn_ragged_ref(x, offs, exps, valid, wi, wg, wo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,hd,bq,bk", [
        (1, 32, 2, 2, 16, 16, 16),
        (2, 64, 4, 2, 32, 16, 32),    # GQA G=2
        (1, 96, 8, 1, 16, 32, 32),    # MQA, ragged blocks
    ])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                               (False, 0)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, S, H, K, hd, bq, bk, causal, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
        got = flash_attention_pallas(q, k, v, causal=causal,
                                     sliding_window=window,
                                     block_q=bq, block_k=bk, interpret=True)
        want = flash_attention_ref(q, k, v, causal=causal,
                                   sliding_window=window)
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **_tol(dtype))


class TestDecodeAttention:
    @pytest.mark.parametrize("B,H,K,hd,L,bl", [
        (1, 4, 4, 16, 64, 32),
        (3, 8, 4, 32, 128, 32),
        (2, 16, 2, 16, 100, 64),      # ragged L vs block
    ])
    @pytest.mark.parametrize("window", [0, 48])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, H, K, hd, L, bl, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(L + H), 3)
        q = jax.random.normal(ks[0], (B, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, L, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, L, K, hd), dtype)
        lens = jnp.asarray(
            np.random.default_rng(0).integers(1, L + 1, B), jnp.int32)
        got = decode_attention_pallas(q, k, v, lens, sliding_window=window,
                                      block_l=bl, interpret=True)
        want = decode_attention_ref(q, k, v, lens, sliding_window=window)
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **_tol(dtype))

    def test_matches_layer_decode_semantics(self):
        """Kernel agrees with the model's attn_decode math (pos = len-1)."""
        from repro.models import layers
        cfg = layers.AttnConfig(d_model=64, num_heads=4, num_kv_heads=2,
                                head_dim=16, dtype=jnp.float32)
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        B, L = 2, 32
        k = jax.random.normal(ks[0], (B, L, 2, 16), jnp.float32)
        v = jax.random.normal(ks[1], (B, L, 2, 16), jnp.float32)
        q = jax.random.normal(ks[2], (B, 4, 16), jnp.float32)
        lens = jnp.array([L, L // 2], jnp.int32)
        out = decode_attention_ref(q, k, v, lens)
        assert out.shape == (B, 4, 16)
        assert np.isfinite(np.asarray(out)).all()
