"""Resilient training runtime: chaos injection, step-health guards,
checkpoint rollback, degraded-topology replan, and deadline eviction.

Every fault family ``ChaosConfig`` can inject has a test here proving the
run survives it; the no-chaos guarded path is additionally pinned to be
bit-identical in trained params to the unguarded loop (the whole point of
the in-jit select design).  The multi-axis degraded-link replan lives in
``test_multidevice.py`` (it needs forced host devices).
"""

import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.checkpoint import ckpt
from repro.configs.base import RunConfig, get_config
from repro.models import model as model_lib
from repro.resilience import ChaosConfig, RecoveryPolicy, ResilienceConfig
from repro.resilience import chaos as chaos_lib
from repro.resilience import guards
from repro.serving import engine
from repro.serving.scheduler import Request
from repro.training import trainer

ARCH_ID = "gpt3_medium_moe"


def _run_cfg(**kw):
    base = dict(seq_len=32, global_batch=4, total_steps=10, warmup_steps=2,
                aux_mode="ta", seed=0)
    base.update(kw)
    return RunConfig(**base)


def _train(mesh11, run, steps, **kw):
    arch = get_config(ARCH_ID).reduced()
    return trainer.train(arch, run, mesh11, steps=steps, log_every=1,
                         verbose=False, **kw)


# ---------------------------------------------------------------------------
# guards (pure units, no model)
# ---------------------------------------------------------------------------


def test_nonfinite_score_flags_any_poisoned_leaf():
    grads = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    assert bool(jnp.isfinite(guards.nonfinite_score(jnp.float32(1.0), grads)))
    for poison in (jnp.nan, jnp.inf, -jnp.inf):
        bad = {"a": jnp.ones((3,)).at[1].set(poison), "b": grads["b"]}
        score = guards.nonfinite_score(jnp.float32(1.0), bad)
        assert not bool(jnp.isfinite(score))
    # non-finite loss alone also trips it
    score = guards.nonfinite_score(jnp.float32(jnp.nan), grads)
    assert not bool(jnp.isfinite(score))


def test_spike_detector_warmup_patience_and_baseline_protection():
    det = guards.SpikeDetector(factor=2.0, patience=2, beta=0.5, warmup=2)
    assert not det.update(1.0) and not det.update(1.0)   # warmup absorbs
    ema_before = det.ema
    assert not det.update(10.0)       # spike 1/2: streak, EMA untouched
    assert det.ema == ema_before      # a spike must not poison its baseline
    assert det.update(10.0)           # spike 2/2: sustained -> trip
    det.reset()
    assert det.streak == 0 and det.ema == ema_before
    assert not det.update(math.nan)   # non-finite is the other guard's job
    # within warmup, even a clear spike never trips
    early = guards.SpikeDetector(factor=2.0, patience=1, beta=0.5, warmup=3)
    early.update(1.0)
    early.update(1.0)
    assert not early.update(50.0)     # n=2 < warmup=3


def test_drop_watermark_rearm_and_disable():
    wm = guards.DropWatermark(watermark=0.5, patience=2)
    assert not wm.update(0.6)
    assert wm.update(0.6)             # sustained breach -> one alarm
    assert not wm.update(0.6)         # re-armed: streak restarts
    assert guards.DropWatermark(watermark=1.0).update(0.99) is False
    assert guards.DropWatermark(watermark=0.5).update(None) is False


def test_chaos_schedules_are_pure_and_deterministic():
    cfg = ChaosConfig(seed=7, nan_grad_steps=(3,), nan_loss_steps=(4,),
                      spike_steps=(5,), degraded_links=((2, "pod", 8.0),
                                                        (6, "pod", 2.0)))
    healthy = chaos_lib.fault_scales(cfg, 0)
    assert healthy == {"loss_mult": 1.0, "grad_mult": 1.0, "param_scale": 1.0}
    assert math.isnan(chaos_lib.fault_scales(cfg, 3)["grad_mult"])
    assert math.isnan(chaos_lib.fault_scales(cfg, 4)["loss_mult"])
    assert chaos_lib.fault_scales(cfg, 5)["param_scale"] == cfg.spike_scale
    # degradations persist and compound from their step onward
    assert chaos_lib.link_multipliers(cfg, 1) == {}
    assert chaos_lib.link_multipliers(cfg, 2) == {"pod": 8.0}
    assert chaos_lib.link_multipliers(cfg, 6) == {"pod": 16.0}
    assert chaos_lib.fault_scales(None, 3)["grad_mult"] == 1.0


def test_corrupt_checkpoint_is_seeded(tmp_path):
    a, b = str(tmp_path / "a.bin"), str(tmp_path / "b.bin")
    payload = bytes(range(256)) * 8
    for p in (a, b):
        with open(p, "wb") as f:
            f.write(payload)
        chaos_lib.corrupt_checkpoint(p, seed=3)
    out_a, out_b = open(a, "rb").read(), open(b, "rb").read()
    assert out_a == out_b             # same seed -> identical flips
    assert out_a != payload


# ---------------------------------------------------------------------------
# checkpoint integrity (satellite: loud restore + manifest)
# ---------------------------------------------------------------------------


def _tree():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones((4,), np.int32)}


def test_ckpt_roundtrip_and_latest_step(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt.save(path, _tree(), step=11)
    out = ckpt.restore(path, _tree())
    assert np.array_equal(out["w"], _tree()["w"])
    assert ckpt.latest_step(path) == 11
    assert ckpt.verify(path)


def test_ckpt_restore_names_missing_and_extra_keys(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt.save(path, {"w": _tree()["w"]})
    with pytest.raises(ValueError, match="missing key 'b'"):
        ckpt.restore(path, _tree())
    ckpt.save(path, _tree())
    with pytest.raises(ValueError, match="extra key 'b'"):
        ckpt.restore(path, {"w": _tree()["w"]})


def test_ckpt_restore_refuses_shape_and_dtype_drift(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt.save(path, _tree())
    bad_shape = {"w": np.zeros((3, 2), np.float32), "b": _tree()["b"]}
    with pytest.raises(ValueError, match="key 'w' has shape"):
        ckpt.restore(path, bad_shape)
    bad_dtype = {"w": _tree()["w"], "b": np.ones((4,), np.float32)}
    with pytest.raises(ValueError, match="refusing to cast"):
        ckpt.restore(path, bad_dtype)


def test_ckpt_manifest_catches_corruption(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt.save(path, _tree())
    chaos_lib.corrupt_checkpoint(path, seed=0)
    assert not ckpt.verify(path)
    with pytest.raises(Exception):    # manifest ValueError or a broken zip
        ckpt.restore(path, _tree())


def test_ckpt_pre_manifest_checkpoints_still_restore(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt.save(path, _tree())
    os.unlink(path + ".meta.json")    # pre-manifest era: no sidecar
    out = ckpt.restore(path, _tree())
    assert np.array_equal(out["b"], _tree()["b"])
    assert not ckpt.verify(path)      # but verify() refuses to vouch for it


# ---------------------------------------------------------------------------
# guarded training loop (chaos scenarios end to end)
# ---------------------------------------------------------------------------


def test_guards_on_no_chaos_is_bit_identical(mesh11):
    """The guarded step with no fault firing must train bit-identically to
    the plain loop: fault multipliers of 1.0 are IEEE-exact and the healthy
    path runs no extra per-leaf work."""
    plain = _train(mesh11, _run_cfg(), steps=4)
    guarded = _train(mesh11, _run_cfg(resilience=ResilienceConfig()), steps=4)
    for a, b in zip(jax.tree_util.tree_leaves(plain.params),
                    jax.tree_util.tree_leaves(guarded.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert guarded.skipped_steps == 0 and guarded.rollbacks == 0
    assert guarded.metrics_history[-1]["skipped_steps"] == 0


def test_nan_grad_step_is_skipped_and_run_survives(mesh11):
    res = ResilienceConfig(chaos=ChaosConfig(nan_grad_steps=(2,),
                                             nan_loss_steps=(4,)))
    r = _train(mesh11, _run_cfg(resilience=res), steps=7)
    assert r.skipped_steps == 2       # one grad fault + one loss fault
    assert math.isfinite(r.losses[-1])
    for leaf in jax.tree_util.tree_leaves(r.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert r.metrics_history[-1]["skipped_steps"] == 2


def test_spike_rollback_restores_exact_pre_spike_params(mesh11, tmp_path):
    """Param corruption at step 6 spikes the loss; patience-2 detection
    rolls back at step 8 — the final step — so the returned params must be
    bitwise the step-5 rolling checkpoint."""
    ck = str(tmp_path / "ck.npz")
    res = ResilienceConfig(rollback_on_spike=True, spike_factor=1.5,
                           spike_patience=2, spike_warmup=3,
                           chaos=ChaosConfig(spike_steps=(6,)))
    r = _train(mesh11, _run_cfg(resilience=res), steps=9,
               ckpt_path=ck, ckpt_every=2, ckpt_keep=3)
    assert r.rollbacks == 1
    assert max(r.losses[7:9]) > 1.5 * r.losses[5]    # the spike was real
    good = ckpt.restore(str(tmp_path / "ck-000005.npz"),
                        {"params": r.params, "opt": r.opt_state})
    for a, b in zip(jax.tree_util.tree_leaves(r.params),
                    jax.tree_util.tree_leaves(good["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_rolling_ckpt_falls_back_to_previous(mesh11, tmp_path):
    """The newest rolling checkpoint (step 5) is byte-corrupted right after
    its save; the rollback must detect it via the sha256 manifest and
    restore the step-3 checkpoint instead."""
    ck = str(tmp_path / "ck.npz")
    res = ResilienceConfig(rollback_on_spike=True, spike_factor=1.5,
                           spike_patience=2, spike_warmup=3,
                           chaos=ChaosConfig(spike_steps=(6,),
                                             corrupt_ckpt_steps=(5,)))
    r = _train(mesh11, _run_cfg(resilience=res), steps=9,
               ckpt_path=ck, ckpt_every=2, ckpt_keep=3)
    assert r.rollbacks == 1
    assert not ckpt.verify(str(tmp_path / "ck-000005.npz"))
    good = ckpt.restore(str(tmp_path / "ck-000003.npz"),
                        {"params": r.params, "opt": r.opt_state})
    for a, b in zip(jax.tree_util.tree_leaves(r.params),
                    jax.tree_util.tree_leaves(good["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_rollback_without_rolling_ckpts_is_rejected(mesh11):
    res = ResilienceConfig(rollback_on_spike=True)
    with pytest.raises(ValueError, match="rollback_on_spike"):
        _train(mesh11, _run_cfg(resilience=res), steps=2)


def test_straggler_delay_does_not_change_results(mesh11):
    res = ResilienceConfig(chaos=ChaosConfig(straggler_steps=(1, 2),
                                             straggler_delay_s=0.01))
    slow = _train(mesh11, _run_cfg(resilience=res), steps=4)
    fast = _train(mesh11, _run_cfg(resilience=ResilienceConfig()), steps=4)
    assert slow.losses == fast.losses  # a stuck rank slows, never diverges


# ---------------------------------------------------------------------------
# serving: per-request deadlines with mid-decode eviction
# ---------------------------------------------------------------------------


def test_deadline_evicted_stream_frees_slot_for_waiters(mesh11, key):
    arch = dataclasses.replace(get_config(ARCH_ID).reduced(),
                               dtype="float32")
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                              aux_mode="none")
    with mesh11, sharding.axis_rules(model_lib.default_rules(mesh11)):
        params = model_lib.init_params(key, ctx)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab_size, size=5).tolist()
               for _ in range(3)]
    cfg = engine.ServeConfig(num_slots=2, cache_len=24, prefill_pack=2,
                             prompt_buckets=(16,))
    reqs = [Request(uid=0, tokens=prompts[0], max_new_tokens=15,
                    deadline_s=0.0),
            Request(uid=1, tokens=prompts[1], max_new_tokens=3),
            Request(uid=2, tokens=prompts[2], max_new_tokens=3)]
    with mesh11:
        eng = engine.ServingEngine(params, ctx, cfg)
        report = eng.run(reqs)
    assert report.evictions == 1
    evicted = [s for s in report.streams if s.evicted]
    assert [s.request.uid for s in evicted] == [0]
    assert len(evicted[0].generated) < 15     # partial output kept
    for uid in (1, 2):                        # waiters got the freed slot
        assert len(report.tokens_for(uid)) == 3


def test_no_deadline_means_no_eviction(mesh11, key):
    arch = dataclasses.replace(get_config(ARCH_ID).reduced(),
                               dtype="float32")
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                              aux_mode="none")
    with mesh11, sharding.axis_rules(model_lib.default_rules(mesh11)):
        params = model_lib.init_params(key, ctx)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, arch.vocab_size, size=4).tolist(),
                    max_new_tokens=3)
            for i in range(2)]
    cfg = engine.ServeConfig(num_slots=2, cache_len=24, prefill_pack=2,
                             prompt_buckets=(16,))
    with mesh11:
        report = engine.ServingEngine(params, ctx, cfg).run(reqs)
    assert report.evictions == 0
    assert all(not s.evicted for s in report.streams)


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------


def test_policy_classify_precedence_and_counters():
    pol = RecoveryPolicy(ResilienceConfig(rollback_on_spike=True,
                                          spike_factor=2.0, spike_patience=1,
                                          spike_warmup=0))
    assert pol.classify(0, {"nonfinite": 0.0, "loss": 1.0}) == "ok"
    assert pol.classify(1, {"nonfinite": 1.0, "loss": 1.0}) == "skip"
    assert pol.classify(2, {"nonfinite": 0.0, "loss": math.nan}) == "skip"
    assert pol.healthy
    assert pol.classify(3, {"nonfinite": 0.0, "loss": 50.0}) == "rollback"
    pol.on_rollback()
    assert pol.healthy
    assert pol.counters() == {"skipped_steps": 2, "rollbacks": 1,
                              "replans": 0, "drop_alarms": 0}
