"""Pipelined hierarchical dispatch (comm–compute overlap).

The pipelined schedule must be numerically equivalent to the sync ``a2a``
path at matched capacities — same routing, same capacities, only the
execution order differs.  Multi-rank equivalence runs in
test_multidevice.py; here the 1-device mesh isolates the chunking /
padding / pipeline-schedule logic, plus the capacity alignment and the
alpha-beta overlap model."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.core import capacity, comm_model, gating, moe as moe_lib
from repro.core.capacity import make_plan
from repro.models import model as model_lib

D, F, N, K, T = 16, 32, 4, 2, 64


def _setup(key, capacity_factor=8.0, shared=0, round_multiple=8):
    cfg = moe_lib.MoEConfig(d_model=D, d_ff=F, num_experts=N, top_k=K,
                            capacity_factor=capacity_factor,
                            num_shared_experts=shared, dtype=jnp.float32)
    ep = moe_lib.EPSpec(num_pods=1, ep_per_pod=1, pod_axis=None,
                        data_axis="data", model_axis="model")
    gate_cfg = gating.GateConfig(num_experts=N, top_k=K, aux_mode="lb")
    params = moe_lib.init_moe_params(key, cfg, ep, gate_cfg)
    plan = make_plan(tokens_per_device=T, num_experts=N, top_k=K,
                     capacity_factor=capacity_factor, num_pods=1,
                     ep_per_pod=1, mode="even", round_multiple=round_multiple)
    return cfg, ep, gate_cfg, params, plan


def _run(fn, mesh, params, x):
    from jax.sharding import PartitionSpec as P
    body = shard_map(fn, mesh=mesh, in_specs=(P(), P()),
                     out_specs=(P(), P()), check_vma=False)
    with mesh:
        return body(params, x)


@pytest.mark.parametrize("num_chunks", [1, 2, 3, 4])
def test_pipelined_matches_a2a(key, mesh11, num_chunks):
    cfg, ep, gate_cfg, params, plan = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
    y0, m0 = _run(lambda p, xx: moe_lib.moe_apply_a2a(
        p, xx, cfg, ep, plan, gate_cfg), mesh11, params, x)
    y1, m1 = _run(lambda p, xx: moe_lib.moe_apply_a2a_pipelined(
        p, xx, cfg, ep, plan, gate_cfg, num_chunks=num_chunks),
        mesh11, params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               atol=1e-5, rtol=1e-5)
    for k in m0:
        np.testing.assert_allclose(np.asarray(m1[k]), np.asarray(m0[k]),
                                   atol=1e-6, err_msg=k)


def test_pipelined_pads_undivisible_capacity(key, mesh11):
    """caps[0] = 15 does not divide by 4 chunks; the zero-padded slots must
    not change the output."""
    cfg, ep, gate_cfg, params, plan = _setup(key, round_multiple=1)
    plan = dataclasses.replace(plan, caps=(15,))
    assert plan.cap_near == 15   # deprecated alias tracks caps[0]
    x = jax.random.normal(jax.random.PRNGKey(2), (T, D), jnp.float32)
    y0, m0 = _run(lambda p, xx: moe_lib.moe_apply_a2a(
        p, xx, cfg, ep, plan, gate_cfg), mesh11, params, x)
    y1, m1 = _run(lambda p, xx: moe_lib.moe_apply_a2a_pipelined(
        p, xx, cfg, ep, plan, gate_cfg, num_chunks=4), mesh11, params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               atol=1e-5, rtol=1e-5)
    assert float(m0["dropped"]) == pytest.approx(float(m1["dropped"]),
                                                 abs=1e-6)


def test_pipelined_with_shared_experts_and_drops(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key, capacity_factor=0.5,
                                             shared=1, round_multiple=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (T, D), jnp.float32)
    y0, m0 = _run(lambda p, xx: moe_lib.moe_apply_a2a(
        p, xx, cfg, ep, plan, gate_cfg), mesh11, params, x)
    y1, m1 = _run(lambda p, xx: moe_lib.moe_apply_a2a_pipelined(
        p, xx, cfg, ep, plan, gate_cfg, num_chunks=2), mesh11, params, x)
    assert float(m0["dropped"]) > 0.1          # the tight-capacity regime
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               atol=1e-5, rtol=1e-5)


def test_grad_flows_through_pipelined(key, mesh11):
    cfg, ep, gate_cfg, params, plan = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(4), (T, D), jnp.float32)

    def loss(p, pipelined):
        fn = (lambda pp, xx: moe_lib.moe_apply_a2a_pipelined(
            pp, xx, cfg, ep, plan, gate_cfg, num_chunks=2)) if pipelined \
            else (lambda pp, xx: moe_lib.moe_apply_a2a(
                pp, xx, cfg, ep, plan, gate_cfg))
        y, m = _run(fn, mesh11, p, x)
        return jnp.sum(y ** 2) + m["aux_loss"]

    g0 = jax.grad(lambda p: loss(p, False))(params)
    g1 = jax.grad(lambda p: loss(p, True))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        assert np.isfinite(np.asarray(b)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_software_pipeline_schedule():
    """The skeleton must issue combine(t-2), compute(t-1), dispatch(t) per
    tick, cover every chunk exactly once per stage, and preserve order."""
    trace = []
    out = moe_lib.software_pipeline(
        3,
        lambda j: trace.append(("d", j)) or j,
        lambda j, v: trace.append(("g", j)) or v * 10,
        lambda acc, j, v: trace.append(("c", j)) or acc + [v],
        [])
    assert out == [0, 10, 20]
    for stage in "dgc":
        assert [j for s, j in trace if s == stage] == [0, 1, 2]
    # steady state: dispatch of chunk 2 is issued before compute of chunk 1
    # finishes the combine of chunk 0 (3-deep pipeline window)
    assert trace.index(("d", 2)) < trace.index(("c", 1))
    assert trace.index(("d", 1)) < trace.index(("c", 0))


def test_align_to_chunks():
    plan = make_plan(tokens_per_device=4096, num_experts=16, top_k=2,
                     capacity_factor=1.0, num_pods=2, ep_per_pod=4,
                     mode="ta", round_multiple=1)
    for k in (1, 2, 3, 4, 8):
        al = capacity.align_to_chunks(plan, k)
        assert al.num_chunks == k
        assert al.cap_near % k == 0 and al.cap_far % k == 0
        assert al.cap_near >= plan.cap_near      # lossless: never shrink
        assert al.cap_far >= plan.cap_far
        assert al.cap_near - plan.cap_near < k
        assert al.chunk_near * k == al.cap_near


def test_pipelined_time_model():
    # k=1 degenerates to the fully-serialized schedule
    assert comm_model.pipelined_time(4.0, 6.0, 4.0, 1, alpha=0.5) \
        == pytest.approx(2 * (4.0 + 0.5) + 6.0)
    # with zero alpha, more chunks never hurt
    ts = [comm_model.pipelined_time(4.0, 6.0, 4.0, k) for k in (1, 2, 4, 8)]
    assert all(a >= b - 1e-12 for a, b in zip(ts, ts[1:]))
    # asymptote: the bottleneck stage's full time
    assert ts[-1] >= 6.0
    # a large alpha makes chunking counterproductive and the chooser says so
    assert comm_model.choose_num_chunks(t_exchange=1e-6, t_compute=1e-6,
                                        alpha=1.0) == 1
    # compute-rich + cheap alpha: chooser goes wide
    assert comm_model.choose_num_chunks(t_exchange=1.0, t_compute=8.0,
                                        alpha=0.0) == 8


def test_estimate_overlap_speedup_bounds():
    est = comm_model.estimate_overlap(t_exchange=1.0, t_compute=2.0,
                                      alpha=0.0, num_chunks=4)
    assert est.t_pipelined <= est.t_sync + 1e-12
    assert 0.0 <= est.overlapped_fraction < 1.0
    # perfect-overlap upper bound: can't beat the bottleneck stage
    assert est.t_pipelined >= 2.0


def test_build_ctx_plumbs_pipelined_dispatch(mesh11):
    from repro.configs.base import get_config
    arch = get_config("gpt3_medium_moe").reduced()
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                              aux_mode="ta", dispatch="a2a_pipelined",
                              a2a_num_chunks=3)
    assert ctx.dispatch == "a2a_pipelined"
    assert ctx.a2a_num_chunks == 3
    assert ctx.plan.num_chunks == 3
    assert ctx.plan.cap_near % 3 == 0
    # auto mode resolves to a concrete chunk count via the overlap model
    ctx_auto = model_lib.build_ctx(arch, mesh11, seq_len=32, global_batch=4,
                                   aux_mode="ta", dispatch="a2a_pipelined")
    assert ctx_auto.a2a_num_chunks >= 1
    assert ctx_auto.plan.num_chunks == ctx_auto.a2a_num_chunks


def test_train_step_parity_pipelined_vs_sync(mesh11):
    """One full train step through the model stack: the pipelined schedule
    must produce the same loss as sync dispatch at matched capacities."""
    from repro.configs.base import RunConfig, get_config
    from repro.training import trainer
    arch = get_config("gpt3_medium_moe").reduced()
    base = dict(seq_len=32, global_batch=4, learning_rate=1e-3,
                total_steps=10, warmup_steps=2, aux_mode="ta")
    r_sync = trainer.train(arch, RunConfig(**base), mesh11, steps=5,
                           log_every=1, verbose=False)
    # num_chunks=1 keeps capacities identical -> losses must match exactly;
    # chunked runs stay allclose (scatter-add order differs per chunk).
    r_p1 = trainer.train(arch, RunConfig(**base, dispatch="a2a_pipelined",
                                         a2a_num_chunks=1), mesh11, steps=5,
                         log_every=1, verbose=False)
    np.testing.assert_allclose(r_p1.losses, r_sync.losses, rtol=1e-6)
    r_p2 = trainer.train(arch, RunConfig(**base, dispatch="a2a_pipelined",
                                         a2a_num_chunks=2), mesh11, steps=5,
                         log_every=1, verbose=False)
    np.testing.assert_allclose(r_p2.losses, r_sync.losses, rtol=1e-4)
    assert all(np.isfinite(r_p2.losses))


def test_grouped_ffn_chunk_matches_unchunked(key):
    from repro.kernels.moe_gemm import ops
    x = jax.random.normal(key, (4, 37, D), jnp.float32)   # ragged rows
    w_in = jax.random.normal(jax.random.PRNGKey(1), (4, D, F), jnp.float32)
    w_gate = jax.random.normal(jax.random.PRNGKey(2), (4, D, F), jnp.float32)
    w_out = jax.random.normal(jax.random.PRNGKey(3), (4, F, D), jnp.float32)
    y0 = ops.grouped_ffn(x, w_in, w_gate, w_out)
    y1 = ops.grouped_ffn_chunk(x, w_in, w_gate, w_out)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-5, rtol=1e-5)
