"""Prefill/decode consistency: parallel full-sequence forward must agree
with stepwise recurrent decode for every mixer family — the strongest
correctness check on cache layouts and recurrences."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.configs.base import get_config
from repro.models import decode as decode_lib
from repro.models import layers, mamba as mamba_lib, mla as mla_lib
from repro.models import model as model_lib, transformer, xlstm as xlstm_lib

S = 12
B = 2


def _roundtrip(arch_id, mesh11, key, tol=2e-2):
    """Teacher-forced decode logits must match full-forward logits."""
    arch = get_config(arch_id).reduced()
    arch = dataclasses.replace(arch, dtype="float32")
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=S, global_batch=B,
                              aux_mode="none")
    rules = model_lib.default_rules(mesh11)
    toks = jax.random.randint(key, (B, S), 0, arch.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if arch.frontend:
        d = 1024 if arch.frontend == "vision" else arch.d_model
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(9), (B, arch.frontend_len, d), jnp.float32)
    with mesh11, sharding.axis_rules(rules):
        params = model_lib.init_params(key, ctx)
        full_logits, _ = jax.jit(
            lambda p, b: transformer.forward(p, b, ctx))(params, batch)
        cache = decode_lib.init_cache(ctx, B, max_len=S)
        if arch.family == "audio":
            enc_out = transformer._run_encoder(
                params, batch["frontend"], ctx)
            cache = decode_lib.fill_cross_cache(params, cache, enc_out, ctx)
        step = jax.jit(lambda p, c, t: decode_lib.decode_step(p, c, t, ctx))
        dec = []
        for t in range(S):
            lg, cache = step(params, cache, toks[:, t:t + 1])
            dec.append(lg[:, 0])
        dec_logits = jnp.stack(dec, axis=1)
    if arch.family == "vlm":
        # prefill replaces the first frontend_len embeddings with patches;
        # compare only the pure-text tail
        n = arch.frontend_len
        full_logits = full_logits[:, n:]
        dec_logits = dec_logits[:, n:]
        return  # decode stream differs by construction; covered elsewhere
    err = np.max(np.abs(np.asarray(full_logits) - np.asarray(dec_logits)))
    assert err < tol, f"{arch_id}: prefill/decode mismatch {err}"


@pytest.mark.parametrize("arch_id", [
    "internlm2_1_8b", "olmo_1b", "granite_3_2b", "minitron_4b",
])
def test_dense_prefill_decode_match(arch_id, mesh11, key):
    _roundtrip(arch_id, mesh11, key)


def test_mla_prefill_decode_match(mesh11, key):
    """Absorbed-form decode must match expanded-form prefill (DeepSeek)."""
    cfg = mla_lib.MLAConfig(d_model=64, num_heads=4, kv_lora_rank=32,
                            qk_nope_dim=16, qk_rope_dim=8, v_dim=16,
                            dtype=jnp.float32)
    params = mla_lib.init_mla(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 64), jnp.float32)
    full, _ = mla_lib.mla_apply(params, x, cfg)
    cache = mla_lib.init_mla_cache(B, S, cfg)
    outs = []
    for t in range(S):
        o, cache = mla_lib.mla_decode(params, x[:, t:t + 1], cache, cfg)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=2e-4, rtol=2e-3)


def test_mamba_parallel_vs_recurrent(key):
    cfg = mamba_lib.MambaConfig(d_model=32, d_state=8, dtype=jnp.float32)
    params = mamba_lib.init_mamba(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, 32), jnp.float32)
    full = mamba_lib.mamba_apply(params, x, cfg)
    state = mamba_lib.init_mamba_state(B, cfg)
    outs = []
    for t in range(S):
        o, state = mamba_lib.mamba_decode(params, x[:, t:t + 1], state, cfg)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_parallel_vs_recurrent(key):
    cfg = xlstm_lib.XLSTMConfig(d_model=32, num_heads=2, dtype=jnp.float32)
    params = xlstm_lib.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, 32), jnp.float32)
    full = xlstm_lib.mlstm_apply(params, x, cfg)
    state = xlstm_lib.init_mlstm_state(B, cfg)
    outs = []
    for t in range(S):
        o, state = xlstm_lib.mlstm_decode(params, x[:, t:t + 1], state, cfg)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=2e-4, rtol=2e-3)


def test_slstm_stateful_continuation(key):
    """Running sLSTM over [0:S] equals running [0:k] then [k:S] with the
    carried state."""
    cfg = xlstm_lib.XLSTMConfig(d_model=32, num_heads=2, dtype=jnp.float32)
    params = xlstm_lib.init_slstm(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, 32), jnp.float32)
    full, _ = xlstm_lib.slstm_apply(params, x, cfg)
    k = S // 2
    y1, st = xlstm_lib.slstm_apply(params, x[:, :k], cfg)
    y2, _ = xlstm_lib.slstm_apply(params, x[:, k:], cfg, state=st)
    dec = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=1e-5, rtol=1e-5)


def test_whisper_decode_with_cross_cache(mesh11, key):
    _roundtrip("whisper_tiny", mesh11, key)


def test_sliding_window_masks_old_tokens(key):
    """Full attention != sliding window on long sequences; window result
    matches a manually masked reference."""
    cfg = layers.AttnConfig(d_model=32, num_heads=2, num_kv_heads=2,
                            head_dim=16, sliding_window=4,
                            dtype=jnp.float32)
    params = layers.init_attn(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 10, 32), jnp.float32)
    out_w, _ = layers.attn_apply(params, x, cfg)
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    out_f, _ = layers.attn_apply(params, x, cfg_full)
    assert np.abs(np.asarray(out_w) - np.asarray(out_f)).max() > 1e-6
