"""Tests for the static contract checkers (repro.analysis).

The collective-inventory tests are the fast lane the ISSUE asked for:
every dispatch path is verified on a 2-level (2×2) and a 3-level (2×2×2)
mesh via AOT **lowering only** — an abstract mesh needs no devices and
nothing executes, so these run on the single-CPU unit-test rig.
"""

import dataclasses

import pytest

from repro.analysis import fixtures, hlo_check, lint, pallas_check
from repro.analysis.__main__ import main as analysis_main
from repro.kernels import backend


# ---------------------------------------------------------------------------
# HLO collective verifier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("path", ["a2a", "a2a_pipelined", "gather", "einsum"])
@pytest.mark.parametrize("axis_sizes", [(2, 2), (2, 2, 2)],
                         ids=["2x2", "2x2x2"])
def test_collective_inventory_all_paths_both_meshes(path, axis_sizes):
    """All four dispatch paths on the 2-level and 3-level meshes, kernels
    on: the lowered collective inventory matches the plan-derived
    expectation exactly."""
    sc = hlo_check.Scenario(f"{path}-{len(axis_sizes)}lvl", axis_sizes, path,
                            True, num_chunks=2 if path == "a2a_pipelined"
                            else 1)
    assert hlo_check.verify(sc) == []


def test_a2a_inventory_shape_2x2_kernels_on():
    """Pin the expected inventory's *content* on the (2,2) mesh: stage 0
    hops once, stage 1 twice; each hop carries dispatch + combine payload
    a2a's in the wire dtype plus the int32 counts exchange."""
    sc = hlo_check.Scenario("pin", (2, 2), "a2a", True)
    exp = hlo_check.expected_inventory(sc)
    assert len(exp) == 9  # (1 + 2) hops x (dispatch, combine, counts)
    assert all(c.kind == "all_to_all" for c in exp)
    assert sum(c.dtype == "i32" for c in exp) == 3
    assert sum(c.dtype == "f32" for c in exp) == 6
    # caps (16, 8), E_l = 4, d = 16: payload elements scale with the cap —
    # stage 0 sends 2 dests x 4 experts x cap 16 over 1 hop, stage 1
    # 4 x 4 x cap 8 over 2 hops (dispatch + combine each)
    payloads = sorted(c.elements for c in exp if c.dtype == "f32")
    assert payloads == [2 * 4 * 16 * 16] * 2 + [4 * 4 * 8 * 16] * 4


def test_a2a_kernels_off_drops_counts_chain():
    sc = hlo_check.Scenario("ref", (2, 2), "a2a", False)
    exp = hlo_check.expected_inventory(sc)
    assert len(exp) == 6 and not any(c.dtype == "i32" for c in exp)
    assert hlo_check.verify(sc) == []


def test_pipelined_inventory_scales_with_chunks():
    one = hlo_check.expected_inventory(
        hlo_check.Scenario("nc1", (2, 2), "a2a", True))
    two = hlo_check.expected_inventory(
        hlo_check.Scenario("nc2", (2, 2), "a2a_pipelined", True,
                           num_chunks=2))
    assert len(two) == 2 * len(one)
    # chunked payloads halve per op; total wire bytes are conserved
    tot = sum(c.elements for c in one if c.dtype == "f32")
    assert sum(c.elements for c in two if c.dtype == "f32") == tot


def test_gather_path_has_no_a2a():
    exp = hlo_check.expected_inventory(
        hlo_check.Scenario("g", (2, 2), "gather", False))
    kinds = {c.kind for c in exp}
    assert kinds == {"all_gather", "all_reduce"}


def test_replica_groups_match_level_axes():
    """The 3-level mesh's axis groups: innermost 'data' groups adjacent
    ids, outermost 'pod' strides across the whole lower hierarchy."""
    names, sizes = ("pod", "node", "data"), (2, 2, 2)
    assert hlo_check.axis_groups(names, sizes, "data") == (
        (0, 1), (2, 3), (4, 5), (6, 7))
    assert hlo_check.axis_groups(names, sizes, "pod") == (
        (0, 4), (1, 5), (2, 6), (3, 7))


def test_parse_collectives_stablehlo_forms():
    text = """
      %5 = "stablehlo.all_to_all"(%4) <{concat_dimension = 0 : i64,
      replica_groups = dense<[[0, 2], [1, 3]]> : tensor<2x2xi64>,
      split_count = 2 : i64}> : (tensor<2x4x16xf32>) -> tensor<2x4x16xf32>
    """.replace("\n      ", " ")
    (c,) = hlo_check.parse_collectives(text)
    assert c.kind == "all_to_all" and c.dtype == "f32"
    assert c.elements == 2 * 4 * 16
    assert c.groups == ((0, 2), (1, 3))


def test_match_inventory_flags_both_directions():
    a2a = hlo_check.Collective("all_to_all", "f32", 8, ((0, 1),))
    missing = hlo_check.match_inventory("w", [a2a], [])
    assert len(missing) == 1 and "missing" in missing[0].message
    extra = hlo_check.match_inventory("w", [], [a2a])
    assert len(extra) == 1 and "unexpected" in extra[0].message
    assert hlo_check.match_inventory("w", [a2a], [a2a]) == []


# ---------------------------------------------------------------------------
# Pallas kernel analyzer
# ---------------------------------------------------------------------------


def test_registered_kernel_layouts_pass():
    violations, covered = pallas_check.run()
    assert violations == []
    assert {"moe_gemm.grouped_ffn", "moe_gemm.grouped_ffn_ragged",
            "moe_fused.local_moe", "moe_permute.permute",
            "moe_permute.unpermute"} <= set(covered)


def test_fused_layout_depends_on_acc_guard():
    """The fused megakernel's declared layout is exactly the scatter-
    revisit pattern: flipping its acc_guarded flag off must trip the
    race check."""
    (layout,) = backend.KERNEL_REGISTRY["moe_fused.local_moe"]()
    blocks = tuple(dataclasses.replace(b, acc_guarded=False)
                   if b.kind == "out" else b for b in layout.blocks)
    bad = dataclasses.replace(layout, blocks=blocks)
    assert any(v.rule == "scatter-race"
               for v in pallas_check.check_layout(bad))


def test_index_bounds_catches_oob_map():
    def bad_map(i):
        return (i + 1,)  # walks one block past the end

    layout = backend.KernelLayout(
        kernel="t", grid=(4,),
        blocks=(backend.BlockDecl("x", "in", 4, (8,), (32,), bad_map),))
    v = pallas_check.check_index_bounds(layout)
    assert len(v) == 1 and v[0].rule == "index-bounds"


def test_plan_blocks_invariants_catch_straddle():
    import numpy as np

    (layout,) = backend.KERNEL_REGISTRY["moe_gemm.grouped_ffn_ragged"]()
    brow, beid, nv = layout.prefetch
    # shift one block's row so it straddles a segment boundary
    brow = np.array(brow)
    brow[1] = brow[1] + 1000
    bad = backend.KernelLayout(kernel=layout.kernel, grid=layout.grid,
                               blocks=layout.blocks,
                               prefetch=(brow, beid, nv), meta=layout.meta)
    assert any(v.rule == "plan-blocks"
               for v in pallas_check.check_plan_blocks(bad))


# ---------------------------------------------------------------------------
# repo-rule lint
# ---------------------------------------------------------------------------


def test_lint_clean_on_head():
    violations, covered = lint.run()
    assert violations == []
    assert any(f.endswith("compat.py") for f in covered)


def test_lint_rules_fire_on_fixture():
    rules = {v.rule for v in fixtures.run_fixture("raw_shard_map")}
    assert rules == {"raw-shard-map", "np-in-traced",
                     "mutable-config-closure"}


def test_lint_allows_compat_itself():
    src = "import jax\nmesh = jax.make_mesh((2,), ('x',))\n"
    assert lint.lint_source(src, "src/repro/compat.py") == []
    assert lint.lint_source(src, "src/repro/other.py")


# ---------------------------------------------------------------------------
# fixtures + CLI
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(fixtures.FIXTURES))
def test_every_fixture_fires(name):
    assert fixtures.run_fixture(name), f"fixture {name} reported nothing"


@pytest.mark.parametrize("name", ["vmem_over_budget", "raw_shard_map"])
def test_cli_exits_nonzero_on_fixture(name, capsys):
    assert analysis_main(["--fixture", name]) == 1
    capsys.readouterr()


def test_cli_lint_lane_green_on_head(tmp_path, capsys):
    import json

    out = tmp_path / "report.json"
    assert analysis_main(["--only", "lint", "--only", "pallas",
                          "--json", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["ok"] and report["violations"] == []
    assert set(report["checked"]) == {"lint", "pallas"}
    capsys.readouterr()


# ---------------------------------------------------------------------------
# strict REPRO_KERNEL_INTERPRET parsing (kernels/backend.py)
# ---------------------------------------------------------------------------


class TestKernelInterpretEnv:
    def _with(self, value, monkeypatch):
        if value is None:
            monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
        else:
            monkeypatch.setenv("REPRO_KERNEL_INTERPRET", value)

    @pytest.mark.parametrize("value", ["1", "true", "TRUE", " 1 "])
    def test_truthy(self, value, monkeypatch):
        self._with(value, monkeypatch)
        assert backend.env_interpret() is True
        assert backend.want_pallas(None) is True

    @pytest.mark.parametrize("value", ["0", "false", "False", " 0 "])
    def test_falsy(self, value, monkeypatch):
        self._with(value, monkeypatch)
        assert backend.env_interpret() is False

    def test_unset(self, monkeypatch):
        self._with(None, monkeypatch)
        assert backend.env_interpret() is False

    @pytest.mark.parametrize("value", ["yes", "on", "2", ""])
    def test_garbage_raises(self, value, monkeypatch):
        self._with(value, monkeypatch)
        with pytest.raises(ValueError, match="REPRO_KERNEL_INTERPRET"):
            backend.env_interpret()
        with pytest.raises(ValueError):
            backend.want_pallas(None)

    def test_explicit_flag_skips_env(self, monkeypatch):
        # a forced use_pallas never consults the env var
        self._with("garbage", monkeypatch)
        assert backend.want_pallas(True) is True
        assert backend.want_pallas(False) is False
