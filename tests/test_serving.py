"""Continuous-batching serving layer: scheduler admit/evict/slot-reuse,
prefill packing equivalence, slotted KV-cache ops, and batched-generate
parity with the single-stream driver.

The recompilation assertions use the jit cache size of the engine's own
compiled functions — the no-recompile invariant (fixed pack width,
bucketed prompt pads, fixed slot count) is the whole point of the slot
design, so a second cache entry is a regression, not a detail.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.configs.base import get_config
from repro.models import model as model_lib
from repro.serving import batching, engine
from repro.serving.scheduler import Request, Scheduler

ARCH_ID = "gpt3_medium_moe"


def _build(mesh11, key, arch_id=ARCH_ID, seq_len=32, batch=4):
    arch = dataclasses.replace(get_config(arch_id).reduced(), dtype="float32")
    ctx = model_lib.build_ctx(arch, mesh11, seq_len=seq_len,
                              global_batch=batch, aux_mode="none")
    with mesh11, sharding.axis_rules(model_lib.default_rules(mesh11)):
        params = model_lib.init_params(key, ctx)
    return arch, ctx, params


def _prompts(arch, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab_size, size=n).tolist() for n in lens]


# ---------------------------------------------------------------------------
# scheduler (pure python, no jax)
# ---------------------------------------------------------------------------


def test_scheduler_slot_exhaustion():
    sched = Scheduler(num_slots=2)
    for i in range(5):
        sched.submit(Request(uid=i, tokens=[1, 2], max_new_tokens=3))
    admits = sched.take(10, now=0.0)
    assert [s for s, _ in admits] == [0, 1]
    assert sched.num_active == 2 and sched.num_pending == 3
    # pool exhausted: further takes admit nothing
    assert sched.take(10, now=0.0) == []
    sched.on_token(0, 7)
    # freeing one slot admits exactly one more request, into that slot
    sched.complete(0, now=1.0)
    admits = sched.take(10, now=1.0)
    assert [s for s, _ in admits] == [0]
    assert admits[0][1].uid == 2


def test_scheduler_variable_length_completion_and_reuse():
    sched = Scheduler(num_slots=3)
    for i, budget in enumerate([1, 3, 2]):
        sched.submit(Request(uid=i, tokens=[5], max_new_tokens=budget))
    [(s0, _), (s1, _), (s2, _)] = sched.take(3, now=0.0)
    # stream 0 finishes first (budget 1), then 2, then 1
    assert sched.on_token(s0, 11) is True
    sched.complete(s0, now=0.1)
    assert sched.on_token(s1, 12) is False
    assert sched.on_token(s2, 13) is False
    assert sched.on_token(s2, 14) is True
    sched.complete(s2, now=0.2)
    # lowest freed slot (0) is reused first, deterministically
    sched.submit(Request(uid=9, tokens=[5], max_new_tokens=1))
    assert sched.take(1, now=0.3)[0][0] == min(s0, s2) == 0
    assert [st.request.uid for st in sched.finished] == [0, 2]
    assert sched.finished[1].generated == [13, 14]


def test_scheduler_validation():
    sched = Scheduler(num_slots=1)
    with pytest.raises(ValueError):
        sched.submit(Request(uid=0, tokens=[], max_new_tokens=1))
    with pytest.raises(ValueError):
        sched.submit(Request(uid=0, tokens=[1], max_new_tokens=0))
    sched.submit(Request(uid=0, tokens=[1], max_new_tokens=1))
    [(slot, _)] = sched.take(1, now=0.0)
    assert sched.on_token(slot, 3) is True
    with pytest.raises(ValueError):
        sched.on_token(slot, 4)       # stream already complete
    with pytest.raises(ValueError):
        Scheduler(num_slots=0)


def test_pad_pack_and_buckets():
    assert batching.pick_bucket(5, (8, 16)) == 8
    assert batching.pick_bucket(9, (8, 16)) == 16
    with pytest.raises(ValueError):
        batching.pick_bucket(17, (8, 16))
    tokens, lens = batching.pad_pack([[1, 2, 3], [4]], pack=4,
                                     buckets=(8,))
    assert tokens.shape == (4, 8) and lens.shape == (4,)
    assert list(np.asarray(lens)) == [3, 1, 1, 1]   # padded rows: dummy len 1
    assert list(np.asarray(tokens[0, :3])) == [1, 2, 3]
    assert int(tokens[1, 0]) == 4
    with pytest.raises(ValueError):
        batching.pad_pack([[1]] * 5, pack=4, buckets=(8,))


# ---------------------------------------------------------------------------
# slotted KV cache
# ---------------------------------------------------------------------------


def test_slot_cache_insert_evict_reuse_no_recompile(mesh11, key):
    arch, ctx, params = _build(mesh11, key)
    cache_len, pack = 24, 2
    kv = batching.SlotKVCache(ctx, num_slots=4, cache_len=cache_len)
    prefill = jax.jit(engine.make_prefill(ctx, with_cache=True,
                                          cache_len=cache_len))
    prompts = _prompts(arch, [6, 9])
    tokens, lens = batching.pad_pack(prompts, pack, buckets=(16,))
    with mesh11:
        _, pack_cache = prefill(params, {"tokens": tokens, "lens": lens})
        # second pack row carries an out-of-range slot id -> dropped
        kv.insert(pack_cache, jnp.asarray([2, kv.num_slots], jnp.int32))
        assert list(kv.positions()) == [0, 0, 6, 0]
        kv.insert(pack_cache, jnp.asarray([0, 3], jnp.int32))
        assert list(kv.positions()) == [6, 0, 6, 9]
        kv.evict(jnp.asarray([2, 3], jnp.int32))
        assert list(kv.positions()) == [6, 0, 0, 0]
        # re-admitting into the freed slots reuses the same compiled fns
        kv.insert(pack_cache, jnp.asarray([2, 3], jnp.int32))
        assert list(kv.positions()) == [6, 0, 6, 9]
    assert kv._insert._cache_size() == 1
    assert kv._evict._cache_size() == 1


# ---------------------------------------------------------------------------
# prefill packing equivalence
# ---------------------------------------------------------------------------


def test_prefill_packing_equivalence(mesh11, key):
    """A right-padded prompt pack must be indistinguishable from prefilling
    each prompt alone: same last logits, and same decode trajectory from
    the materialized cache (the strongest check that padded rows never
    leak into real rows — decode=True MoE dispatch is drop-free)."""
    arch, ctx, params = _build(mesh11, key)
    cache_len = 24
    lens_py = [4, 9, 6]
    prompts = _prompts(arch, lens_py)
    prefill = jax.jit(engine.make_prefill(ctx, with_cache=True,
                                          cache_len=cache_len))
    step = jax.jit(engine.make_decode_step(ctx))
    with mesh11:
        tokens, lens = batching.pad_pack(prompts, pack=4, buckets=(16,))
        logits_p, cache_p = prefill(params, {"tokens": tokens, "lens": lens})
        traj_p = [np.asarray(logits_p)]
        tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)[:, None]
        for _ in range(3):
            lg, cache_p = step(params, cache_p, tok)
            traj_p.append(np.asarray(lg[:, 0]))
            tok = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)[:, None]
        for i, p in enumerate(prompts):
            t1 = jnp.asarray(np.asarray(p, np.int32)[None])
            l1 = jnp.asarray([len(p)], jnp.int32)
            lg1, c1 = prefill(params, {"tokens": t1, "lens": l1})
            err = np.max(np.abs(np.asarray(lg1[0]) - traj_p[0][i]))
            assert err < 2e-4, f"prompt {i}: prefill logits diverge {err}"
            tok1 = jnp.argmax(lg1, axis=-1).astype(jnp.int32)[:, None]
            for k in range(3):
                lg1, c1 = step(params, c1, tok1)
                err = np.max(np.abs(np.asarray(lg1[0, 0]) - traj_p[k + 1][i]))
                assert err < 2e-4, f"prompt {i} step {k}: {err}"
                tok1 = jnp.argmax(lg1[:, 0], axis=-1).astype(jnp.int32)[:, None]
    # one packed entry + one per distinct single-prompt length
    assert prefill._cache_size() == 1 + len(set(lens_py))


def test_prefill_rejects_overlong_prompt(mesh11, key):
    arch, ctx, params = _build(mesh11, key)
    prefill = engine.make_prefill(ctx, with_cache=True, cache_len=8)
    toks = jnp.zeros((1, 12), jnp.int32)
    with mesh11, pytest.raises(ValueError):
        prefill(params, {"tokens": toks})


# ---------------------------------------------------------------------------
# end-to-end serving
# ---------------------------------------------------------------------------


def test_batched_generate_parity_with_single_stream(mesh11, key):
    """Greedy continuous batching must emit exactly the tokens the
    single-stream ``generate`` driver produces for each request."""
    arch, ctx, params = _build(mesh11, key)
    lens_py = [5, 8, 3]
    prompts = _prompts(arch, lens_py, seed=3)
    steps = 5
    cfg = engine.ServeConfig(num_slots=4, cache_len=24, prefill_pack=2,
                             prompt_buckets=(16,))
    with mesh11:
        eng = engine.ServingEngine(params, ctx, cfg)
        reqs = [Request(uid=i, tokens=p, max_new_tokens=steps)
                for i, p in enumerate(prompts)]
        report = eng.run(reqs)
        assert report.total_new_tokens == steps * len(prompts)
        assert report.prefill_calls == 2       # 3 requests, pack width 2
        for i, p in enumerate(prompts):
            single = engine.generate(
                params, ctx, jnp.asarray(np.asarray(p, np.int32)[None]),
                steps=steps, cache_len=24)
            want = list(np.asarray(single.tokens[0]))
            assert report.tokens_for(i) == want, f"request {i} diverged"


def test_serving_slot_reuse_never_recompiles(mesh11, key):
    """More requests than slots, mixed lengths within one bucket: every
    admit/evict/re-admit round must hit the same compiled entries."""
    arch, ctx, params = _build(mesh11, key)
    cfg = engine.ServeConfig(num_slots=2, cache_len=24, prefill_pack=2,
                             prompt_buckets=(16,))
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i,
                    tokens=_prompts(arch, [int(rng.integers(2, 12))],
                                    seed=i)[0],
                    max_new_tokens=int(rng.integers(1, 5)))
            for i in range(6)]
    with mesh11:
        eng = engine.ServingEngine(params, ctx, cfg)
        report = eng.run(reqs)
    assert len(report.streams) == 6
    assert report.prefill_calls >= 3          # forced several rounds
    assert eng._prefill._cache_size() == 1
    assert eng._decode._cache_size() == 1
    assert eng._sample._cache_size() <= 2     # pack-width + slot-width rows
    for r in reqs:
        assert len(report.tokens_for(r.uid)) == r.max_new_tokens


def test_serving_rejects_budget_overflow(mesh11, key):
    arch, ctx, params = _build(mesh11, key)
    cfg = engine.ServeConfig(num_slots=2, cache_len=16, prefill_pack=2,
                             prompt_buckets=(16,))
    with mesh11:
        eng = engine.ServingEngine(params, ctx, cfg)
        req = Request(uid=0, tokens=_prompts(arch, [10])[0],
                      max_new_tokens=10)     # 10 + 10 > 16
        with pytest.raises(ValueError):
            eng.run([req])


def test_generate_counts_only_generated_tokens(mesh11, key):
    """Regression for the steps_per_sec bug: the reported rate is per
    generated token (prompt positions are prefill work, not decode)."""
    arch, ctx, params = _build(mesh11, key)
    toks = jnp.asarray(np.asarray(_prompts(arch, [10])[0], np.int32)[None])
    with mesh11:
        res = engine.generate(params, ctx, toks, steps=4, cache_len=24)
    assert res.tokens.shape == (1, 4)
    assert res.steps_per_sec > 0
