"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — unit/smoke tests
must see the real single CPU device; multi-device behaviour is tested via
subprocesses in test_multidevice.py, and the 512-device production meshes
only ever exist inside repro.launch.dryrun."""

import jax
import pytest

from repro.compat import make_mesh


@pytest.fixture(scope="session")
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
