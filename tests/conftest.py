"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — unit/smoke tests
must see the real single CPU device; multi-device behaviour is tested via
subprocesses in test_multidevice.py, and the 512-device production meshes
only ever exist inside repro.launch.dryrun."""

import jax
import pytest


@pytest.fixture(scope="session")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
