"""Batched serving example: prefill a batch of prompts, then decode with
per-request cache state — the decode_32k path in miniature, including the
gather-mode MoE decode (weights stationary, tokens psum-combined).

    PYTHONPATH=src python examples/serve_batched.py --arch deepseek_v2_lite_16b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import sharding
from repro.compat import make_mesh
from repro.configs.base import get_config
from repro.models import model as model_lib
from repro.serving import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_v2_lite_16b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args()

    mesh = make_mesh((1, 1), ("data", "model"))
    arch = get_config(args.arch).reduced()
    print(f"serving {arch.name} ({arch.family}); "
          f"batch={args.batch} cache={args.cache_len}")

    ctx = model_lib.build_ctx(arch, mesh, seq_len=args.cache_len,
                              global_batch=args.batch, aux_mode="none")
    rules = model_lib.default_rules(mesh)
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(jax.random.PRNGKey(0), ctx,
                                       rules=rules)
        key = jax.random.PRNGKey(42)
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, arch.vocab_size,
            jnp.int32)
        t0 = time.time()
        res = engine.generate(params, ctx, prompts, steps=args.new_tokens,
                              cache_len=args.cache_len, temperature=0.8,
                              seed=7)
        dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s, {res.steps_per_sec:.1f} steps/s)")
    for b in range(args.batch):
        print(f"  req{b}: {res.tokens[b].tolist()}")


if __name__ == "__main__":
    main()
