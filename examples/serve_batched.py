"""Continuous-batching serving example: a queue of requests with mixed
prompt and output lengths drains through a fixed pool of decode slots —
admission packs prefill through the fused path, freed slots are reused
without recompilation, and per-stream tokens/sec is reported at the end.

    PYTHONPATH=src python examples/serve_batched.py --arch deepseek_v2_lite_16b
    PYTHONPATH=src python examples/serve_batched.py --arch internvl2_26b

See docs/serving.md for the scheduler / slot / KV-cache API.
"""

import argparse

import jax
import numpy as np

from repro import sharding
from repro.compat import make_mesh
from repro.configs.base import get_config
from repro.models import model as model_lib, vlm
from repro.serving import engine
from repro.serving.scheduler import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_v2_lite_16b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--prefill-pack", type=int, default=2)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    mesh = make_mesh((1, 1), ("data", "model"))
    arch = get_config(args.arch).reduced()
    print(f"serving {arch.name} ({arch.family}); "
          f"slots={args.num_slots} pack={args.prefill_pack} "
          f"cache={args.cache_len}")

    ctx = model_lib.build_ctx(arch, mesh, seq_len=args.cache_len,
                              global_batch=args.num_slots, aux_mode="none")
    rules = model_lib.default_rules(mesh)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(4, 24))
        new = int(rng.integers(2, 24))
        fe = (vlm.make_patches(rng, 1, arch)[0]
              if arch.frontend == "vision" else None)
        reqs.append(Request(uid=uid,
                            tokens=rng.integers(0, arch.vocab_size,
                                                size=plen).tolist(),
                            max_new_tokens=new,
                            temperature=args.temperature,
                            frontend=fe))

    cfg = engine.ServeConfig(num_slots=args.num_slots,
                             cache_len=args.cache_len,
                             prefill_pack=args.prefill_pack,
                             prompt_buckets=(24,))
    with mesh, sharding.axis_rules(rules):
        params = model_lib.init_params(jax.random.PRNGKey(0), ctx,
                                       rules=rules)
        eng = engine.ServingEngine(params, ctx, cfg)
        report = eng.run(reqs, seed=args.seed)

    print(f"served {len(report.streams)} streams: "
          f"{report.total_new_tokens} tokens in {report.wall_time:.1f}s "
          f"({report.tokens_per_sec:.1f} tok/s aggregate, "
          f"{report.decode_steps} decode steps, "
          f"{report.prefill_calls} prefill packs)")
    for s in report.streams:
        print(f"  req{s.request.uid}: prompt={s.request.prompt_len:2d} "
              f"new={len(s.generated):2d} "
              f"{s.tokens_per_sec:6.1f} tok/s  {s.generated[:8]}")


if __name__ == "__main__":
    main()
