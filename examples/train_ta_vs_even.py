"""End-to-end training driver: a ~100M-parameter MoE LM trained for a few
hundred steps, comparing the TA-MoE topology loss against the load-balance
baseline (paper Fig. 3 protocol).

Full run (~100M params, 200 steps — give it time on CPU):
    PYTHONPATH=src python examples/train_ta_vs_even.py --full
CI-sized run:
    PYTHONPATH=src python examples/train_ta_vs_even.py
"""

import argparse
import dataclasses


from repro.compat import make_mesh
from repro.configs.base import MoEArch, RunConfig, get_config
from repro.training import trainer


def build_arch(full: bool):
    base = get_config("gpt3_medium_moe")
    if full:
        # ~100M active params: 8 layers, d=512, 8 experts of f=1024, top-2
        return dataclasses.replace(
            base, name="moe-100m", num_layers=8, d_model=512, num_heads=8,
            num_kv_heads=8, d_ff=2048, vocab_size=50304,
            moe=MoEArch(num_experts=8, top_k=2, d_ff_expert=1024,
                        moe_period=2, capacity_factor=1.5))
    return base.reduced()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    steps = args.steps or (200 if args.full else 40)
    seq = 256 if args.full else 64
    batch = 8 if args.full else 4

    mesh = make_mesh((1, 1), ("data", "model"))
    arch = build_arch(args.full)
    run = RunConfig(seq_len=seq, global_batch=batch, learning_rate=6e-4,
                    total_steps=steps, warmup_steps=max(steps // 10, 1))

    results = {}
    for mode in ("lb", "ta"):
        print(f"\n=== aux_mode={mode} ===")
        res = trainer.train(arch, run, mesh, steps=steps, aux_mode=mode,
                            log_every=max(steps // 10, 1), data_seed=0)
        results[mode] = res
    print("\n=== summary (paper Fig. 3: curves should coincide) ===")
    for mode, res in results.items():
        fb = res.metrics_history[-1].get("frac_by_level")
        frac = ("  frac_by_level=[" + ",".join(f"{v:.2f}" for v in fb) + "]"
                if fb else "")
        print(f"  {mode}: final loss {res.losses[-1]:.4f}  "
              f"({res.steps_per_sec:.2f} steps/s){frac}")
    gap = abs(results["ta"].losses[-1] - results["lb"].losses[-1])
    print(f"  convergence gap: {gap:.4f} "
          f"({'OK — TA does not hurt accuracy' if gap < 0.1 else 'LARGE'})")


if __name__ == "__main__":
    main()
