"""Quickstart: build a TA-MoE model, inspect its topology plan, train a few
steps, and generate — all on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro import sharding
from repro.compat import make_mesh
from repro.configs.base import RunConfig, get_config
from repro.core import topology
from repro.models import model as model_lib
from repro.serving import engine
from repro.training import trainer


def main():
    # 1. The topology plan: what TA-MoE computes before training starts.
    # The plan API is level-indexed: one capacity multiplier per topology
    # level, however deep the hierarchy is.
    print("== TA-MoE dispatch plan for the 2-pod production mesh ==")
    tm = topology.tpu_topology(num_pods=2, devices_per_pod=16)
    ratios = topology.per_level_ratios(tm)
    print(f"  capacity multipliers by level (0=self .. {len(ratios)-1}): "
          f"{[round(float(r), 3) for r in ratios]}")
    print("  -> intra-pod chunks are "
          f"{ratios[1]/ratios[2]:.1f}x larger than cross-pod chunks "
          "(= the ICI/DCI bandwidth ratio, Eq. 7 of the paper)")
    # the same solver on a 3-tier hierarchy (pods of nodes of devices):
    tm3 = topology.tree_topology_nd((2, 2, 8))
    r3 = topology.per_level_ratios(tm3)
    print(f"  3-tier [[8,8],[8,8]]-style mesh multipliers: "
          f"{[round(float(r), 3) for r in r3]}\n")

    # 2. Train the paper's model (reduced) with the topology-aware loss.
    # RunConfig.use_pallas picks the token-permutation implementation in
    # the dispatch hot path: None (default) = auto — the Pallas
    # kernels/moe_permute sort-based permute/unpermute on TPU/GPU, the jnp
    # reference on CPU (so this script is identical math everywhere);
    # True/False force it.
    mesh = make_mesh((1, 1), ("data", "model"))
    arch = get_config("gpt3_medium_moe").reduced()
    run = RunConfig(seq_len=64, global_batch=4, learning_rate=1e-3,
                    total_steps=20, warmup_steps=2, aux_mode="ta",
                    use_pallas=None)
    print("== training gpt3-medium-moe (reduced) with l_topo ==")
    res = trainer.train(arch, run, mesh, steps=15, log_every=5)

    # 3. Generate from the trained model.
    print("\n== generation ==")
    ctx = model_lib.build_ctx(arch, mesh, seq_len=64, global_batch=2,
                              aux_mode="none")
    rules = model_lib.default_rules(mesh)
    with mesh, sharding.axis_rules(rules):
        prompts = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
        out = engine.generate(res.params, ctx, prompts, steps=8,
                              cache_len=64)
    print(f"  generated tokens: {out.tokens.tolist()}")
    print(f"  decode steps/s: {out.steps_per_sec:.1f}")


if __name__ == "__main__":
    main()
